"""Property-based + metamorphic fleet invariants (ISSUE 3 satellite).

Two PRs of hand-picked scenarios pinned exact numbers; this suite pins
the INVARIANTS those numbers are instances of, over randomized seeded
scenarios: energy conservation (fleet Wh is the sum of device meters),
non-negativity, the clairvoyant floor under every router (autoscaled
included), latency-accounting consistency, and the autoscaler's safety
contract (max_replicas=1 is trace-identical to no autoscaler; a single
device never scales; replica counts respect the cap).

Runs with real ``hypothesis`` when installed, and under the
deterministic mini-runner in ``tests/_hypothesis_shim.py`` otherwise
(per-test seeded example streams, so failures reproduce run-to-run).

The metamorphic monotonicity laws are scoped to always-on fleets on
purpose: with an eviction policy, an EXTRA arrival can legitimately
*save* energy by bridging a gap that would otherwise pay an eviction
plus a reload (ski rental: step * gap < step * T* + reload), so
"more traffic => more energy" is only a law when nothing evicts.
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, st

from repro.core import QWEN25_7B_MEASURED
from repro.core import traffic
from repro.core.scheduler import AlwaysOn, Breakeven, FixedTTL
from repro.fleet import (CarbonAwareRouter, CarbonBreakeven, Cluster,
                         Consolidator, FleetModel, FleetModelSpec,
                         FleetScenario, ReplicaAutoscaler, build_fleet,
                         get_mix, marginal_park_w, run_fleet,
                         scaleout_cost_j)
from repro.serving import ConstantServiceTime, DeviceRuntime

GB = 1024 ** 3
HOUR = 3600.0
ROUTERS = ("warm-first", "least-loaded", "energy-greedy", "breakeven-aware",
           "slo-aware")
PATTERNS = ("steady", "bursty", "diurnal", "mmpp")
POLICIES = {"always-on": AlwaysOn, "breakeven": Breakeven,
            "ttl-10min": lambda: FixedTTL(600.0),
            "carbon-breakeven": CarbonBreakeven}


def _scenario(seed, *, router="warm-first", policy="breakeven",
              fleet="h100+a100+l40s", n_models=3, horizon_s=6 * HOUR,
              service_s=0.0, autoscaler=None, prewarm=True,
              max_batch=2) -> FleetScenario:
    """Randomized-but-seeded scenario: patterns, sizes, and homes all
    derive from ``seed``, so every drawn example is reproducible."""
    rng = np.random.default_rng(seed)
    devices = build_fleet(fleet)
    models = []
    for i in range(n_models):
        pat = PATTERNS[int(rng.integers(len(PATTERNS)))]
        arr = traffic.PATTERNS[pat](seed=seed + 17 * i)
        arr = arr[arr < horizon_s]
        ckpt_gb = float(rng.uniform(3.0, 20.0))
        home = devices[int(rng.integers(len(devices)))].instance_id \
            if prewarm else None
        spec = FleetModelSpec(
            model_id=f"m{i}", policy_factory=POLICIES[policy],
            checkpoint_bytes=int(ckpt_gb * GB), vram_gb=ckpt_gb * 1.1,
            home=home)
        models.append(FleetModel(spec, arr))
    return FleetScenario(devices=devices, models=models, router=router,
                         horizon_s=horizon_s, service_s=service_s,
                         max_batch=max_batch, autoscaler=autoscaler)


# ---------------------------------------------------------------------------
# conservation / non-negativity / bounds
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.sampled_from(ROUTERS))
@settings(max_examples=10, deadline=None)
def test_fleet_energy_is_sum_of_device_meters(seed, router):
    res = run_fleet(_scenario(seed, router=router))
    assert res.energy_wh == pytest.approx(
        sum(d.total_wh for d in res.devices), rel=1e-12)
    for d in res.devices:
        parts = sum(v for k, v in d.energy_wh.items() if k != "total")
        assert d.total_wh == pytest.approx(parts, rel=1e-12)


@given(st.integers(0, 10_000), st.sampled_from(list(POLICIES)))
@settings(max_examples=10, deadline=None)
def test_all_energies_nonnegative(seed, policy):
    res = run_fleet(_scenario(seed, policy=policy,
                              autoscaler=ReplicaAutoscaler()))
    assert res.energy_wh >= 0.0
    assert res.parking_tax_wh >= 0.0
    for d in res.devices:
        assert d.parking_tax_wh >= -1e-12
        for state, wh in d.energy_wh.items():
            assert wh >= -1e-12, (d.instance_id, state)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_nongated_clairvoyant_bound_floors_every_router(seed):
    """The offline NON-GATED lower bound (``lb_nongated_wh``) never
    exceeds any non-gated online policy's energy -- autoscaled routers
    included (held replicas only ADD warm time).  These scenarios run no
    gating consolidator, so the scoped floor applies; a gated run is
    explicitly allowed to land below it (test_power_states pins that)."""
    for router in ROUTERS:
        for scaler in (None, ReplicaAutoscaler()):
            res = run_fleet(_scenario(seed, router=router,
                                      autoscaler=scaler))
            assert res.energy_wh >= res.lb_nongated_wh - 1e-6, \
                (router, scaler is not None)
            assert res.cv_per_model_wh >= res.lb_nongated_wh - 1e-9


@given(st.integers(0, 10_000), st.sampled_from(ROUTERS))
@settings(max_examples=10, deadline=None)
def test_savings_vs_is_bounded(seed, router):
    base = run_fleet(_scenario(seed, policy="always-on"))
    res = run_fleet(_scenario(seed, router=router))
    s = res.savings_vs(base)
    assert math.isfinite(s) and s <= 1.0
    import dataclasses
    assert res.savings_vs(dataclasses.replace(base, energy_wh=0.0)) == 0.0


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_latency_accounting_consistent(seed):
    res = run_fleet(_scenario(seed, router="slo-aware", service_s=5.0))
    lat = np.asarray(res.latencies_s)
    assert lat.size == res.requests
    assert (lat >= 0.0).all()
    assert (np.diff(lat) >= 0.0).all()                 # sorted
    assert lat.sum() == pytest.approx(res.added_latency_s_total, rel=1e-9)
    assert res.p50_added_latency_s <= res.p99_added_latency_s + 1e-12


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_requests_conserved(seed):
    sc = _scenario(seed, router="energy-greedy")
    expected = sum(len(fm.arrivals_s) for fm in sc.models)
    res = run_fleet(sc)
    assert res.requests == expected


# ---------------------------------------------------------------------------
# autoscaler safety contract
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(2, 3))
@settings(max_examples=10, deadline=None)
def test_autoscaler_respects_max_replicas(seed, cap):
    scaler = ReplicaAutoscaler(max_replicas=cap, tick_s=60.0,
                               cooldown_s=60.0, pressure_hi=0.25)
    res = run_fleet(_scenario(seed, router="warm-first", service_s=20.0,
                              autoscaler=scaler))
    assert res.peak_replicas() <= cap
    for mid, log in res.replica_timeline.items():
        for _, n in log:
            assert 0 <= n <= cap, mid


@given(st.integers(0, 10_000), st.sampled_from(["h100", "a100", "l40s"]))
@settings(max_examples=10, deadline=None)
def test_single_device_fleet_never_scales(seed, sku):
    """A single route on a single device must never scale -- the
    equivalence anchor to core/simulator.py depends on it."""
    scaler = ReplicaAutoscaler(tick_s=60.0, pressure_hi=0.1,
                               pressure_lo=0.05, cooldown_s=60.0)
    res = run_fleet(_scenario(seed, fleet=sku, n_models=1,
                              service_s=30.0, autoscaler=scaler))
    assert res.scale_outs == 0 and res.scale_ins == 0
    assert res.peak_replicas() <= 1


@given(st.integers(0, 10_000), st.sampled_from(ROUTERS))
@settings(max_examples=8, deadline=None)
def test_autoscaler_max_replicas_one_is_trace_identical(seed, router):
    """max_replicas=1 disables the controller outright: same joules,
    same cold starts, same per-request latencies as no autoscaler."""
    plain = run_fleet(_scenario(seed, router=router, service_s=10.0))
    gated = run_fleet(_scenario(
        seed, router=router, service_s=10.0,
        autoscaler=ReplicaAutoscaler(max_replicas=1, tick_s=30.0)))
    assert gated.energy_wh == pytest.approx(plain.energy_wh, rel=1e-12)
    assert gated.cold_starts == plain.cold_starts
    assert gated.migrations == plain.migrations
    np.testing.assert_allclose(gated.latencies_s, plain.latencies_s,
                               rtol=0, atol=1e-12)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_replica_timeline_well_formed(seed):
    res = run_fleet(_scenario(seed, router="slo-aware", service_s=15.0,
                              autoscaler=ReplicaAutoscaler(tick_s=120.0)))
    for mid, log in res.replica_timeline.items():
        times = [t for t, _ in log]
        counts = [n for _, n in log]
        assert times == sorted(times)
        assert all(n >= 0 for n in counts)
        # entries only on change: consecutive counts differ
        assert all(a != b for a, b in zip(counts, counts[1:]))
        assert res.peak_replicas(mid) == max(counts, default=0)


# ---------------------------------------------------------------------------
# carbon invariants (ISSUE 4)
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.sampled_from(ROUTERS))
@settings(max_examples=10, deadline=None)
def test_flat_trace_carbon_equals_scalar_accounting(seed, router):
    """Invariant: with the default (flat) trace, trace-integrated carbon
    IS the scalar bookkeeping -- energy_kwh x zone mean -- to 1e-9 kg,
    whatever the router/consolidation did to the schedule."""
    res = run_fleet(_scenario(seed, router=router))
    mix = get_mix("USA")
    assert res.carbon_kg == pytest.approx(
        res.energy_wh / 1e3 * mix.gwp_kg_per_kwh, abs=1e-9)
    assert res.carbon_kg == pytest.approx(res.carbon_kg_flat, abs=1e-9)
    assert res.carbon_kg == pytest.approx(
        sum(d.carbon_kg for d in res.devices), rel=1e-12)


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_carbon_aware_never_exceeds_always_on_emissions(seed):
    """Invariant (ISSUE 4 satellite): carbon-aware scheduling never
    emits more than the always-on warm-everywhere baseline under the
    same diurnal trace -- eviction only sheds standing power, and the
    carbon-aware components only reorder work the energy policies
    would also do.  The baseline is priced by re-integrating its
    recorded power timeline under the same trace (identical schedule,
    trace-blind dynamics)."""
    from repro.fleet import make_trace
    duck = make_trace("solar-duck", get_mix("USA").gwp_kg_per_kwh)
    base_kg = run_fleet(_scenario(seed, policy="always-on")) \
        .carbon_with(duck)
    aware = _scenario(seed, router=CarbonAwareRouter(1e9),
                      policy="carbon-breakeven")
    aware.carbon_trace = duck
    aware.consolidator = Consolidator(carbon_aware=True)
    res = run_fleet(aware)
    assert 0.0 <= res.carbon_kg <= base_kg + 1e-9


# ---------------------------------------------------------------------------
# metamorphic laws
# ---------------------------------------------------------------------------

def _always_on_scenario(seed, arrivals_by_model, devices):
    models = []
    for i, arr in enumerate(arrivals_by_model):
        spec = FleetModelSpec(
            model_id=f"m{i}", policy_factory=AlwaysOn,
            checkpoint_bytes=int(8 * GB), vram_gb=9.0,
            home=devices[i % 2].instance_id)     # homes on the first two
        models.append(FleetModel(spec, arr))
    return FleetScenario(devices=devices, models=models,
                         router="warm-first", horizon_s=6 * HOUR,
                         service_model=ConstantServiceTime(5.0),
                         max_batch=2)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_doubling_arrivals_never_decreases_energy_always_on(seed):
    """Metamorphic: with always-on fleets (nothing evicts, so no reload
    can be bridged away) every added request adds >= 0 joules."""
    arrs = [traffic.PATTERNS["steady"](seed=seed)[:200],
            traffic.PATTERNS["bursty"](seed=seed + 1)[:200]]
    arrs = [a[a < 6 * HOUR] for a in arrs]
    doubled = [np.sort(np.concatenate([a, a[:-1] + np.diff(a) / 2.0]))
               for a in arrs]
    base = run_fleet(_always_on_scenario(seed, arrs, build_fleet("h100+a100")))
    up = run_fleet(_always_on_scenario(seed, doubled,
                                       build_fleet("h100+a100")))
    assert up.requests > base.requests
    assert up.energy_wh >= base.energy_wh - 1e-9


@given(st.integers(0, 10_000), st.sampled_from(["l40s", "a100", "tpu_v5e"]))
@settings(max_examples=10, deadline=None)
def test_empty_device_costs_at_most_its_bare_idle_floor(seed, extra_sku):
    """Metamorphic: an extra device nobody routes to adds exactly its
    bare-idle energy -- never more (warm-first with everything prewarmed
    never touches it)."""
    arrs = [traffic.PATTERNS["diurnal"](seed=seed)]
    arrs = [a[a < 6 * HOUR] for a in arrs]
    small = build_fleet("h100+a100")
    big = build_fleet("h100+a100+" + extra_sku)
    base = run_fleet(_always_on_scenario(seed, arrs, small))
    grown = run_fleet(_always_on_scenario(seed, arrs, big))
    extra = {d.instance_id: d for d in grown.devices}[big[-1].instance_id]
    # the stranger idles at bare power for the whole metered window
    # (which may overshoot the horizon by the final service burst) and
    # contributes not one joule more
    assert extra.energy_wh.get("bare", 0.0) == \
        pytest.approx(extra.total_wh, rel=1e-12)
    assert extra.total_wh >= \
        big[-1].profile.p_base_w * 6 * HOUR / 3600.0 - 1e-9
    assert grown.energy_wh == \
        pytest.approx(base.energy_wh + extra.total_wh, rel=1e-12)


# ---------------------------------------------------------------------------
# deterministic unit checks (no strategies)
# ---------------------------------------------------------------------------

def test_autoscaler_plan_empty_when_disabled_or_single_device():
    cluster = Cluster(build_fleet("h100"))
    cluster.register_model(FleetModelSpec(
        "m", AlwaysOn, loader=QWEN25_7B_MEASURED, vram_gb=5.0))
    cluster.replica("h100-0", "m")
    cluster.managers["h100-0"].prewarm("m")
    assert ReplicaAutoscaler().plan(cluster, 0.0) == []      # one device
    two = Cluster(build_fleet("h100+a100"))
    two.register_model(FleetModelSpec(
        "m", AlwaysOn, loader=QWEN25_7B_MEASURED, vram_gb=5.0))
    two.replica("h100-0", "m")
    two.managers["h100-0"].prewarm("m")
    assert ReplicaAutoscaler(max_replicas=1).plan(two, 0.0) == []


def test_scale_in_refuses_unsafe_replicas():
    cluster = Cluster(build_fleet("h100+a100"))
    cluster.register_model(FleetModelSpec(
        "m", AlwaysOn, loader=QWEN25_7B_MEASURED, vram_gb=5.0))
    rt = {did: DeviceRuntime(2) for did in cluster.devices}
    cluster.attach_runtime(rt, ConstantServiceTime(0.0))
    m = cluster.replica("h100-0", "m")
    assert not cluster.scale_in("h100-0", "m")               # not resident
    cluster.managers["h100-0"].prewarm("m")
    m.pins = 1
    assert not cluster.scale_in("h100-0", "m")               # pinned demand
    m.pins = 0
    slot = rt["h100-0"].pool("m").acquire()
    assert not cluster.scale_in("h100-0", "m")               # busy slot
    rt["h100-0"].pool("m").release(slot)
    rt["h100-0"].wait_q("m").append(1.0)
    assert not cluster.scale_in("h100-0", "m")               # queued demand
    rt["h100-0"].wait_q("m").clear()
    assert cluster.scale_in("h100-0", "m")                   # safe now
    assert cluster.managers["h100-0"].meter.state == "bare"


def test_scaleout_cost_monotone_and_context_aware():
    dev = build_fleet("h100")[0]
    ld = QWEN25_7B_MEASURED
    c0 = scaleout_cost_j(dev, ld, 0.0, context_on=False)
    c1 = scaleout_cost_j(dev, ld, 600.0, context_on=False)
    c2 = scaleout_cost_j(dev, ld, 3600.0, context_on=False)
    assert c0 <= c1 <= c2                        # monotone in hold time
    assert marginal_park_w(dev, True) == 0.0
    assert scaleout_cost_j(dev, ld, 3600.0, context_on=True) == \
        pytest.approx(c0)                        # context-on parks free


def test_held_replica_survives_lull_then_policy_replica_evicts():
    """End-to-end: a burst scales the route out; the held replica stays
    warm through a lull that evicts the policy-armed primary, so the
    post-lull burst is served warm (no reload) and total queueing falls
    vs the single-replica run."""
    ld = QWEN25_7B_MEASURED
    burst = [float(t) for t in range(100, 160, 4)]           # 15 reqs
    late = [5000.0, 5004.0]

    def run(scaler):
        spec = FleetModelSpec("hot", lambda: FixedTTL(300.0), loader=ld,
                              vram_gb=5.0, home="h100-0")
        return run_fleet(FleetScenario(
            devices=build_fleet("h100+a100"),
            models=[FleetModel(spec, burst + late)],
            router="warm-first", horizon_s=8000.0, service_s=30.0,
            max_batch=2, autoscaler=scaler))

    plain = run(None)
    auto = run(ReplicaAutoscaler(tick_s=20.0, cooldown_s=20.0,
                                 pressure_hi=0.5, max_replicas=2))
    assert auto.scale_outs == 1 and auto.peak_replicas("hot") == 2
    # same cold-start budget: the scale-out load REPLACES the t=5000
    # reload the single-replica run pays (prewarm + one load each)
    assert plain.cold_starts == auto.cold_starts == 2
    # plain goes cold before the late burst; the held replica does not
    counts_at_late = [n for t, n in plain.replica_timeline["hot"]
                      if t <= late[0]]
    assert counts_at_late[-1] == 0
    assert [n for t, n in auto.replica_timeline["hot"]][-1] >= 1
    # the second replica halves the burst queue and kills the reload
    # wait: strictly less total added latency, strictly smaller max
    assert auto.added_latency_s_total < plain.added_latency_s_total
    assert max(auto.latencies_s) < max(plain.latencies_s)


# ---------------------------------------------------------------------------
# synthetic-day trace generator invariants (ISSUE 6)
# ---------------------------------------------------------------------------

from repro.fleet import flash_crowd, product_launch, regional_outage  # noqa: E402

_GENERATORS = {"flash-crowd": flash_crowd, "product-launch": product_launch,
               "regional-outage": regional_outage}


@given(st.integers(0, 10_000), st.sampled_from(sorted(_GENERATORS)))
@settings(max_examples=9, deadline=None)
def test_generated_traces_well_formed(seed, gen_name):
    """Invariant: every synthetic day is a valid arrival trace -- sorted,
    non-negative, strictly inside the horizon, with positive checkpoint
    footprints -- for any seed."""
    tr = _GENERATORS[gen_name](seed=seed, n_routes=4, horizon_s=6 * HOUR)
    assert len(tr.routes) == 4
    assert len({r.route_id for r in tr.routes}) == 4
    for r in tr.routes:
        a = r.arrivals_s
        assert np.all(np.diff(a) >= 0.0)
        assert a.size == 0 or (a[0] >= 0.0 and a[-1] < tr.horizon_s)
        assert r.checkpoint_gb > 0.0
    assert tr.requests == sum(r.requests for r in tr.routes)


@given(st.integers(0, 10_000))
@settings(max_examples=9, deadline=None)
def test_regional_outage_window_is_dark(seed):
    """Invariant: during the outage, NO route sees a single arrival --
    the upstream region is gone, not merely degraded."""
    t0 = 2 * HOUR
    tr = regional_outage(seed=seed, n_routes=4, horizon_s=6 * HOUR,
                         outage_start_s=t0, outage_s=HOUR)
    assert tr.requests > 0
    for r in tr.routes:
        a = r.arrivals_s
        assert not np.any((a >= t0) & (a < t0 + HOUR))


@given(st.integers(0, 10_000))
@settings(max_examples=9, deadline=None)
def test_product_launch_route_silent_before_launch(seed):
    """Invariant: the launching route has EXACTLY zero arrivals before
    the launch instant (the model is not public yet), and -- it being a
    launch -- some traffic after it."""
    tr = product_launch(seed=seed, n_routes=4, horizon_s=8 * HOUR,
                        launch_s=3 * HOUR)
    launch = tr.routes[0].arrivals_s
    assert not np.any(launch < 3 * HOUR)
    assert launch.size > 0
