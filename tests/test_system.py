"""End-to-end behaviour tests for the paper's system: the full
measurement -> model -> decision pipeline, run through the real
components (no mocks)."""
import numpy as np
import pytest

from repro.core import H100, PYTORCH_70B
from repro.core.breakeven import breakeven_seconds
from repro.core.doseresponse import run_simulated_dose_response
from repro.core.scheduler import AlwaysOn, Breakeven
from repro.core.simulator import simulate
from repro.core import traffic


def test_measure_then_decide_pipeline():
    """The paper's whole point, end to end: measure a device's parking tax
    via dose-response, derive T*, schedule with it, save energy."""
    # 1. measure (Phase 2 protocol on the simulated oracle)
    dr = run_simulated_dose_response(H100, seed=11)
    assert dr.tost.equivalent                   # beta bounded ~ 0
    measured_tax = dr.dvfs_step_w               # ~ 49.9 W

    # 2. derive the breakeven from MEASURED hardware parameters
    import dataclasses
    measured_profile = dataclasses.replace(
        H100, p_base_w=dr.bare_idle_w, p_ctx_w=dr.ctx_idle_w)
    t_star = breakeven_seconds(PYTORCH_70B, measured_profile)
    assert abs(t_star - 270.5) < 10.0           # paper: 4.5 min

    # 3. schedule with it on a day of traffic; must beat always-on
    arr = traffic.poisson(5.0, seed=0)
    base = simulate(arr, AlwaysOn(), measured_profile, PYTORCH_70B)
    be = simulate(arr, Breakeven(PYTORCH_70B, measured_profile),
                  measured_profile, PYTORCH_70B)
    savings = be.savings_vs(base)
    assert 0.10 < savings < 0.35                # paper: 18.1% on steady

    # 4. energy-conservation identity of the simulator:
    #    base - be = evicted*(P_ctx - P_base) - loading*(P_load - P_ctx)
    assert be.evicted_s * measured_tax / 3600.0 == pytest.approx(
        base.energy_wh - be.energy_wh
        + (be.loading_s / 3600.0) * (PYTORCH_70B.p_load_w
                                     - measured_profile.p_ctx_w),
        rel=0.05)


def test_model_size_independence():
    """Paper conclusion: a 1 GB and a 64 GB model pay the SAME parking tax;
    T* depends on the loader, not the footprint."""
    from repro.core.coldstart import LoaderSpec
    fast_small = LoaderSpec("small", 150.0, 4.0)
    fast_large = LoaderSpec("large", 150.0, 4.0)   # same loader profile
    assert breakeven_seconds(fast_small, H100) == \
        breakeven_seconds(fast_large, H100)
    # small models reload faster -> shorter T* -> evict MORE aggressively
    slow = LoaderSpec("slow", 300.0, 45.0)
    assert breakeven_seconds(fast_small, H100) < \
        breakeven_seconds(slow, H100)
