"""Unit + property tests for the statistics pipeline (paper sections 3-4)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # optional extra: property tests skip, rest run
    from _hypothesis_shim import given, settings, st

from repro.core import stats


def test_ols_recovers_known_slope(rng):
    x = np.linspace(0, 64, 40)
    y = 3.0 + 0.5 * x + rng.normal(0, 0.01, size=40)
    res = stats.ols(x, y)
    assert abs(res.slope - 0.5) < 1e-2
    assert abs(res.intercept - 3.0) < 0.05
    assert res.ci_low < 0.5 < res.ci_high
    assert res.p_value < 1e-10


def test_ols_flat_has_high_p(rng):
    x = np.linspace(0, 64, 40)
    y = 100.0 + rng.normal(0, 0.1, size=40)
    res = stats.ols(x, y)
    assert abs(res.slope) < 0.01
    assert res.p_value > 0.01


def test_tost_bounds_flat_slope(rng):
    x = np.linspace(0, 64, 80)
    y = 100.0 + rng.normal(0, 0.1, size=80)
    res = stats.ols(x, y)
    t = stats.tost_slope(res, bound=0.1)
    assert t.equivalent and t.p_tost < 0.05


def test_tost_rejects_real_slope(rng):
    x = np.linspace(0, 64, 80)
    y = 100.0 + 0.5 * x + rng.normal(0, 0.1, size=80)
    res = stats.ols(x, y)
    t = stats.tost_slope(res, bound=0.1)
    assert not t.equivalent


def test_welch_cohens_matches_paper_scale(rng):
    bare = rng.normal(74.7, 7.9, size=5000)
    ctx = rng.normal(145.5, 11.2, size=5000)
    r = stats.welch_cohens(bare, ctx)
    assert 65 < r.diff < 76
    assert 6.5 < r.cohens_d < 8.2           # paper: 7.3
    assert r.p_value < 1e-100


def test_effective_sample_size_eq6():
    # paper: N ~ 335,267, tau 6-10 -> N_eff ~ 16k-26k
    lo = stats.effective_sample_size(335_267, 10.0)
    hi = stats.effective_sample_size(335_267, 6.0)
    assert 15_000 < lo < 17_000
    assert 25_000 < hi < 27_000


def test_autocorr_time_detects_ar1(rng):
    rho = np.exp(-1.0 / 8.0)
    x = np.empty(20_000)
    acc = 0.0
    eps = rng.normal(0, 1, 20_000) * np.sqrt(1 - rho ** 2)
    for i in range(20_000):
        acc = rho * acc + eps[i]
        x[i] = acc
    tau = stats.autocorr_time(x)
    assert 4.0 < tau < 14.0                 # integrated tau ~ 7.5 for rho


@given(st.floats(1.0, 1e4), st.floats(0.0, 50.0))
@settings(max_examples=50, deadline=None)
def test_neff_never_exceeds_n(n_raw, tau):
    n_raw = int(n_raw)
    assert stats.effective_sample_size(n_raw, tau) <= n_raw


@given(st.integers(5, 200), st.floats(-5, 5), st.floats(-2, 2))
@settings(max_examples=30, deadline=None)
def test_ols_exact_fit_property(n, intercept, slope):
    x = np.arange(n, dtype=float)
    y = intercept + slope * x
    y[0] += 1e-9                             # avoid zero variance degeneracy
    res = stats.ols(x, y)
    assert abs(res.slope - slope) < 1e-6 + 1e-6 * abs(slope)
