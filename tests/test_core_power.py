"""Power model, telemetry oracle, dose-response, phase-1 pipeline tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # optional extra: property tests skip, rest run
    from _hypothesis_shim import given, settings, st

from repro.core import A100, H100, L40S, PROFILES
from repro.core.doseresponse import (default_vram_ladder,
                                     run_simulated_dose_response)
from repro.core.phase1 import analyze_fleet
from repro.core.telemetry import SimulatedPowerReader, simulate_fleet


def test_profiles_match_paper_table2():
    assert H100.dvfs_step_w == pytest.approx(49.9, abs=0.01)
    assert A100.dvfs_step_w == pytest.approx(26.3, abs=0.01)
    assert L40S.dvfs_step_w == pytest.approx(66.4, abs=0.15)  # paper rounds
    assert L40S.ctx_pct_tdp == pytest.approx(0.19, abs=0.005)


@given(st.sampled_from(list(PROFILES.values())),
       st.booleans(), st.floats(0.0, 48.0))
@settings(max_examples=60, deadline=None)
def test_idle_power_piecewise_constant(profile, ctx, vram):
    """Eq. 1 with beta=0: power independent of VRAM, steps with context."""
    p = profile.idle_power_w(ctx, vram)
    assert p == profile.idle_power_w(ctx, 0.0)          # flat in VRAM
    assert profile.idle_power_w(True, vram) > \
        profile.idle_power_w(False, vram)               # context step


def test_instance_offset_preserves_step():
    shifted = H100.with_instance_offset(23.0)
    assert shifted.dvfs_step_w == pytest.approx(H100.dvfs_step_w)
    assert shifted.p_base_w == pytest.approx(H100.p_base_w + 23.0)


def test_reader_rejects_over_capacity():
    rd = SimulatedPowerReader(H100)
    with pytest.raises(ValueError):
        rd.set_state(context_active=True, vram_gb=100.0)


def test_dose_response_recovers_flat_beta():
    for prof in (H100, A100, L40S):
        dr = run_simulated_dose_response(prof, seed=1)
        assert abs(dr.regression.slope) < 0.02           # paper bound
        assert dr.tost.equivalent
        assert dr.dvfs_step_w == pytest.approx(prof.dvfs_step_w, abs=1.5)
        assert dr.context_share_of_tax > 0.98


def test_dose_response_detects_injected_slope():
    """If VRAM power were real, the pipeline must find it (sensitivity)."""
    import dataclasses
    hot = dataclasses.replace(H100, beta_w_per_gb=0.5)
    dr = run_simulated_dose_response(hot, seed=1)
    assert dr.regression.slope == pytest.approx(0.5, abs=0.05)
    assert not dr.tost.equivalent


def test_ladder_covers_range():
    lad = default_vram_ladder(64.0, n_levels=9)
    assert lad[0] == 0.0 and lad[-1] == 64.0 and len(lad) == 9


def test_phase1_pipeline():
    ds = simulate_fleet(seed=7)
    assert len(ds) == 336_226
    idle = ds.idle_only()
    assert len(idle) >= 335_000
    res = analyze_fleet(ds)
    assert 60 < res.context_effect_w < 85                # paper: 70.9
    assert res.cohens_d > 4
    assert abs(res.pooled_slope_w_per_gb) < 0.05
    # per-device slope bound (paper section 8)
    for g, reg in res.per_gpu_slopes.items():
        assert abs(reg.slope) < 0.06, (g, reg.slope)
