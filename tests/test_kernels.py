"""Pallas kernel allclose sweeps vs. the pure-jnp oracles (interpret mode).

Per assignment: for each kernel, sweep shapes/dtypes and
assert_allclose against ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

FLASH_SHAPES = [
    # (B, H, Hkv, S, D)
    (1, 4, 4, 128, 64),      # MHA
    (2, 8, 2, 256, 64),      # GQA 4:1
    (1, 4, 1, 256, 128),     # MQA
    (2, 2, 2, 512, 32),      # long-ish
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_attention_sweep(shape, dtype, window):
    b, h, hkv, s, d = shape
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, d), dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


DECODE_SHAPES = [
    (1, 4, 4, 256, 64),
    (2, 8, 2, 512, 64),
    (4, 8, 1, 1024, 128),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(shape, dtype):
    b, h, hkv, t, d = shape
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, t, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, t, d), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, t, size=b), jnp.int32)
    got = ops.decode_attention(q, k, v, lengths)
    want = ref.decode_attention_ref(q, k, v, lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_decode_ignores_entries_past_length():
    """Garbage beyond the frontier must not affect the output."""
    b, h, hkv, t, d = 1, 4, 2, 256, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, t, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, t, d))
    out1 = ops.decode_attention(q, k, v, jnp.array([100]))
    k2 = k.at[:, :, 100:].set(1e4)
    v2 = v.at[:, :, 100:].set(-1e4)
    out2 = ops.decode_attention(q, k2, v2, jnp.array([100]))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


@pytest.mark.parametrize("shape", [(1, 128, 128), (2, 256, 256),
                                   (3, 384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(shape, dtype):
    b, s, w = shape
    a = jax.random.uniform(jax.random.PRNGKey(0), (b, s, w), dtype,
                           0.5, 0.999)
    bx = jax.random.normal(jax.random.PRNGKey(1), (b, s, w), dtype)
    h0 = jax.random.normal(jax.random.PRNGKey(2), (b, w), dtype)
    got = ops.rglru_scan(a, bx, h0)
    want = ref.rglru_scan_ref(a, bx, h0)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_rglru_carries_initial_state():
    b, s, w = 1, 128, 128
    a = jnp.full((b, s, w), 0.9)
    bx = jnp.zeros((b, s, w))
    h0 = jnp.ones((b, w))
    h = ops.rglru_scan(a, bx, h0)
    np.testing.assert_allclose(np.asarray(h[:, 0]), 0.9, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h[:, -1]),
                               0.9 ** s, rtol=1e-3)


# ---------------------------------------------------------------------------
# segment_trapz: the carbon-integration primitive of the mega-simulator's
# jax backend (fleet/mega/jaxback.py).  Oracle chain: Pallas kernel ==
# jnp reference == CarbonTrace.integral evaluated one segment at a time.
# ---------------------------------------------------------------------------

def _trace_tables(trace):
    kt = np.asarray(trace._kt)
    kv = np.asarray(trace._kv)
    cum = np.asarray(trace._cum)
    return kt, kv, cum


@pytest.mark.parametrize("n", [1, 17, 512, 2001])
@pytest.mark.parametrize("shape_name", ["solar-duck", "wind-night", "flat"])
def test_segment_trapz_sweep(n, shape_name):
    from jax.experimental import enable_x64

    from repro.fleet.carbon import make_trace

    trace = make_trace(shape_name, 0.39)
    kt, kv, cum = _trace_tables(trace)
    rng = np.random.default_rng(n)
    # spans crossing knots, bins, midnight wrap, and multiple periods
    a = np.sort(rng.uniform(0.0, 2.5 * trace.period_s, n))
    b = a + rng.uniform(0.0, 4 * 3600.0, n)
    w = rng.uniform(10.0, 700.0, n)
    want = np.array([trace.integral(x, y) * z for x, y, z in zip(a, b, w)])
    with enable_x64():
        args = [jnp.asarray(x) for x in (a, b, w, kt, kv, cum)]
        got_pl = np.asarray(ops.segment_trapz(
            *args, period=trace.period_s, use_pallas=True))
        got_ref = np.asarray(ops.segment_trapz(
            *args, period=trace.period_s, use_pallas=False))
    np.testing.assert_allclose(got_pl, want, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(got_ref, want, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(got_pl, got_ref, rtol=1e-12, atol=0)


def test_segment_trapz_f32_kernel_matches_ref():
    """TPU-realistic dtype: kernel and reference agree bit-comparably
    in f32 (no f64 on real TPU hardware)."""
    from repro.fleet.carbon import solar_duck

    trace = solar_duck(0.39)
    kt, kv, cum = (x.astype(np.float32) for x in _trace_tables(trace))
    rng = np.random.default_rng(0)
    a = np.sort(rng.uniform(0, 86400.0, 700)).astype(np.float32)
    b = a + np.float32(50.0)
    w = np.full(700, 300.0, np.float32)
    args = [jnp.asarray(x) for x in (a, b, w, kt, kv, cum)]
    got = np.asarray(ops.segment_trapz(*args, period=trace.period_s,
                                       use_pallas=True))
    want = np.asarray(ref.segment_trapz_ref(*args, period=trace.period_s))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_segment_trapz_zero_and_empty_segments():
    from jax.experimental import enable_x64

    from repro.fleet.carbon import solar_duck

    trace = solar_duck(0.39)
    kt, kv, cum = _trace_tables(trace)
    with enable_x64():
        empty = ops.segment_trapz(
            jnp.zeros(0), jnp.zeros(0), jnp.zeros(0),
            jnp.asarray(kt), jnp.asarray(kv), jnp.asarray(cum),
            period=trace.period_s)
        point = ops.segment_trapz(
            jnp.asarray([100.0, 7e4]), jnp.asarray([100.0, 7e4]),
            jnp.asarray([500.0, 500.0]),
            jnp.asarray(kt), jnp.asarray(kv), jnp.asarray(cum),
            period=trace.period_s)
    assert np.asarray(empty).shape == (0,)
    np.testing.assert_allclose(np.asarray(point), 0.0, atol=1e-12)


# ---------------------------------------------------------------------------
# fused_meter: the single-pass metering kernel behind the mega jax
# backend's fused finalize (energy segment-sum + per-tier billed seconds
# + per-trace carbon trapezoid in ONE launch).  Oracle chain: Pallas
# kernel == jnp reference == CarbonTrace.integral per entry, and the
# energy/seconds outputs are BIT-identical to the unfused inputs.
# ---------------------------------------------------------------------------

def _stacked_tables(traces):
    """CarbonTrace knot tables stacked [G, K]: rows padded by repeating
    the last knot (in-period offsets are strictly below the period, so
    the pad never matches a compare)."""
    kmax = max(len(t._kt) for t in traces)
    kt = np.stack([np.concatenate(
        [t._kt, np.full(kmax - len(t._kt), t._kt[-1])]) for t in traces])
    kv = np.stack([np.concatenate(
        [t._kv, np.full(kmax - len(t._kv), t._kv[-1])]) for t in traces])
    cum = np.stack([np.concatenate(
        [t._cum, np.full(kmax - len(t._cum), t._cum[-1])]) for t in traces])
    per = np.array([t.period_s for t in traces])
    return kt, kv, cum, per


@pytest.mark.parametrize("n", [1, 33, 1024, 3001])
@pytest.mark.parametrize("seed", [0, 7])
def test_fused_meter_sweep(n, seed):
    """Multi-trace entries crossing knots, midnight, and whole periods:
    carbon matches the Python integral, energy/seconds are exact
    pass-throughs, fa is the prefix integral at each start."""
    from jax.experimental import enable_x64

    from repro.fleet.carbon import make_trace

    traces = [make_trace(s, 0.39) for s in
              ("solar-duck", "wind-night", "flat")]
    kt, kv, cum, per = _stacked_tables(traces)
    rng = np.random.default_rng(seed)
    a = np.sort(rng.uniform(0.0, 2.5 * 86400.0, n))
    b = a + rng.uniform(0.0, 4 * 3600.0, n)
    dt = b - a
    w = rng.uniform(10.0, 700.0, n)
    g = rng.integers(0, len(traces), n).astype(np.int32)
    want_c = np.array([traces[gi].integral(x, y) * z
                       for gi, x, y, z in zip(g, a, b, w)])
    want_fa = np.array([traces[gi].integral(0.0, x)
                        for gi, x in zip(g, a)])
    with enable_x64():
        args = [jnp.asarray(x) for x in (a, b, dt, w, g, kt, kv, cum, per)]
        got_pl = [np.asarray(o) for o in
                  ops.fused_meter(*args, use_pallas=True)]
        got_ref = [np.asarray(o) for o in
                   ops.fused_meter(*args, use_pallas=False)]
    for pl_o, ref_o in zip(got_pl, got_ref):
        np.testing.assert_allclose(pl_o, ref_o, rtol=1e-12, atol=0)
    e, s, c, fa = got_pl
    # pass-through outputs: exact, not allclose -- the fused finalize's
    # energy segment-sum must be bit-identical to the unfused path
    assert np.array_equal(e, w * dt)
    assert np.array_equal(s, dt)
    np.testing.assert_allclose(c, want_c, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(fa, want_fa, rtol=1e-9, atol=1e-12)


def test_fused_meter_empty_and_zero_width():
    from jax.experimental import enable_x64

    from repro.fleet.carbon import solar_duck

    kt, kv, cum, per = _stacked_tables([solar_duck(0.39)])
    with enable_x64():
        tabs = [jnp.asarray(x) for x in (kt, kv, cum, per)]
        empty = ops.fused_meter(jnp.zeros(0), jnp.zeros(0), jnp.zeros(0),
                                jnp.zeros(0), jnp.zeros(0, jnp.int32),
                                *tabs)
        point = ops.fused_meter(jnp.asarray([7e4]), jnp.asarray([7e4]),
                                jnp.asarray([0.0]), jnp.asarray([500.0]),
                                jnp.zeros(1, jnp.int32), *tabs)
    assert all(np.asarray(o).shape == (0,) for o in empty)
    e, s, c, fa = (np.asarray(o) for o in point)
    assert e[0] == 0.0 and s[0] == 0.0
    np.testing.assert_allclose(c, 0.0, atol=1e-12)
    assert fa[0] > 0.0                      # prefix at 7e4 s into the day


def test_fused_meter_matches_segment_trapz():
    """The fused kernel's carbon lane reproduces the standalone
    segment_trapz kernel on a single-trace workload (same closed form,
    stacked-table indexing vs scalar tables)."""
    from jax.experimental import enable_x64

    from repro.fleet.carbon import make_trace

    trace = make_trace("wind-night", 0.39)
    kt, kv, cum, per = _stacked_tables([trace])
    rng = np.random.default_rng(3)
    n = 777
    a = np.sort(rng.uniform(0.0, 2.0 * trace.period_s, n))
    b = a + rng.uniform(0.0, 7200.0, n)
    w = rng.uniform(50.0, 400.0, n)
    with enable_x64():
        _, _, c, _ = ops.fused_meter(
            jnp.asarray(a), jnp.asarray(b), jnp.asarray(b - a),
            jnp.asarray(w), jnp.zeros(n, jnp.int32),
            *[jnp.asarray(x) for x in (kt, kv, cum, per)])
        flat = ops.segment_trapz(
            jnp.asarray(a), jnp.asarray(b), jnp.asarray(w),
            jnp.asarray(np.asarray(trace._kt)),
            jnp.asarray(np.asarray(trace._kv)),
            jnp.asarray(np.asarray(trace._cum)),
            period=trace.period_s)
    np.testing.assert_allclose(np.asarray(c), np.asarray(flat),
                               rtol=1e-12, atol=0)
