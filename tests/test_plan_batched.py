"""Batched plan_fleet == serial plan_fleet, point for point.

The batched planner's whole contract is that grouping grid points by
structural shape and re-pricing tier variants from one shared
simulation changes NOTHING observable: every PlanPoint's objectives,
cost decomposition, engine label, the frontier, and the hypervolume
must be exactly what the one-simulation-per-point serial sweep
produces.  These tests pin that equivalence -- as a property over
random sub-grids of the pinned axes, and as an explicit full-grid
regression for the shared-trace replay (satellite of the batched
planning PR; see docs/SCALE.md "Batched planning").
"""
import dataclasses

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, st

from repro.fleet.fleetsim import run_fleet
from repro.fleet.planner import (SPOT_ALL_FLEET, SPOT_H100_FLEET,
                                 ZONES3_FLEET, PlanAxes, pinned_day_axes,
                                 pinned_day_base, plan_fleet)

H6 = 6 * 3600.0

# the full pinned-axes coordinate pools the property sub-samples
FLEETS = (ZONES3_FLEET, SPOT_H100_FLEET, SPOT_ALL_FLEET)
ROUTERS = ("warm-first", "slo-aware")
TIERS = ("on_demand", "reserved")
RATES = (0.0, 2.0)

# every PlanPoint field the equivalence must hold EXACTLY on --
# everything except eval_s, which is informational wall-clock
COMPARED = ("fleet", "router", "price_tier", "preemption_rate",
            "cost_usd", "energy_wh", "carbon_kg", "p99_s", "engine",
            "gpu_hours_usd", "energy_usd", "preemptions", "requests")


def _key(p):
    return tuple(getattr(p, f) for f in COMPARED)


def _assert_identical(serial, batched):
    assert len(serial.points) == len(batched.points)
    for a, b in zip(serial.points, batched.points):
        assert _key(a) == _key(b)
    assert ([_key(p) for p in serial.frontier]
            == [_key(p) for p in batched.frontier])
    assert _key(serial.reference) == _key(batched.reference)
    assert serial.hypervolume == batched.hypervolume


_BASE6 = None


def _base6():
    """The 6 h pinned day, built once per test run (the property and
    the regressions all sweep the same base workload)."""
    global _BASE6
    if _BASE6 is None:
        _BASE6 = pinned_day_base(horizon_s=H6)
    return _BASE6


@pytest.fixture(scope="module")
def base6():
    return _base6()


class TestBatchedEqualsSerial:

    @settings(max_examples=5)
    @given(nf=st.integers(min_value=1, max_value=3),
           nr=st.integers(min_value=1, max_value=2),
           nt=st.integers(min_value=1, max_value=2),
           with_faults=st.booleans(),
           reverse=st.booleans())
    def test_random_subgrid_property(self, nf, nr, nt,
                                     with_faults, reverse):
        """Batched == serial on arbitrary sub-grids of the pinned axes:
        same points in the same order, same decompositions, same
        frontier, same hypervolume.  ``reverse`` flips the fleet axis
        so the reference fallback path (grid without the all-on-demand
        corner first) is exercised too."""
        fleets = FLEETS[:nf][::-1] if reverse else FLEETS[:nf]
        axes = PlanAxes(fleets=fleets, routers=ROUTERS[:nr],
                        price_tiers=TIERS[:nt],
                        preemption_rates=RATES if with_faults else (0.0,))
        serial = plan_fleet(_base6(), axes, backend="numpy", batched=False)
        batched = plan_fleet(_base6(), axes, backend="numpy", batched=True)
        _assert_identical(serial, batched)

    def test_full_pinned_grid_shared_trace_replay(self, base6):
        """The explicit regression for hoisted trace generation: the
        full pinned sweep runs FEWER simulations than it has points
        (tier variants replay their group's shared run) and still
        reproduces the serial sweep bit for bit."""
        axes = pinned_day_axes()
        serial = plan_fleet(base6, axes, backend="numpy", batched=False)
        batched = plan_fleet(base6, axes, backend="numpy", batched=True)
        _assert_identical(serial, batched)
        assert batched.stats["sims"] < batched.stats["points"]
        assert serial.stats["sims"] == serial.stats["points"] == 20
        # exact float equality, not approx: tier variants re-price the
        # primary's metered reports, which is the SAME arithmetic the
        # serial engines run
        for a, b in zip(serial.points, batched.points):
            assert a.cost_usd == b.cost_usd
            assert a.energy_wh == b.energy_wh
            assert a.carbon_kg == b.carbon_kg

    def test_engine_labels_match_serial_dispatch(self, base6):
        """Grouping must not change WHICH engine a point reports:
        fault-free warm-first plans ride mega, preemption draws and
        stateful routers ride the event loop, and tier variants carry
        their group primary's engine."""
        sweep = plan_fleet(base6, pinned_day_axes(), backend="numpy",
                           batched=True)
        for p in sweep.points:
            if p.preemption_rate > 0 or p.router != "warm-first":
                assert p.engine == "fleet", p.label()
            else:
                assert p.engine == "mega-numpy", p.label()

    def test_stats_shape(self, base6):
        axes = PlanAxes(fleets=(ZONES3_FLEET,), routers=("warm-first",),
                        price_tiers=TIERS)
        res = plan_fleet(base6, axes, backend="numpy", batched=True)
        st_ = res.stats
        assert st_["mode"] == "batched"
        assert st_["points"] == 2 and st_["sims"] == 1
        assert st_["wall_s"] > 0.0
        assert isinstance(st_["compiles"], int)
        # the primary carries the wall share; the replayed tier variant
        # ran no simulation of its own
        assert res.points[0].eval_s > 0.0
        assert res.points[1].eval_s == 0.0


class TestDetailFlagInvariance:
    """run_fleet's detail=False fast path (no replica logging, no
    timeline assembly) must not perturb any field the planner reads."""

    def test_detail_false_same_plan_fields(self, base6):
        full = run_fleet(base6)
        fast = run_fleet(base6, compute_bound=False, detail=False)
        for f in ("cost_usd", "energy_wh", "carbon_kg",
                  "p99_added_latency_s", "gpu_hours_usd", "energy_usd",
                  "preemptions", "requests"):
            assert getattr(full, f) == getattr(fast, f), f
        assert full.tier_billed_s == fast.tier_billed_s
        # and the fast path really did skip the detail work
        assert fast.carbon_timeline == []
        assert all(log == [] for log in fast.replica_timeline.values())
        assert full.carbon_timeline
        assert any(full.replica_timeline.values())
