"""Device power-state machine + sleep/wake gating (ISSUE 5).

Four layers:
  * machine unit tests -- transition-table completeness, illegal
    transitions raise (in the machine, the meter, and the lifecycle
    layer), per-state power formula.
  * EnergyMeter accounting -- wake-energy bookkeeping, the totals()
    flush contract vs the non-mutating peek_totals(), gated_wh_saved.
  * hand-checked single-device gating end-to-end (every interval of the
    timeline priced by hand to 1e-9 Wh).
  * property/invariant suite -- gating never increases energy on an
    empty device, a gated fleet stays under the always-on baseline,
    the equivalence anchors survive with gating enabled-but-idle, and
    the pinned 10x6 / seed-100 acceptance: gated total Wh strictly
    below the best non-gated policy at p99 within the SLO budget.
"""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, st

from repro.core import (A100, H100, L40S, PROFILES, QWEN25_7B_MEASURED,
                        traffic)
from repro.core.power_states import (IllegalPowerTransition,
                                     LEGAL_TRANSITIONS, PowerState,
                                     PowerStateMachine, TransitionModel,
                                     can_transition, gate_breakeven_s,
                                     state_power_w, wake_penalty_j)
from repro.core.scheduler import AlwaysOn, Breakeven, FixedTTL
from repro.core.simulator import simulate
from repro.fleet import (Consolidator, FleetModel, FleetModelSpec,
                         FleetScenario, SLOAwareRouter, build_fleet,
                         mixed_fleet_scenario, run_fleet,
                         single_device_scenario, wake_cost_j)
from repro.serving import EnergyMeter, ModelManager, RooflineServiceTime, \
    SimClock

GB = 1024 ** 3
DAY = 24 * 3600.0


# ---------------------------------------------------------------------------
# the machine itself
# ---------------------------------------------------------------------------

def test_transition_table_complete_and_well_formed():
    """Every state has a row, every target is a real state, self-loops
    are implicit, and BARE is the hub: reachable FROM every state (so
    any device can always be brought back to a safe floor) and the only
    way INTO the gated states."""
    assert set(LEGAL_TRANSITIONS) == set(PowerState)
    for src, dsts in LEGAL_TRANSITIONS.items():
        assert dsts <= set(PowerState)
        assert src not in dsts                   # self-loops are implicit
        assert can_transition(src, src)
    for src in PowerState:
        if src is not PowerState.BARE:
            assert can_transition(src, PowerState.BARE) or \
                PowerState.BARE in {
                    d for d in LEGAL_TRANSITIONS[src]}, src
    # the gated states only connect through BARE
    for src in (PowerState.CTX_IDLE, PowerState.LOADING, PowerState.ACTIVE):
        assert not can_transition(src, PowerState.SLEEP)
        assert not can_transition(src, PowerState.OFF)


def test_legacy_string_names_are_the_wire_format():
    """The str-enum values are the historical meter/report keys, so the
    typed refactor changes no bench rows or pinned dict keys."""
    assert PowerState.CTX_IDLE.value == "parked"
    assert PowerState.coerce("parked") is PowerState.CTX_IDLE
    assert PowerState.BARE == "bare"             # str-enum equality
    with pytest.raises(ValueError, match="unknown power state"):
        PowerState.coerce("warm")


def test_illegal_transitions_raise_and_do_not_mutate():
    m = PowerStateMachine(PowerState.SLEEP, 0.0)
    for bad in (PowerState.ACTIVE, PowerState.LOADING, PowerState.CTX_IDLE):
        with pytest.raises(IllegalPowerTransition):
            m.to(bad, 1.0)
        assert m.state is PowerState.SLEEP       # unchanged on raise
    assert m.to(PowerState.BARE, 2.0)            # the legal wake edge
    assert m.entered_at_s == 2.0
    with pytest.raises(IllegalPowerTransition):
        PowerStateMachine(PowerState.OFF).to(PowerState.ACTIVE, 0.0)


def test_self_loop_does_not_reset_entry_time():
    """Re-settling into the current state keeps the state clock running
    -- this is the bare-idle clock the gating ski rental measures."""
    m = PowerStateMachine(PowerState.BARE, 10.0)
    assert not m.to(PowerState.BARE, 50.0)
    assert m.entered_at_s == 10.0
    assert m.time_in_state_s(60.0) == 50.0


def test_state_power_formula():
    for prof in PROFILES.values():
        assert state_power_w(prof, PowerState.OFF) == 0.0
        assert 0.0 < state_power_w(prof, PowerState.SLEEP) \
            < state_power_w(prof, PowerState.BARE) \
            < state_power_w(prof, PowerState.CTX_IDLE) \
            < state_power_w(prof, PowerState.ACTIVE)
    # LOADING: loader-specific when a LoaderSpec applies, the SKU's own
    # p_load_w otherwise (the field that replaced `p_base_w + 30.0`)
    assert state_power_w(H100, "loading", QWEN25_7B_MEASURED) == \
        QWEN25_7B_MEASURED.p_load_w
    assert state_power_w(H100, "loading") == H100.p_load_w
    assert H100.load_power_w() == H100.p_load_w == 124.1


def test_gate_breakeven_is_device_level_ski_rental():
    """T*_gate = (E_wake - P_base t_wake) / (P_base - P_sleep): at a
    bare-idle gap of exactly T*_gate, sleeping and staying bare cost the
    same; beyond it sleeping wins linearly."""
    for prof in (H100, A100, L40S):
        t_gate = gate_breakeven_s(prof)
        tm = TransitionModel.for_profile(prof)
        bare_j = prof.p_base_w * t_gate
        sleep_j = tm.p_sleep_w * t_gate + tm.wake_extra_j(prof.p_base_w)
        assert bare_j == pytest.approx(sleep_j, rel=1e-12)
        assert 10.0 < t_gate < 120.0             # engineering-estimate band
    # a profile whose sleep saves nothing never gates
    import dataclasses
    lazy = dataclasses.replace(H100, p_sleep_w=H100.p_base_w)
    assert gate_breakeven_s(lazy) == math.inf


def test_wake_penalty_prices_ramp_plus_hold():
    dev = build_fleet("h100")[0]
    tm = TransitionModel.for_profile(H100)
    assert wake_cost_j(dev, 0.0) == pytest.approx(
        tm.wake_energy_j - tm.p_sleep_w * tm.wake_s)
    assert wake_cost_j(dev, 600.0) - wake_cost_j(dev, 0.0) == pytest.approx(
        (H100.p_base_w - H100.p_sleep_w) * 600.0)
    assert wake_penalty_j(H100, 60.0) == wake_cost_j(dev, 60.0)


# ---------------------------------------------------------------------------
# EnergyMeter on the machine
# ---------------------------------------------------------------------------

def test_meter_rejects_illegal_transitions():
    clk = SimClock()
    m = EnergyMeter(H100, clk)
    m.gate()                                     # bare -> sleep is legal
    clk.advance(100.0)
    with pytest.raises(IllegalPowerTransition):
        m.transition("active")                   # serve while gated
    with pytest.raises(IllegalPowerTransition):
        m.transition(PowerState.LOADING)         # load while gated
    # nothing was charged by the failed transitions
    assert m.peek_totals()["sleep"] == pytest.approx(
        H100.p_sleep_w * 100.0 / 3600.0)
    # gating is only legal from SETTLED bare: mid-wake (bare with the
    # ramp's composed override) must refuse
    m.begin_wake()
    with pytest.raises(IllegalPowerTransition):
        m.gate()                                 # mid-wake (override set)


def test_lifecycle_layer_raises_on_gated_device():
    """ModelManager.begin_load on a sleeping device raises through the
    machine instead of silently metering load watts below the floor."""
    mm = ModelManager(H100, clock=SimClock())
    mm.register("m", policy=AlwaysOn(), loader=QWEN25_7B_MEASURED)
    mm.meter.gate()
    with pytest.raises(IllegalPowerTransition):
        mm.begin_load("m")


def test_meter_wake_energy_accounting():
    """gate -> sleep S seconds -> wake: the sleep bucket meters the
    floor, the wake ramp meters exactly wake_energy_j (as 'bare' at the
    ramp's mean power), and gated_wh_saved is the hand formula."""
    clk = SimClock()
    m = EnergyMeter(H100, clk)
    clk.advance(50.0)                            # 50 s bare
    m.gate()
    clk.advance(1000.0)                          # 1000 s asleep
    dt = m.begin_wake()
    assert dt == H100.wake_latency_s
    clk.advance(dt)
    m.finish_wake()
    wh = m.totals()
    assert wh["sleep"] == pytest.approx(H100.p_sleep_w * 1000.0 / 3600.0)
    # bare = 50 s plain + the ramp's wake_energy_j
    assert wh["bare"] == pytest.approx(
        (H100.p_base_w * 50.0 + H100.wake_energy_j) / 3600.0)
    assert m.wakes == 1
    tm = TransitionModel.for_profile(H100)
    expect_saved = ((H100.p_base_w - H100.p_sleep_w) * 1000.0
                    - tm.wake_extra_j(H100.p_base_w)) / 3600.0
    assert m.gated_wh_saved() == pytest.approx(expect_saved)


def test_sleep_wake_round_trip_conserves_energy_at_breakeven():
    """A gap of exactly T*_gate costs the same slept as bare (the ski
    rental's indifference point); a longer gap is strictly cheaper
    slept, a shorter one strictly dearer."""
    t_gate = gate_breakeven_s(H100)

    def cycle_wh(gap_s: float, gated: bool) -> float:
        clk = SimClock()
        m = EnergyMeter(H100, clk)
        if gated:
            m.gate()
            clk.advance(gap_s)
            clk.advance(m.begin_wake())
            m.finish_wake()
        else:
            clk.advance(gap_s + H100.wake_latency_s)
        return m.totals()["total"]

    assert cycle_wh(t_gate, True) == pytest.approx(cycle_wh(t_gate, False),
                                                   abs=1e-9)
    assert cycle_wh(4 * t_gate, True) < cycle_wh(4 * t_gate, False)
    assert cycle_wh(t_gate / 4, True) > cycle_wh(t_gate / 4, False)


def test_totals_flush_contract_and_peek():
    """totals() flushes (documented mutation) but is double-call safe
    and preserves state + override; peek_totals() is a pure read."""
    clk = SimClock()
    m = EnergyMeter(H100, clk)
    clk.advance(3600.0)
    first = m.totals()
    n_timeline = len(m.timeline)
    again = m.totals()                           # same instant: no drift
    assert again == first
    assert len(m.timeline) == n_timeline         # zero-width not appended
    clk.advance(1800.0)
    peek = m.peek_totals()
    assert peek["bare"] == pytest.approx(H100.p_base_w * 1.5 / 3600.0 * 3600)
    assert len(m.timeline) == n_timeline         # peek did not flush
    assert m.peek_totals() == peek               # idempotent
    assert m.totals()["bare"] == pytest.approx(peek["bare"])
    # flush mid-burst preserves the composed override
    m.transition("parked")
    m.transition("active", power_override_w=500.0)
    clk.advance(10.0)
    m.totals()
    assert m.power_override_w == 500.0
    clk.advance(10.0)
    assert m.totals()["active"] == pytest.approx(500.0 * 20.0 / 3600.0)


# ---------------------------------------------------------------------------
# hand-checked single-device gating end-to-end
# ---------------------------------------------------------------------------

def test_single_device_gating_timeline_by_hand():
    """One model, TTL 60 s, one arrival at t=5000 into a 7200 s horizon
    with a 100 s gating tick: prewarm -> evict(60) -> gate(100) ->
    sleep -> wake+reload at the arrival -> evict(5100) -> gate(5200) ->
    sleep to the horizon.  Every interval priced by hand."""
    devices = build_fleet("h100")
    spec = FleetModelSpec("m", lambda: FixedTTL(60.0),
                          loader=QWEN25_7B_MEASURED, vram_gb=10.0,
                          home="h100-0")
    sc = FleetScenario(
        devices=devices, models=[FleetModel(spec, [5000.0])],
        horizon_s=7200.0,
        consolidator=Consolidator(period_s=100.0,
                                  gate_drained_devices=True))
    res = run_fleet(sc)
    ld = QWEN25_7B_MEASURED
    expected = (H100.p_ctx_w * 60.0              # prewarmed, TTL armed
                + H100.p_base_w * 40.0           # bare until the 100 s tick
                + H100.p_sleep_w * 4900.0        # gated through the lull
                + H100.wake_energy_j             # wake ramp at t=5000
                + ld.p_load_w * ld.t_load_s      # reload
                + H100.p_ctx_w * 60.0            # parked until TTL
                + H100.p_base_w * 100.0          # bare until the 5200 tick
                + H100.p_sleep_w * 2000.0        # gated to the horizon
                ) / 3600.0
    assert res.energy_wh == pytest.approx(expected, abs=1e-9)
    assert res.gates == 2 and res.wakes == 1
    assert res.cold_starts == 2                  # prewarm + the reload
    # the request waited the wake ramp plus its own load
    assert res.added_latency_s_total == pytest.approx(
        H100.wake_latency_s + ld.t_load_s, abs=1e-9)
    assert res.state_durations_s["sleep"] == pytest.approx(6900.0)
    by_state = res.state_energy_wh
    assert sum(by_state.values()) == pytest.approx(res.energy_wh, rel=1e-12)
    tm = TransitionModel.for_profile(H100)
    assert res.gated_wh_saved == pytest.approx(
        ((H100.p_base_w - H100.p_sleep_w) * 6900.0
         - tm.wake_extra_j(H100.p_base_w)) / 3600.0, abs=1e-9)
    assert res.devices[0].meter_state == "sleep"


# ---------------------------------------------------------------------------
# invariants (property suite)
# ---------------------------------------------------------------------------

def _gated_consolidator() -> Consolidator:
    return Consolidator(period_s=300.0, gate_drained_devices=True)


@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_gating_never_increases_energy_on_an_empty_device(seed):
    """An extra device nobody routes to costs at most its bare-idle
    floor without gating; WITH gating it costs strictly less (it sleeps
    out the horizon), and the served workload's joules are untouched."""
    arr = traffic.PATTERNS["diurnal"](seed=seed)
    arr = arr[arr < 6 * 3600.0]
    devices = build_fleet("h100+a100")

    def scenario(consolidator):
        spec = FleetModelSpec("m", AlwaysOn, checkpoint_bytes=8 * GB,
                              vram_gb=9.0, home="h100-0")
        return FleetScenario(devices=build_fleet("h100+a100"),
                             models=[FleetModel(spec, arr)],
                             horizon_s=6 * 3600.0,
                             consolidator=consolidator)

    plain = run_fleet(scenario(None))
    gated = run_fleet(scenario(_gated_consolidator()))
    stranger = {d.instance_id: d for d in gated.devices}["a100-0"]
    assert gated.energy_wh <= plain.energy_wh + 1e-9
    assert stranger.wakes == 0
    assert stranger.durations_s.get("sleep", 0.0) > 0.0
    assert gated.gated_wh_saved > 0.0
    assert gated.energy_wh == pytest.approx(
        plain.energy_wh - gated.gated_wh_saved, abs=1e-6)


@given(st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_gated_fleet_never_exceeds_always_on(seed):
    """Gated breakeven scheduling stays under the always-on
    warm-everywhere baseline: gating only removes standing power, and
    every wake it buys is priced against that saving."""
    kw = dict(n_models=3, fleet="h100+a100+l40s", horizon_s=6 * 3600.0,
              seed=seed)
    base = run_fleet(mixed_fleet_scenario(AlwaysOn, "warm-first", **kw))
    gated = run_fleet(mixed_fleet_scenario(
        Breakeven, "energy-greedy", consolidate=_gated_consolidator(),
        **kw))
    assert gated.energy_wh <= base.energy_wh + 1e-9
    assert gated.requests == base.requests


@given(st.integers(0, 10_000))
@settings(max_examples=4, deadline=None)
def test_equivalence_anchor_survives_gating_enabled_but_idle(seed):
    """1 device x 1 model with an always-on policy never drains, so a
    gating-enabled consolidator never fires and run_fleet still
    reproduces core/simulator.py to 1e-6 Wh -- the anchor contract for
    the new layer (the path switched on but fed degenerate inputs must
    equal the old path exactly)."""
    arr = traffic.PATTERNS["bursty"](seed=seed)
    sim = simulate(arr, AlwaysOn(), H100, QWEN25_7B_MEASURED)
    sc = single_device_scenario(arr, AlwaysOn, QWEN25_7B_MEASURED, "h100")
    sc.consolidator = _gated_consolidator()
    res = run_fleet(sc)
    assert res.energy_wh == pytest.approx(sim.energy_wh, abs=1e-6)
    assert res.cold_starts == sim.cold_starts
    assert res.gates == 0 and res.wakes == 0
    assert res.gated_wh_saved == 0.0


# ---------------------------------------------------------------------------
# acceptance: the pinned 10x6 day
# ---------------------------------------------------------------------------

def test_gating_opens_the_bare_idle_floor_pinned_day():
    """Acceptance (ISSUE 5): on the 10-model x 6-GPU day (seed 100) with
    roofline service times, SLO-aware routing + a gating consolidator
    lands total Wh STRICTLY below the best non-gated policy -- below
    even the non-gated clairvoyant bound, because gating is the first
    mechanism that cuts under p_base -- while holding p99 inside the
    90 s budget.  (Measured: 4240 vs 8430 Wh, p99 83.0 s, 127 gates /
    122 wakes, ~4235 Wh recovered from the bare-idle floor.)"""
    svc = RooflineServiceTime()
    kw = dict(service_model=svc, seed=100)
    best_nongated = run_fleet(mixed_fleet_scenario(
        Breakeven, "energy-greedy", consolidate=True, **kw))
    gated = run_fleet(mixed_fleet_scenario(
        Breakeven, SLOAwareRouter(90.0),
        consolidate=_gated_consolidator(), **kw))
    assert gated.energy_wh < best_nongated.energy_wh
    assert gated.p99_added_latency_s <= 90.0
    # below even the NON-GATED clairvoyant floor (which is exactly why
    # the field is scoped: gating is allowed to undercut it)
    assert gated.energy_wh < best_nongated.lb_nongated_wh
    assert gated.gates > 0 and gated.wakes > 0
    assert gated.gated_wh_saved > 1000.0
    # measured band, pinned loosely enough to survive float churn
    assert 0.40 <= gated.energy_wh / best_nongated.energy_wh <= 0.65
    assert gated.state_durations_s["sleep"] > 50 * 3600.0
