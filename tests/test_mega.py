"""Equivalence anchors + scope guards for the vectorized mega simulator.

The correctness spine of `fleet/mega/megasim.py` is a single claim: on
its supported scope, `run_mega` IS `run_fleet` -- same routing, same
evictions, same joules -- just re-expressed as an array program.  This
file pins that claim the way every other layer pins its anchor
(docs/ARCHITECTURE.md, "The equivalence-anchor contract"):

* the pinned 10-model x 6-GPU seed-100 day matches the event loop
  **bit-for-bit** on fleet totals (the ISSUE acceptance asks for 1e-3
  relative; we hold 0.0) and to <=1e-9 relative on every per-device
  bucket (the event loop's `Cluster.advance_to` steps its clock by
  float *deltas*, so its absolute times carry ~1-ulp accumulated drift
  that megasim, which uses exact event times, does not reproduce);
* unsupported scenarios refuse loudly (`MegaUnsupportedError`), never
  silently approximate;
* a 500-device x 100k-request day completes, conserves requests, and
  meters non-negative energy;
* the trace generators are seed-deterministic (same seed => the
  bit-identical trace) and round-trip through the record schema --
  as does the streaming JSON-Lines form (``FleetTrace.to_jsonl``);
* the compiled backend (``run_mega(backend="jax")``) matches the numpy
  anchor on fleet totals to <=1e-9 relative (and bit-for-bit on
  requests, cold starts, power timeline, and the fsum'd latency total)
  across the pinned day, generated days, and a property sweep of
  random seeds x policies x generators;
* the big-gap cache reuses derived stream arrays across runs on the
  same trace and stays within its bounds.
"""
import dataclasses
import json
import math
import pathlib
import sys

import numpy as np
import pytest

from repro.core.scheduler import (AdaptiveBreakeven, AlwaysOn, Breakeven,
                                  Clairvoyant, FixedTTL)
from repro.fleet import (CarbonBreakeven, FleetTrace, MegaUnsupportedError,
                         ReplicaAutoscaler, flash_crowd, make_trace,
                         mixed_fleet_scenario, product_launch,
                         regional_outage, run_fleet, run_mega, solar_duck,
                         trace_from_records)
from repro.fleet.mega import GENERATORS
from repro.fleet.mega.megasim import _BigGapCache, biggap_cache

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, st

DATA = pathlib.Path(__file__).parent / "data"

# pinned seed and the cross-engine tolerance live in conftest.py
# (shared with test_zones / test_pricing)
from conftest import PIN_SEED, REL


def _ttl300():
    return FixedTTL(300.0)


def _pair(policy, **kw):
    """Run the same scenario through both simulators (scenarios hold
    mutable per-run state, so each gets a fresh one)."""
    ref = run_fleet(mixed_fleet_scenario(policy, "warm-first", **kw))
    got = run_mega(mixed_fleet_scenario(policy, "warm-first", **kw))
    return ref, got


class TestEquivalenceAnchor:
    """run_mega == run_fleet on the pinned 10-model x 6-GPU day."""

    def test_pinned_day_bit_exact_fleet_totals(self):
        ref, got = _pair(Breakeven, seed=PIN_SEED)
        assert got.requests == ref.requests
        assert got.cold_starts == ref.cold_starts
        assert got.energy_wh == ref.energy_wh            # bit-for-bit
        assert got.parking_tax_wh == ref.parking_tax_wh
        assert got.carbon_kg == ref.carbon_kg
        # per-state aggregates sum the per-device buckets, which carry the
        # event loop's ~1-ulp clock drift (see module docstring)
        for k in ref.state_energy_wh:
            assert got.state_energy_wh[k] == pytest.approx(
                ref.state_energy_wh[k], rel=1e-12)
        for k in ref.state_durations_s:
            assert got.state_durations_s[k] == pytest.approx(
                ref.state_durations_s[k], rel=1e-12)
        assert got.power_timeline == ref.power_timeline  # same segments
        assert got.replica_timeline == ref.replica_timeline
        assert got.lb_nongated_wh == ref.lb_nongated_wh
        assert got.cv_per_model_wh == ref.cv_per_model_wh
        assert got.infra_usd == ref.infra_usd
        assert got.energy_usd == ref.energy_usd
        assert got.carbon_timeline == ref.carbon_timeline

    @pytest.mark.parametrize("policy", [Breakeven, AlwaysOn, _ttl300,
                                        CarbonBreakeven],
                             ids=["breakeven", "always-on", "ttl-300",
                                  "carbon-breakeven"])
    def test_per_device_reports_match(self, policy):
        ref, got = _pair(policy, seed=PIN_SEED)
        assert got.requests == ref.requests
        assert got.cold_starts == ref.cold_starts
        assert got.energy_wh == pytest.approx(ref.energy_wh, rel=REL)
        for rd, gd in zip(ref.devices, got.devices):
            assert gd.instance_id == rd.instance_id
            assert gd.cold_starts == rd.cold_starts
            assert gd.requests == rd.requests
            assert gd.meter_state == rd.meter_state
            assert gd.resident == rd.resident
            assert list(gd.energy_wh) == list(rd.energy_wh)  # key order too
            for k in rd.energy_wh:
                assert gd.energy_wh[k] == pytest.approx(
                    rd.energy_wh[k], rel=REL, abs=1e-9)
            for k in rd.durations_s:
                assert gd.durations_s[k] == pytest.approx(
                    rd.durations_s[k], rel=REL, abs=1e-6)

    def test_latency_multiset_matches(self):
        ref, got = _pair(Breakeven, seed=PIN_SEED)
        assert len(got.latencies_s) == len(ref.latencies_s)
        assert np.allclose(np.asarray(got.latencies_s),
                           np.asarray(ref.latencies_s), rtol=0, atol=1e-9)
        assert got.p99_added_latency_s == pytest.approx(
            ref.p99_added_latency_s, abs=1e-9)

    @pytest.mark.parametrize("seed", [7, 42, 2024])
    def test_other_seeds_match(self, seed):
        ref, got = _pair(Breakeven, seed=seed)
        assert got.requests == ref.requests
        assert got.cold_starts == ref.cold_starts
        assert got.energy_wh == pytest.approx(ref.energy_wh, rel=REL)

    def test_generated_trace_day_matches_event_loop(self):
        tr = flash_crowd(n_routes=4, fleet="h100+a100+l40s",
                         horizon_s=4 * 3600.0, seed=PIN_SEED)
        ref = run_fleet(tr.to_scenario(Breakeven))
        got = run_mega(tr.to_scenario(Breakeven))
        assert got.requests == ref.requests == tr.requests
        assert got.cold_starts == ref.cold_starts
        assert got.energy_wh == pytest.approx(ref.energy_wh, rel=REL)


class TestScopeGuards:
    """Out-of-scope scenarios refuse loudly instead of approximating."""

    def test_non_warm_first_router_rejected(self):
        with pytest.raises(MegaUnsupportedError, match="warm-first"):
            run_mega(mixed_fleet_scenario(Breakeven, "least-loaded",
                                          seed=PIN_SEED))

    def test_stateful_policy_rejected(self):
        with pytest.raises(MegaUnsupportedError, match="adapts"):
            run_mega(mixed_fleet_scenario(AdaptiveBreakeven, "warm-first",
                                          seed=PIN_SEED))

    def test_clairvoyant_policy_rejected(self):
        with pytest.raises(MegaUnsupportedError):
            run_mega(mixed_fleet_scenario(Clairvoyant, "warm-first",
                                          seed=PIN_SEED))

    def test_nonzero_service_time_rejected(self):
        sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED)
        with pytest.raises(MegaUnsupportedError, match="service"):
            run_mega(dataclasses.replace(sc, service_s=2.0))

    def test_autoscaler_rejected(self):
        sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED)
        with pytest.raises(MegaUnsupportedError, match="autoscal"):
            run_mega(dataclasses.replace(sc,
                                         autoscaler=ReplicaAutoscaler()))

    def test_carbon_breakeven_on_shaped_trace_rejected(self):
        # flat trace => constant T*, supported (anchored above); a shaped
        # trace makes the timeout time-varying, which the probe must catch
        sc = mixed_fleet_scenario(CarbonBreakeven, "warm-first", seed=PIN_SEED,
                                  carbon_trace=solar_duck(0.4))
        with pytest.raises(MegaUnsupportedError, match="varies"):
            run_mega(sc)


class TestScale:
    """The point of the subsystem: mega days in interactive time."""

    def test_500_devices_100k_requests(self):
        tr = flash_crowd(n_routes=500,
                         fleet="170xh100+170xa100+160xl40s",
                         seed=PIN_SEED, base_rate_hr=18.0, spike_x=30.0)
        assert tr.requests > 100_000
        res = run_mega(tr.to_scenario(Breakeven), compute_bound=False)
        assert res.requests == tr.requests          # conservation
        assert len(res.devices) == 500
        assert res.energy_wh > 0.0
        assert all(v >= 0.0 for v in res.state_energy_wh.values())
        assert all(v >= 0.0 for d in res.devices
                   for v in d.energy_wh.values())
        # every device's meter covers the same shared-clock span, which
        # is the horizon plus any load still in flight at day end (the
        # event loop's final advance_to(max(horizon, clock)) semantics)
        spans = [sum(d.durations_s.values()) for d in res.devices]
        assert min(spans) == pytest.approx(max(spans), rel=1e-9)
        assert min(spans) >= tr.horizon_s - 1e-6


class TestGenerators:
    """Seed discipline + schema round-trip for the synthetic days."""

    @pytest.mark.parametrize("gen", [flash_crowd, product_launch,
                                     regional_outage],
                             ids=["flash-crowd", "product-launch",
                                  "regional-outage"])
    def test_same_seed_bit_identical(self, gen):
        a, b = gen(seed=PIN_SEED), gen(seed=PIN_SEED)
        assert [r.route_id for r in a.routes] == \
               [r.route_id for r in b.routes]
        for ra, rb in zip(a.routes, b.routes):
            assert np.array_equal(ra.arrivals_s, rb.arrivals_s)
            assert ra.checkpoint_gb == rb.checkpoint_gb

    @pytest.mark.parametrize("gen", [flash_crowd, product_launch,
                                     regional_outage],
                             ids=["flash-crowd", "product-launch",
                                  "regional-outage"])
    def test_different_seed_differs(self, gen):
        a, b = gen(seed=PIN_SEED), gen(seed=101)
        assert any(not np.array_equal(ra.arrivals_s, rb.arrivals_s)
                   for ra, rb in zip(a.routes, b.routes))

    @pytest.mark.parametrize("gen", [flash_crowd, product_launch,
                                     regional_outage],
                             ids=["flash-crowd", "product-launch",
                                  "regional-outage"])
    def test_records_round_trip(self, gen):
        tr = gen(seed=PIN_SEED)
        back = trace_from_records(tr.to_records())
        assert back.name == tr.name and back.fleet == tr.fleet
        assert back.horizon_s == tr.horizon_s and back.seed == tr.seed
        for ra, rb in zip(tr.routes, back.routes):
            assert ra.route_id == rb.route_id
            assert ra.checkpoint_gb == rb.checkpoint_gb
            assert np.array_equal(ra.arrivals_s, rb.arrivals_s)

    def test_records_reject_unknown_route(self):
        rec = flash_crowd(seed=PIN_SEED).to_records()
        rec["events"].append({"t_s": 1.0, "route": "ghost"})
        with pytest.raises(ValueError, match="unknown route"):
            trace_from_records(rec)


class TestJsonl:
    """Streaming JSON-Lines ingestion: lossless both ways."""

    def test_fixture_round_trip(self, tmp_path):
        tr = FleetTrace.from_jsonl(DATA / "mini_day.jsonl")
        assert tr.name == "flash-crowd" and tr.seed == 17
        assert tr.requests > 0
        out = tmp_path / "again.jsonl"
        tr.to_jsonl(out)
        assert out.read_text() == (DATA / "mini_day.jsonl").read_text()

    def test_generated_round_trip_lossless(self, tmp_path):
        tr = flash_crowd(n_routes=3, fleet="1xh100+1xl40s", seed=17,
                         base_rate_hr=2.0, spike_x=8.0)
        p = tmp_path / "day.jsonl"
        tr.to_jsonl(p)
        back = FleetTrace.from_jsonl(p)
        assert back.name == tr.name and back.fleet == tr.fleet
        assert back.horizon_s == tr.horizon_s and back.seed == tr.seed
        for ra, rb in zip(tr.routes, back.routes):
            assert ra.route_id == rb.route_id
            assert ra.checkpoint_gb == rb.checkpoint_gb
            assert np.array_equal(ra.arrivals_s, rb.arrivals_s)
        assert back.to_records() == tr.to_records()

    def test_rejects_unknown_route_with_line_number(self, tmp_path):
        p = tmp_path / "bad.jsonl"
        flash_crowd(n_routes=2, seed=17, base_rate_hr=1.0).to_jsonl(p)
        with open(p, "a", encoding="utf-8") as fh:
            fh.write('{"t_s": 1.0, "route": "ghost"}\n')
        with pytest.raises(ValueError, match="unknown route"):
            FleetTrace.from_jsonl(p)

    def test_rejects_empty_file(self, tmp_path):
        p = tmp_path / "empty.jsonl"
        p.write_text("")
        with pytest.raises(ValueError, match="empty"):
            FleetTrace.from_jsonl(p)

    def test_missing_t_s_not_misreported_as_unknown_route(self, tmp_path):
        # regression: the event-parsing try block used to span the whole
        # row, so the KeyError from a missing "t_s" was swallowed by the
        # unknown-route handler and reported as "unknown route 'r0'"
        p = tmp_path / "bad.jsonl"
        flash_crowd(n_routes=2, seed=17, base_rate_hr=1.0).to_jsonl(p)
        with open(p, "a", encoding="utf-8") as fh:
            fh.write('{"route": "r0"}\n')
        n_lines = sum(1 for _ in open(p, encoding="utf-8"))
        with pytest.raises(ValueError,
                           match=rf":{n_lines}: event missing 't_s'") as ei:
            FleetTrace.from_jsonl(p)
        assert "unknown route" not in str(ei.value)

    def test_malformed_t_s_reports_line_number(self, tmp_path):
        p = tmp_path / "bad.jsonl"
        flash_crowd(n_routes=2, seed=17, base_rate_hr=1.0).to_jsonl(p)
        with open(p, "a", encoding="utf-8") as fh:
            fh.write('{"t_s": "noonish", "route": "r0"}\n')
        n_lines = sum(1 for _ in open(p, encoding="utf-8"))
        with pytest.raises(ValueError,
                           match=rf":{n_lines}: malformed 't_s'"):
            FleetTrace.from_jsonl(p)

    def test_rejects_duplicate_route_id_in_header(self, tmp_path):
        # regression: duplicate header route ids used to silently
        # collapse into one bucket (last checkpoint wins, events merged)
        hdr = {"name": "dup", "fleet": "h100", "horizon_s": 100.0,
               "seed": None,
               "routes": [{"route": "r0", "checkpoint_gb": 4.0},
                          {"route": "r0", "checkpoint_gb": 9.0}]}
        p = tmp_path / "dup.jsonl"
        p.write_text(json.dumps(hdr) + "\n"
                     + '{"t_s": 1.0, "route": "r0"}\n')
        with pytest.raises(ValueError, match="duplicate route id 'r0'"):
            FleetTrace.from_jsonl(p)

    def test_leading_blank_lines_tolerated(self, tmp_path):
        # regression: a leading blank line used to be misreported as
        # "empty jsonl trace" (the header read was a bare readline)
        tr = flash_crowd(n_routes=2, seed=17, base_rate_hr=1.0)
        p = tmp_path / "day.jsonl"
        tr.to_jsonl(p)
        padded = tmp_path / "padded.jsonl"
        padded.write_text("\n  \n" + p.read_text())
        back = FleetTrace.from_jsonl(padded)
        assert back.to_records() == tr.to_records()

    def test_zone_field_round_trips(self, tmp_path):
        tr = flash_crowd(n_routes=2, seed=17, base_rate_hr=1.0)
        routes = tuple(
            dataclasses.replace(r, zone="DEU" if i == 0 else None)
            for i, r in enumerate(tr.routes))
        tr = dataclasses.replace(tr, routes=routes)
        p = tmp_path / "zoned.jsonl"
        tr.to_jsonl(p)
        back = FleetTrace.from_jsonl(p)
        assert back.routes[0].zone == "DEU"
        assert back.routes[1].zone is None
        rec = trace_from_records(tr.to_records())
        assert rec.routes[0].zone == "DEU" and rec.routes[1].zone is None


class TestBigGapCache:
    """Derived stream arrays are shared across runs, within bounds."""

    def test_hit_on_same_source_array(self):
        cache = _BigGapCache(maxsize=4)
        src = np.array([3.0, 1.0, 2.0, 99.0])
        a1, g1 = cache.stream_arrays(src, 10.0)
        a2, g2 = cache.stream_arrays(src, 10.0)
        assert a1 is a2 and g1 is g2            # shared derived objects
        assert cache.hits == 1 and cache.misses == 1
        assert list(a1) == [1.0, 2.0, 3.0]      # sorted, horizon-filtered
        # a different horizon is a different derivation
        a3, _ = cache.stream_arrays(src, 2.5)
        assert cache.misses == 2 and list(a3) == [1.0, 2.0]

    def test_lru_bound_holds(self):
        cache = _BigGapCache(maxsize=2)
        srcs = [np.array([float(i)]) for i in range(5)]
        for s in srcs:
            cache.stream_arrays(s, 10.0)
        assert len(cache) == 2
        cache.stream_arrays(srcs[-1], 10.0)     # newest still resident
        assert cache.hits == 1

    def test_list_source_not_cached(self):
        cache = _BigGapCache()
        arr, _ = cache.stream_arrays([2.0, 1.0], 10.0)   # no weakref
        assert list(arr) == [1.0, 2.0] and len(cache) == 0

    def test_repeat_runs_on_same_trace_hit(self):
        tr = flash_crowd(n_routes=3, fleet="1xh100+1xl40s", seed=7,
                         base_rate_hr=4.0, horizon_s=6 * 3600.0)
        biggap_cache.clear()
        run_mega(tr.to_scenario(Breakeven), compute_bound=False)
        assert biggap_cache.misses == 3 and biggap_cache.hits == 0
        run_mega(tr.to_scenario(Breakeven), compute_bound=False)
        assert biggap_cache.hits == 3           # every stream reused

    def test_biggap_dict_bounded_per_stream(self):
        cache = _BigGapCache(max_timeouts=3)
        src = np.arange(50, dtype=np.float64)
        _, gaps = cache.stream_arrays(src, 100.0)
        from repro.fleet.mega.megasim import _Stream
        ms = _Stream("m", src, gaps)
        import repro.fleet.mega.megasim as megasim_mod
        old = megasim_mod.biggap_cache
        megasim_mod.biggap_cache = cache
        try:
            for T in (0.5, 1.5, 2.5, 3.5, 4.5):
                ms.biggaps(T)
        finally:
            megasim_mod.biggap_cache = old
        assert len(ms.biggap) == 3              # oldest evicted


def _jax_pair(make_scenario, **run_kw):
    """The same scenario through both bulk backends (fresh scenarios:
    they hold mutable per-run state)."""
    ref = run_mega(make_scenario(), backend="numpy", **run_kw)
    got = run_mega(make_scenario(), backend="jax", **run_kw)
    return ref, got


def _assert_backends_match(ref, got):
    """The backend contract: identical structural outcomes, float totals
    to <=1e-9 relative (energy is summed in a different order on the
    compiled path; latency totals use fsum on an identical multiset, so
    they are exactly equal)."""
    assert got.requests == ref.requests
    assert got.cold_starts == ref.cold_starts
    assert got.power_timeline == ref.power_timeline
    assert got.replica_timeline == ref.replica_timeline
    assert got.added_latency_s_total == ref.added_latency_s_total
    assert got.energy_wh == pytest.approx(ref.energy_wh, rel=REL)
    assert got.carbon_kg == pytest.approx(ref.carbon_kg, rel=REL)
    assert got.parking_tax_wh == pytest.approx(ref.parking_tax_wh, rel=REL)
    for (t1, c1), (t2, c2) in zip(ref.carbon_timeline, got.carbon_timeline):
        assert t2 == t1
        assert c2 == pytest.approx(c1, rel=REL, abs=1e-12)
    for rd, gd in zip(ref.devices, got.devices):
        assert gd.requests == rd.requests
        assert gd.cold_starts == rd.cold_starts
        assert list(gd.energy_wh) == list(rd.energy_wh)
        for k in rd.energy_wh:
            assert gd.energy_wh[k] == pytest.approx(rd.energy_wh[k],
                                                    rel=REL, abs=1e-9)
        assert gd.carbon_kg == pytest.approx(rd.carbon_kg, rel=REL,
                                             abs=1e-12)


class TestJaxBackend:
    """run_mega(backend="jax") == the numpy anchor, which == run_fleet."""

    def test_pinned_day_matches_numpy(self):
        ref, got = _jax_pair(
            lambda: mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED))
        _assert_backends_match(ref, got)
        assert np.array_equal(np.asarray(ref.latencies_s),
                              np.asarray(got.latencies_s))

    @pytest.mark.parametrize("gen", [flash_crowd, product_launch,
                                     regional_outage],
                             ids=["flash-crowd", "product-launch",
                                  "regional-outage"])
    def test_generated_days_match(self, gen):
        tr = gen(n_routes=4, fleet="h100+a100+l40s", seed=7)
        ref, got = _jax_pair(lambda: tr.to_scenario(Breakeven),
                             compute_bound=False)
        _assert_backends_match(ref, got)

    def test_shaped_carbon_trace_matches(self):
        # the carbon integral is the Pallas-kernel path's whole reason
        # to exist; anchor it on a non-flat intensity curve
        tr = flash_crowd(n_routes=4, fleet="h100+a100", seed=11,
                         horizon_s=8 * 3600.0)
        ct = make_trace("solar-duck", 0.39)
        ref, got = _jax_pair(
            lambda: tr.to_scenario(Breakeven, carbon_trace=ct),
            compute_bound=False)
        _assert_backends_match(ref, got)

    def test_phase_timings_reported(self):
        sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED)
        res = run_mega(sc, backend="jax")
        keys = {"biggap_s", "billing_s", "energy_s", "carbon_s",
                "bulk_scan_s"}
        assert set(res.phase_timings) == keys
        assert all(v >= 0.0 for v in res.phase_timings.values())

    def test_unknown_backend_rejected(self):
        sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED)
        with pytest.raises(ValueError, match="unknown backend"):
            run_mega(sc, backend="torch")

    def test_scope_guard_parity(self):
        # out-of-scope scenarios refuse identically on either backend
        sc = mixed_fleet_scenario(AdaptiveBreakeven, "warm-first", seed=PIN_SEED)
        with pytest.raises(MegaUnsupportedError, match="adapts"):
            run_mega(sc, backend="jax")

    def test_clear_error_when_jax_missing(self, monkeypatch):
        import repro.fleet.mega as mega_pkg
        monkeypatch.delitem(sys.modules, "repro.fleet.mega.jaxback",
                            raising=False)
        monkeypatch.delattr(mega_pkg, "jaxback", raising=False)
        monkeypatch.setitem(sys.modules, "jax", None)   # import -> error
        sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED)
        with pytest.raises(RuntimeError, match="needs jax"):
            run_mega(sc, backend="jax")

    @settings(max_examples=6)
    @given(seed=st.integers(min_value=0, max_value=10_000),
           gen=st.sampled_from(sorted(GENERATORS)),
           policy=st.sampled_from([Breakeven, AlwaysOn, _ttl300]))
    def test_property_backends_agree(self, seed, gen, policy):
        tr = GENERATORS[gen](n_routes=3, fleet="h100+l40s", seed=seed,
                             horizon_s=6 * 3600.0)
        ref, got = _jax_pair(lambda: tr.to_scenario(policy),
                             compute_bound=False)
        _assert_backends_match(ref, got)


class TestFusedFinalize:
    """The fused metering finalize (one kernel launch for energy +
    billed seconds + carbon) against the legacy three-pass path, on the
    multi-trace 3-zone day with mixed purchase tiers -- the widest
    surface the fused kernel covers."""

    FLEET = "2xh100@DEU:spot+2xa100@USA+2xl40s@IND"

    def _scenario(self):
        return mixed_fleet_scenario(
            Breakeven, "warm-first", fleet=self.FLEET, seed=PIN_SEED,
            horizon_s=6 * 3600.0, carbon_trace="zone")

    def _toggle_pair(self, monkeypatch):
        from repro.fleet.mega import jaxback
        fused = run_mega(self._scenario(), backend="jax",
                         compute_bound=False)
        monkeypatch.setattr(jaxback, "FUSED", False)
        unfused = run_mega(self._scenario(), backend="jax",
                           compute_bound=False)
        return fused, unfused

    def test_fused_matches_unfused(self, monkeypatch):
        fused, unfused = self._toggle_pair(monkeypatch)
        # energy and state durations are pass-through lanes of the same
        # segment-sum: BIT-identical, so the 0.0-USD anchors survive
        assert fused.energy_wh == unfused.energy_wh
        assert fused.cost_usd == unfused.cost_usd
        assert fused.gpu_hours_usd == unfused.gpu_hours_usd
        for fd, ud in zip(fused.devices, unfused.devices):
            assert fd.energy_wh == ud.energy_wh
            assert fd.durations_s == ud.durations_s
        # the carbon lane integrates the raw charge log instead of the
        # coalesced segments: same closed form, float-assoc tolerance
        assert fused.carbon_kg == pytest.approx(unfused.carbon_kg, rel=REL)
        for (t1, c1), (t2, c2) in zip(unfused.carbon_timeline,
                                      fused.carbon_timeline):
            assert t2 == t1
            assert c2 == pytest.approx(c1, rel=REL, abs=1e-12)

    def test_tier_billed_seconds_all_engines_agree(self, monkeypatch):
        fused, unfused = self._toggle_pair(monkeypatch)
        ref = run_fleet(self._scenario())
        assert set(fused.tier_billed_s) == {"on_demand", "spot"}
        for engine in (unfused, ref):
            assert set(engine.tier_billed_s) == set(fused.tier_billed_s)
            for t, s in fused.tier_billed_s.items():
                assert s == pytest.approx(engine.tier_billed_s[t], rel=REL)
        # mega scope has no sleep/off states, so billed seconds per
        # tier partition the full metered time
        total = sum(s for d in fused.devices
                    for s in d.durations_s.values())
        assert sum(fused.tier_billed_s.values()) == pytest.approx(
            total, rel=REL)

    def test_fused_matches_numpy_anchor(self):
        ref = run_mega(self._scenario(), backend="numpy",
                       compute_bound=False)
        got = run_mega(self._scenario(), backend="jax",
                       compute_bound=False)
        _assert_backends_match(ref, got)
        for t, s in ref.tier_billed_s.items():
            assert got.tier_billed_s[t] == pytest.approx(s, rel=REL)

    def test_phase_timing_keys_unchanged(self):
        res = run_mega(self._scenario(), backend="jax")
        assert set(res.phase_timings) == {"biggap_s", "billing_s",
                                          "energy_s", "carbon_s",
                                          "bulk_scan_s"}


class TestMegaSweep:
    """Vmapped sweep entry point: deterministic, compiled-once batches."""

    def test_seeds_sweep_runs_and_is_deterministic(self):
        from repro.fleet import run_mega_sweep
        kw = dict(n_routes=3, fleet="h100+l40s", base_rate_hr=8.0,
                  horizon_s=6 * 3600.0)
        r1 = run_mega_sweep(seeds=[1, 2, 3], **kw)
        r2 = run_mega_sweep(seeds=[1, 2, 3], **kw)
        assert len(r1) == 3
        assert [a.energy_wh for a in r1] == [b.energy_wh for b in r2]
        assert [a.requests for a in r1] == [b.requests for b in r2]
        assert all(a.phase_timings is not None for a in r1)
        # distinct seeds produced distinct days
        assert len({a.requests for a in r1}) > 1

    def test_sweep_traces_generator_shapes(self):
        from repro.fleet.mega import sweep_traces
        for gen in sorted(GENERATORS):
            trs = sweep_traces([5], generator=gen, n_routes=3,
                               horizon_s=6 * 3600.0)
            assert len(trs) == 1 and len(trs[0].routes) == 3
            assert trs[0].requests > 0
        with pytest.raises(KeyError, match="unknown sweep generator"):
            sweep_traces([5], generator="meteor-strike")

    def test_scenarios_sweep_matches_run_mega(self):
        from repro.fleet import run_mega_sweep
        tr = flash_crowd(n_routes=3, fleet="h100+l40s", seed=9,
                         horizon_s=6 * 3600.0)
        ref = run_mega(tr.to_scenario(Breakeven), backend="jax",
                       compute_bound=False)
        got = run_mega_sweep(scenarios=[tr.to_scenario(Breakeven)])[0]
        assert got.energy_wh == ref.energy_wh
        assert got.requests == ref.requests

    def test_argument_validation(self):
        from repro.fleet import run_mega_sweep
        with pytest.raises(ValueError, match="exactly one"):
            run_mega_sweep()
        with pytest.raises(ValueError, match="exactly one"):
            run_mega_sweep(scenarios=[], seeds=[1])
        with pytest.raises(ValueError, match="need seeds"):
            run_mega_sweep(scenarios=[], n_routes=4)

    def test_on_unsupported_skip_returns_none_slots(self):
        # the batched planner's seam: out-of-scope scenarios come back
        # as None in place instead of aborting the whole sweep
        from repro.fleet.mega.jaxback import run_mega_sweep
        tr = flash_crowd(n_routes=3, fleet="h100+l40s", seed=9,
                         horizon_s=6 * 3600.0)
        good = tr.to_scenario(Breakeven)
        bad = tr.to_scenario(Breakeven)
        bad = dataclasses.replace(bad, router="slo-aware")
        out = run_mega_sweep(scenarios=[good, bad],
                             compute_bound=False, on_unsupported="skip")
        assert out[0] is not None and out[0].requests > 0
        assert out[1] is None
        with pytest.raises(MegaUnsupportedError):
            run_mega_sweep(scenarios=[tr.to_scenario(Breakeven), bad],
                           compute_bound=False)
        with pytest.raises(ValueError, match="on_unsupported"):
            run_mega_sweep(scenarios=[good], on_unsupported="ignore")
