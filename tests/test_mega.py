"""Equivalence anchors + scope guards for the vectorized mega simulator.

The correctness spine of `fleet/mega/megasim.py` is a single claim: on
its supported scope, `run_mega` IS `run_fleet` -- same routing, same
evictions, same joules -- just re-expressed as an array program.  This
file pins that claim the way every other layer pins its anchor
(docs/ARCHITECTURE.md, "The equivalence-anchor contract"):

* the pinned 10-model x 6-GPU seed-100 day matches the event loop
  **bit-for-bit** on fleet totals (the ISSUE acceptance asks for 1e-3
  relative; we hold 0.0) and to <=1e-9 relative on every per-device
  bucket (the event loop's `Cluster.advance_to` steps its clock by
  float *deltas*, so its absolute times carry ~1-ulp accumulated drift
  that megasim, which uses exact event times, does not reproduce);
* unsupported scenarios refuse loudly (`MegaUnsupportedError`), never
  silently approximate;
* a 500-device x 100k-request day completes, conserves requests, and
  meters non-negative energy;
* the trace generators are seed-deterministic (same seed => the
  bit-identical trace) and round-trip through the record schema.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.scheduler import (AdaptiveBreakeven, AlwaysOn, Breakeven,
                                  Clairvoyant, FixedTTL)
from repro.fleet import (CarbonBreakeven, MegaUnsupportedError,
                         ReplicaAutoscaler, flash_crowd,
                         mixed_fleet_scenario, product_launch,
                         regional_outage, run_fleet, run_mega, solar_duck,
                         trace_from_records)

REL = 1e-9          # per-device tolerance (observed worst: ~2e-15)


def _ttl300():
    return FixedTTL(300.0)


def _pair(policy, **kw):
    """Run the same scenario through both simulators (scenarios hold
    mutable per-run state, so each gets a fresh one)."""
    ref = run_fleet(mixed_fleet_scenario(policy, "warm-first", **kw))
    got = run_mega(mixed_fleet_scenario(policy, "warm-first", **kw))
    return ref, got


class TestEquivalenceAnchor:
    """run_mega == run_fleet on the pinned 10-model x 6-GPU day."""

    def test_pinned_day_bit_exact_fleet_totals(self):
        ref, got = _pair(Breakeven, seed=100)
        assert got.requests == ref.requests
        assert got.cold_starts == ref.cold_starts
        assert got.energy_wh == ref.energy_wh            # bit-for-bit
        assert got.parking_tax_wh == ref.parking_tax_wh
        assert got.carbon_kg == ref.carbon_kg
        # per-state aggregates sum the per-device buckets, which carry the
        # event loop's ~1-ulp clock drift (see module docstring)
        for k in ref.state_energy_wh:
            assert got.state_energy_wh[k] == pytest.approx(
                ref.state_energy_wh[k], rel=1e-12)
        for k in ref.state_durations_s:
            assert got.state_durations_s[k] == pytest.approx(
                ref.state_durations_s[k], rel=1e-12)
        assert got.power_timeline == ref.power_timeline  # same segments
        assert got.replica_timeline == ref.replica_timeline
        assert got.lb_nongated_wh == ref.lb_nongated_wh
        assert got.cv_per_model_wh == ref.cv_per_model_wh
        assert got.infra_usd == ref.infra_usd
        assert got.energy_usd == ref.energy_usd
        assert got.carbon_timeline == ref.carbon_timeline

    @pytest.mark.parametrize("policy", [Breakeven, AlwaysOn, _ttl300,
                                        CarbonBreakeven],
                             ids=["breakeven", "always-on", "ttl-300",
                                  "carbon-breakeven"])
    def test_per_device_reports_match(self, policy):
        ref, got = _pair(policy, seed=100)
        assert got.requests == ref.requests
        assert got.cold_starts == ref.cold_starts
        assert got.energy_wh == pytest.approx(ref.energy_wh, rel=REL)
        for rd, gd in zip(ref.devices, got.devices):
            assert gd.instance_id == rd.instance_id
            assert gd.cold_starts == rd.cold_starts
            assert gd.requests == rd.requests
            assert gd.meter_state == rd.meter_state
            assert gd.resident == rd.resident
            assert list(gd.energy_wh) == list(rd.energy_wh)  # key order too
            for k in rd.energy_wh:
                assert gd.energy_wh[k] == pytest.approx(
                    rd.energy_wh[k], rel=REL, abs=1e-9)
            for k in rd.durations_s:
                assert gd.durations_s[k] == pytest.approx(
                    rd.durations_s[k], rel=REL, abs=1e-6)

    def test_latency_multiset_matches(self):
        ref, got = _pair(Breakeven, seed=100)
        assert len(got.latencies_s) == len(ref.latencies_s)
        assert np.allclose(np.asarray(got.latencies_s),
                           np.asarray(ref.latencies_s), rtol=0, atol=1e-9)
        assert got.p99_added_latency_s == pytest.approx(
            ref.p99_added_latency_s, abs=1e-9)

    @pytest.mark.parametrize("seed", [7, 42, 2024])
    def test_other_seeds_match(self, seed):
        ref, got = _pair(Breakeven, seed=seed)
        assert got.requests == ref.requests
        assert got.cold_starts == ref.cold_starts
        assert got.energy_wh == pytest.approx(ref.energy_wh, rel=REL)

    def test_generated_trace_day_matches_event_loop(self):
        tr = flash_crowd(n_routes=4, fleet="h100+a100+l40s",
                         horizon_s=4 * 3600.0, seed=100)
        ref = run_fleet(tr.to_scenario(Breakeven))
        got = run_mega(tr.to_scenario(Breakeven))
        assert got.requests == ref.requests == tr.requests
        assert got.cold_starts == ref.cold_starts
        assert got.energy_wh == pytest.approx(ref.energy_wh, rel=REL)


class TestScopeGuards:
    """Out-of-scope scenarios refuse loudly instead of approximating."""

    def test_non_warm_first_router_rejected(self):
        with pytest.raises(MegaUnsupportedError, match="warm-first"):
            run_mega(mixed_fleet_scenario(Breakeven, "least-loaded",
                                          seed=100))

    def test_stateful_policy_rejected(self):
        with pytest.raises(MegaUnsupportedError, match="adapts"):
            run_mega(mixed_fleet_scenario(AdaptiveBreakeven, "warm-first",
                                          seed=100))

    def test_clairvoyant_policy_rejected(self):
        with pytest.raises(MegaUnsupportedError):
            run_mega(mixed_fleet_scenario(Clairvoyant, "warm-first",
                                          seed=100))

    def test_nonzero_service_time_rejected(self):
        sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=100)
        with pytest.raises(MegaUnsupportedError, match="service"):
            run_mega(dataclasses.replace(sc, service_s=2.0))

    def test_autoscaler_rejected(self):
        sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=100)
        with pytest.raises(MegaUnsupportedError, match="autoscal"):
            run_mega(dataclasses.replace(sc,
                                         autoscaler=ReplicaAutoscaler()))

    def test_carbon_breakeven_on_shaped_trace_rejected(self):
        # flat trace => constant T*, supported (anchored above); a shaped
        # trace makes the timeout time-varying, which the probe must catch
        sc = mixed_fleet_scenario(CarbonBreakeven, "warm-first", seed=100,
                                  carbon_trace=solar_duck(0.4))
        with pytest.raises(MegaUnsupportedError, match="varies"):
            run_mega(sc)


class TestScale:
    """The point of the subsystem: mega days in interactive time."""

    def test_500_devices_100k_requests(self):
        tr = flash_crowd(n_routes=500,
                         fleet="170xh100+170xa100+160xl40s",
                         seed=100, base_rate_hr=18.0, spike_x=30.0)
        assert tr.requests > 100_000
        res = run_mega(tr.to_scenario(Breakeven), compute_bound=False)
        assert res.requests == tr.requests          # conservation
        assert len(res.devices) == 500
        assert res.energy_wh > 0.0
        assert all(v >= 0.0 for v in res.state_energy_wh.values())
        assert all(v >= 0.0 for d in res.devices
                   for v in d.energy_wh.values())
        # every device's meter covers the same shared-clock span, which
        # is the horizon plus any load still in flight at day end (the
        # event loop's final advance_to(max(horizon, clock)) semantics)
        spans = [sum(d.durations_s.values()) for d in res.devices]
        assert min(spans) == pytest.approx(max(spans), rel=1e-9)
        assert min(spans) >= tr.horizon_s - 1e-6


class TestGenerators:
    """Seed discipline + schema round-trip for the synthetic days."""

    @pytest.mark.parametrize("gen", [flash_crowd, product_launch,
                                     regional_outage],
                             ids=["flash-crowd", "product-launch",
                                  "regional-outage"])
    def test_same_seed_bit_identical(self, gen):
        a, b = gen(seed=100), gen(seed=100)
        assert [r.route_id for r in a.routes] == \
               [r.route_id for r in b.routes]
        for ra, rb in zip(a.routes, b.routes):
            assert np.array_equal(ra.arrivals_s, rb.arrivals_s)
            assert ra.checkpoint_gb == rb.checkpoint_gb

    @pytest.mark.parametrize("gen", [flash_crowd, product_launch,
                                     regional_outage],
                             ids=["flash-crowd", "product-launch",
                                  "regional-outage"])
    def test_different_seed_differs(self, gen):
        a, b = gen(seed=100), gen(seed=101)
        assert any(not np.array_equal(ra.arrivals_s, rb.arrivals_s)
                   for ra, rb in zip(a.routes, b.routes))

    @pytest.mark.parametrize("gen", [flash_crowd, product_launch,
                                     regional_outage],
                             ids=["flash-crowd", "product-launch",
                                  "regional-outage"])
    def test_records_round_trip(self, gen):
        tr = gen(seed=100)
        back = trace_from_records(tr.to_records())
        assert back.name == tr.name and back.fleet == tr.fleet
        assert back.horizon_s == tr.horizon_s and back.seed == tr.seed
        for ra, rb in zip(tr.routes, back.routes):
            assert ra.route_id == rb.route_id
            assert ra.checkpoint_gb == rb.checkpoint_gb
            assert np.array_equal(ra.arrivals_s, rb.arrivals_s)

    def test_records_reject_unknown_route(self):
        rec = flash_crowd(seed=100).to_records()
        rec["events"].append({"t_s": 1.0, "route": "ghost"})
        with pytest.raises(ValueError, match="unknown route"):
            trace_from_records(rec)
