"""Breakeven model + eviction policy + simulator invariants (sections 5, 7)."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # optional extra: property tests skip, rest run
    from _hypothesis_shim import given, settings, st

from repro.core import (A100, H100, L40S, PYTORCH_70B, QWEN25_7B_MEASURED,
                        LoaderSpec)
from repro.core.breakeven import breakeven_seconds, critical_rate_per_hr
from repro.core.scheduler import (AdaptiveBreakeven, AlwaysOn, Breakeven,
                                  Clairvoyant, ExactBreakeven, FixedTTL)
from repro.core.simulator import compare_policies, simulate
from repro.core import traffic


def test_breakeven_paper_values():
    assert breakeven_seconds(PYTORCH_70B, H100) == pytest.approx(270.5, 1e-3)
    assert breakeven_seconds(QWEN25_7B_MEASURED, H100) == \
        pytest.approx(74.5, 1e-2)
    assert critical_rate_per_hr(PYTORCH_70B, H100) == pytest.approx(13.3, 1e-2)
    assert critical_rate_per_hr(PYTORCH_70B, A100) == pytest.approx(7.0, 1e-2)
    assert critical_rate_per_hr(PYTORCH_70B, L40S) == pytest.approx(17.7, 1e-2)


@given(st.floats(50.0, 400.0), st.floats(1.0, 120.0))
@settings(max_examples=50, deadline=None)
def test_breakeven_algebra(p_load, t_load):
    """T* * lambda* == 3600 (Eq. 12 x Eq. 13), exact convention <= paper."""
    ld = LoaderSpec("x", p_load, t_load)
    t = breakeven_seconds(ld, H100)
    lam = critical_rate_per_hr(ld, H100)
    assert t * lam == pytest.approx(3600.0, rel=1e-9)
    assert breakeven_seconds(ld, H100, paper_convention=False) <= t


def test_always_on_energy_is_ctx_power():
    arr = traffic.poisson(5.0, seed=0)
    r = simulate(arr, AlwaysOn(), H100, PYTORCH_70B)
    assert r.energy_wh == pytest.approx(H100.p_ctx_w * 24.0, rel=1e-6)
    assert r.cold_starts == 1


def test_policy_energy_ordering():
    """Clairvoyant <= every online policy on every trace (lower bound)."""
    for seed in range(3):
        for gen in (lambda s: traffic.poisson(5.0, seed=s),
                    lambda s: traffic.bursty(seed=s),
                    lambda s: traffic.diurnal(seed=s)):
            arr = gen(seed)
            res = compare_policies(
                arr, [AlwaysOn(), FixedTTL(300),
                      Breakeven(PYTORCH_70B, H100),
                      Clairvoyant(PYTORCH_70B, H100)], H100, PYTORCH_70B)
            clair = res[-1].energy_wh
            for r in res[:-1]:
                assert clair <= r.energy_wh + 1e-6, (r.policy, seed)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_simulator_time_conservation(seed):
    arr = traffic.poisson(8.0, seed=seed)
    r = simulate(arr, Breakeven(PYTORCH_70B, H100), H100, PYTORCH_70B)
    accounted = r.warm_idle_s + r.evicted_s + r.loading_s
    # loading can push past the horizon by at most one load
    assert accounted == pytest.approx(r.horizon_s, abs=PYTORCH_70B.t_load_s + 1)
    assert r.energy_wh > 0
    assert r.cold_starts >= 1


def test_no_evictions_above_critical_rate():
    """At rates far above lambda*, breakeven behaves like always-on."""
    arr = traffic.poisson(120.0, seed=1)     # >> lambda* = 13.3/hr
    be = simulate(arr, Breakeven(PYTORCH_70B, H100), H100, PYTORCH_70B)
    ao = simulate(arr, AlwaysOn(), H100, PYTORCH_70B)
    assert be.cold_starts <= 3
    assert be.energy_wh == pytest.approx(ao.energy_wh, rel=0.02)


def test_adaptive_beats_paper_policy_on_diurnal():
    """The beyond-paper fix for the paper's section-8 oscillation issue."""
    sav_paper, sav_adapt = [], []
    for s in range(5):
        arr = traffic.diurnal(seed=s)
        base = simulate(arr, AlwaysOn(), H100, PYTORCH_70B)
        p = simulate(arr, Breakeven(PYTORCH_70B, H100), H100, PYTORCH_70B)
        a = simulate(arr, AdaptiveBreakeven(PYTORCH_70B, H100), H100,
                     PYTORCH_70B)
        sav_paper.append(p.savings_vs(base))
        sav_adapt.append(a.savings_vs(base))
    assert np.mean(sav_adapt) > np.mean(sav_paper)


def test_clairvoyant_requires_future():
    c = Clairvoyant(PYTORCH_70B, H100)
    with pytest.raises(ValueError):
        c.idle_timeout_s(0.0, next_gap_s=None)


def test_traffic_generators_in_horizon():
    for name, gen in traffic.PATTERNS.items():
        arr = gen(seed=3)
        assert np.all(arr >= 0) and np.all(arr < traffic.DAY), name
        assert np.all(np.diff(arr) >= 0), name
