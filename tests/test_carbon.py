"""Carbon-intensity-aware scheduling: trace math, fleet integration,
equivalence anchors, and the pinned 10x6-day acceptance (ISSUE 4).

Three layers:
  * CarbonTrace unit tests -- interpolation/wrap, EXACT integration
    against hand-computed trapezoids, generator means.
  * fleetsim integration -- flat-trace carbon reproduces the scalar
    accounting to 1e-9 kg, a two-segment trace matches a hand-computed
    integral, device carbon sums to fleet carbon, and re-pricing a
    recorded schedule under another trace equals simulating under it.
  * acceptance -- on the pinned 10x6 day (seed 100) under a solar-duck
    trace, the carbon-aware stack cuts kgCO2e vs energy-greedy at
    equal-or-better p99; the single-device energy anchor survives with
    a diurnal trace bound.
"""
import math

import pytest

from repro.core import H100, PYTORCH_70B, QWEN25_7B_MEASURED, traffic
from repro.core.impact import BASE, US_GRID_KG_CO2_PER_KWH
from repro.core.scheduler import AlwaysOn, Breakeven, FixedTTL
from repro.core.simulator import simulate
from repro.fleet import (CarbonAwareRouter, CarbonBreakeven, CarbonTrace,
                         Consolidator, FleetModel, FleetModelSpec,
                         FleetScenario, MIXES, build_fleet,
                         carbon_timeline_kg, flat_trace, get_mix, make_trace,
                         mixed_fleet_scenario, run_fleet,
                         single_device_scenario, solar_duck, trace_for_zone,
                         wind_night)
from repro.serving import RooflineServiceTime

DAY = 24 * 3600.0
HALF = 43200.0


# ---------------------------------------------------------------------------
# CarbonTrace unit tests
# ---------------------------------------------------------------------------

def test_flat_trace_is_scalar_accounting():
    f = flat_trace(0.39)
    assert f.is_flat
    assert f.intensity_at(12345.0) == 0.39
    assert f.integral(0.0, 3600.0) == pytest.approx(0.39 * 3600.0)
    # 1 kW for 1 h = 1 kWh = 0.39 kg
    assert f.carbon_kg(1000.0, 0.0, 3600.0) == pytest.approx(0.39)


def test_two_segment_trace_hand_computed():
    """0.2 kg/kWh at t=0 rising linearly to 0.6 at 12 h, wrapping back
    down to 0.2 at 24 h: every quantity is a trapezoid by hand."""
    tr = CarbonTrace("two", ((0.0, 0.2), (HALF, 0.6)))
    assert tr.intensity_at(0.0) == pytest.approx(0.2)
    assert tr.intensity_at(HALF) == pytest.approx(0.6)
    assert tr.intensity_at(HALF / 2) == pytest.approx(0.4)
    assert tr.intensity_at(18 * 3600.0) == pytest.approx(0.4)  # wrap leg
    day = tr.integral(0.0, DAY)
    assert day == pytest.approx((0.2 + 0.6) * HALF, rel=1e-12)
    assert tr.daily_mean_kg_per_kwh == pytest.approx(0.4, rel=1e-12)
    # partial window [0, 6 h]: mean of endpoints 0.2 and 0.4
    assert tr.integral(0.0, HALF / 2) == pytest.approx(0.3 * HALF / 2,
                                                       rel=1e-12)
    # window straddling a period boundary == one whole period
    assert tr.integral(10_000.0, DAY + 10_000.0) == pytest.approx(day,
                                                                  rel=1e-9)
    assert tr.integral(0.0, 3 * DAY) == pytest.approx(3 * day, rel=1e-9)


def test_trace_validation():
    with pytest.raises(ValueError, match="at least one"):
        CarbonTrace("x", ())
    with pytest.raises(ValueError, match="strictly increasing"):
        CarbonTrace("x", ((0.0, 1.0), (0.0, 2.0)))
    with pytest.raises(ValueError, match="negative"):
        CarbonTrace("x", ((0.0, -1.0),))
    with pytest.raises(ValueError, match="period"):
        CarbonTrace("x", ((0.0, 1.0), (DAY, 2.0)))
    with pytest.raises(KeyError, match="unknown carbon trace"):
        make_trace("nope", 0.39)


@pytest.mark.parametrize("gen", [solar_duck, wind_night])
def test_generators_hit_target_mean(gen):
    tr = gen(0.39)
    assert tr.daily_mean_kg_per_kwh == pytest.approx(0.39, rel=1e-9)
    vals = [v for _, v in tr.points]
    assert min(vals) > 0.0 and max(vals) / min(vals) > 1.5  # real swing


def test_solar_duck_shape():
    """Midday solar belly is the trough, evening ramp the peak."""
    tr = solar_duck(0.39)
    assert tr.intensity_at(13 * 3600.0) < tr.intensity_at(4 * 3600.0) \
        < tr.intensity_at(20 * 3600.0)


def test_zone_presets_preserve_means():
    for zone, mix in MIXES.items():
        tr = trace_for_zone(zone)
        assert tr.daily_mean_kg_per_kwh == pytest.approx(
            mix.gwp_kg_per_kwh, rel=1e-9), zone
    assert trace_for_zone("usa").name == "solar-duck"
    assert trace_for_zone("FRA").is_flat


def test_carbon_timeline_bins():
    f = flat_trace(0.39)
    segs = [(0.0, HALF, 100.0), (HALF, DAY, 50.0)]
    tl = carbon_timeline_kg(f, segs)
    assert len(tl) == 24
    assert all(b >= a - 1e-15 for (_, a), (_, b) in zip(tl, tl[1:]))
    assert tl[-1][1] == pytest.approx(f.carbon_for_segments(segs), rel=1e-12)


# ---------------------------------------------------------------------------
# CarbonBreakeven stopping rule
# ---------------------------------------------------------------------------

def test_carbon_breakeven_flat_is_energy_breakeven():
    pol = CarbonBreakeven(QWEN25_7B_MEASURED, H100,
                          carbon_trace=flat_trace(0.39))
    ref = Breakeven(QWEN25_7B_MEASURED, H100)
    assert pol.idle_timeout_s(0.0) == pytest.approx(ref.t_star_s)
    bare = CarbonBreakeven(QWEN25_7B_MEASURED, H100)   # no trace bound
    assert bare.idle_timeout_s(5000.0) == pytest.approx(ref.t_star_s)


def test_carbon_breakeven_shifts_reloads_toward_clean_hours():
    """Rising intensity ahead -> a reload would land dearer -> hold
    longer; falling intensity -> evict early, reload lands cheap."""
    rising = CarbonTrace("up", ((0.0, 0.2), (HALF, 0.6)))
    pol = CarbonBreakeven(QWEN25_7B_MEASURED, H100, carbon_trace=rising)
    t_star = pol.t_star_s
    up = pol.idle_timeout_s(2 * 3600.0)       # on the rising leg
    down = pol.idle_timeout_s(14 * 3600.0)    # on the falling leg
    assert up > t_star > down
    assert up <= CarbonBreakeven._CAP_TSTARS * t_star


# ---------------------------------------------------------------------------
# fleetsim integration: equivalence anchors
# ---------------------------------------------------------------------------

def test_flat_trace_reproduces_scalar_carbon():
    """Acceptance: flat-trace fleetsim carbon == energy * scalar to
    1e-9 kg, across routers and with consolidation in play."""
    for router, cons in (("warm-first", False), ("energy-greedy", True)):
        res = run_fleet(mixed_fleet_scenario(
            Breakeven, router, consolidate=cons, n_models=6,
            fleet="h100+a100+l40s", horizon_s=6 * 3600.0, seed=7))
        mix = get_mix("USA")
        scalar = res.energy_wh / 1e3 * mix.gwp_kg_per_kwh
        assert res.carbon_kg == pytest.approx(scalar, abs=1e-9)
        assert res.carbon_kg == pytest.approx(res.carbon_kg_flat, abs=1e-9)
        assert res.carbon_trace_name == "flat"


def test_two_segment_trace_fleet_integration_hand_computed():
    """One H100, one always-on model warm from t=0, no requests: power
    is p_ctx_w for the whole day, so fleet carbon is exactly
    p_ctx * integral(trace) / 3.6e6 -- checkable by hand."""
    tr = CarbonTrace("two", ((0.0, 0.2), (HALF, 0.6)))
    devices = build_fleet("h100")
    spec = FleetModelSpec("m", AlwaysOn, loader=QWEN25_7B_MEASURED,
                          vram_gb=10.0, home="h100-0")
    res = run_fleet(FleetScenario(devices=devices,
                                  models=[FleetModel(spec, [])],
                                  horizon_s=DAY, carbon_trace=tr))
    expected = H100.p_ctx_w * (0.2 + 0.6) * HALF / 3.6e6
    assert res.carbon_kg == pytest.approx(expected, abs=1e-12)
    assert res.carbon_trace_name == "two"
    # flat reference: same energy, mean intensity -> same number here
    # (constant power integrates the mean)
    assert res.carbon_kg == pytest.approx(
        res.energy_wh / 1e3 * 0.4, abs=1e-9)


def test_device_carbon_sums_to_fleet_carbon():
    res = run_fleet(mixed_fleet_scenario(
        Breakeven, "energy-greedy", n_models=6, fleet="h100+a100+l40s",
        horizon_s=6 * 3600.0, seed=3, carbon_trace="solar-duck"))
    assert res.carbon_kg == pytest.approx(
        sum(d.carbon_kg for d in res.devices), rel=1e-12)
    assert res.carbon_trace_name == "solar-duck"
    # the trace moves carbon but not joules
    assert res.carbon_kg != pytest.approx(res.carbon_kg_flat, abs=1e-6)
    # cumulative timeline ends at the total
    assert res.carbon_timeline[-1][1] == pytest.approx(res.carbon_kg,
                                                       rel=1e-9)


def test_carbon_with_reprices_identical_schedule():
    """Routers/policies that ignore the trace produce the SAME schedule
    under any trace, so simulating under the duck equals re-pricing the
    flat run's power timeline (the zone-sweep instrument)."""
    kw = dict(n_models=4, fleet="h100+a100", horizon_s=6 * 3600.0, seed=5)
    flat = run_fleet(mixed_fleet_scenario(Breakeven, "energy-greedy", **kw))
    duck = run_fleet(mixed_fleet_scenario(Breakeven, "energy-greedy",
                                          carbon_trace="solar-duck", **kw))
    assert duck.energy_wh == pytest.approx(flat.energy_wh, rel=1e-12)
    duck_trace = make_trace("solar-duck", get_mix("USA").gwp_kg_per_kwh)
    assert flat.carbon_with(duck_trace) == pytest.approx(duck.carbon_kg,
                                                         rel=1e-12)


def test_single_device_energy_anchor_survives_carbon_trace():
    """The 1-device x 1-model equivalence to core/simulator.py (1e-6 Wh)
    must hold with a diurnal trace bound: the trace changes carbon
    pricing, never the energy dynamics of trace-blind policies."""
    arr = traffic.PATTERNS["bursty"](seed=7)
    sim = simulate(arr, FixedTTL(300.0), H100, PYTORCH_70B)
    sc = single_device_scenario(arr, lambda: FixedTTL(300.0), PYTORCH_70B,
                                "h100")
    sc.carbon_trace = "solar-duck"
    res = run_fleet(sc)
    assert res.energy_wh == pytest.approx(sim.energy_wh, abs=1e-6)
    assert res.cold_starts == sim.cold_starts


# ---------------------------------------------------------------------------
# single source of truth: impact <-> catalog (ISSUE 4 satellite fix)
# ---------------------------------------------------------------------------

def test_us_grid_intensity_single_source_of_truth():
    assert MIXES["USA"].gwp_kg_per_kwh is US_GRID_KG_CO2_PER_KWH


def test_paper_180kt_regression():
    """Paper section 6: the BASE scenario prices ~462 GWh/yr at the US
    grid intensity => ~180 kT CO2e/yr."""
    assert BASE.energy_gwh_per_year == pytest.approx(462.0, rel=0.01)
    assert BASE.co2_kt_per_year == pytest.approx(180.0, rel=0.01)


# ---------------------------------------------------------------------------
# acceptance: the pinned 10x6 day under a solar-duck trace
# ---------------------------------------------------------------------------

def test_carbon_aware_cuts_kg_at_equal_or_better_p99_pinned_day():
    """Acceptance (ISSUE 4): on the 10-model x 6-GPU day (seed 100) with
    roofline service times under a solar-duck trace, the carbon-aware
    stack (carbon-breakeven eviction + carbon routing + carbon-aware
    consolidation) emits LESS kgCO2e than breakeven + energy-greedy at
    equal-or-better p99.  (Measured: 3.2785 vs 3.2798 kg at p99 116.1
    vs 119.8 s; the delta is ~0.5% of the schedulable carbon above the
    trace-invariant bare-idle floor -- see docs/CARBON.md.)"""
    svc = RooflineServiceTime()
    kw = dict(service_model=svc, carbon_trace="solar-duck", seed=100)
    eg = run_fleet(mixed_fleet_scenario(Breakeven, "energy-greedy", **kw))
    ca = run_fleet(mixed_fleet_scenario(
        CarbonBreakeven, CarbonAwareRouter(math.inf),
        consolidate=Consolidator(carbon_aware=True, period_s=300.0), **kw))
    assert ca.carbon_kg < eg.carbon_kg
    assert ca.p99_added_latency_s <= eg.p99_added_latency_s
    # sanity: both serve the same workload at comparable joules
    assert ca.requests == eg.requests
    assert abs(ca.energy_wh / eg.energy_wh - 1.0) < 0.01
    # the budgeted variant trades carbon for latency along the Pareto
    slo = run_fleet(mixed_fleet_scenario(
        CarbonBreakeven, CarbonAwareRouter(90.0),
        consolidate=Consolidator(carbon_aware=True, period_s=300.0), **kw))
    assert slo.p99_added_latency_s <= 90.0
    assert slo.carbon_kg >= ca.carbon_kg
