"""Fleet subsystem: catalog, routing, consolidation, fleetsim invariants.

The anchor test is single-device equivalence: 1 device x 1 model through
``run_fleet`` must reproduce ``core.simulator.simulate`` to 1e-6 Wh
(same trace, same policy) -- the fleet layer is then a strict
generalisation of the paper's Table-6 instrument.
"""
import math

import numpy as np
import pytest

import dataclasses

from repro.core import (A100, H100, L40S, LoaderSpec, PYTORCH_70B,
                        QWEN25_7B_MEASURED)
from repro.core.scheduler import AlwaysOn, Breakeven, FixedTTL
from repro.core import traffic
from repro.core.simulator import simulate
from repro.fleet import (CATALOG, Cluster, Consolidator, FleetModel,
                         FleetModelSpec, FleetScenario, ReplicaAutoscaler,
                         SLOAwareRouter, build_fleet, carbon_kg,
                         energy_cost_usd, get_mix, get_router, get_sku,
                         mixed_fleet_scenario, run_fleet,
                         single_device_scenario)
from repro.serving import (ConstantServiceTime, DeviceRuntime,
                           ModelServiceProfile, RequestShape,
                           RooflineServiceTime)

GB = 1024 ** 3
DAY = 24 * 3600.0


# ---------------------------------------------------------------------------
# catalog
# ---------------------------------------------------------------------------

def test_build_fleet_spec_parsing():
    fleet = build_fleet("2xh100+a100+2xl40s")
    assert [d.instance_id for d in fleet] == \
        ["h100-0", "h100-1", "a100-0", "l40s-0", "l40s-1"]
    assert fleet[0].profile is H100
    assert fleet[2].sku.vram_gb == 80.0
    with pytest.raises(ValueError):
        build_fleet("2*h100")
    with pytest.raises(KeyError):
        build_fleet("1xb200")


def test_catalog_prices_and_mixes():
    sku = get_sku("h100")
    assert sku.price_usd_per_hr("spot") < sku.price_usd_per_hr("reserved") \
        < sku.price_usd_per_hr("on_demand")
    mix = get_mix("usa")
    assert energy_cost_usd(1000.0, mix) == pytest.approx(mix.usd_per_kwh)
    assert carbon_kg(1000.0, mix) == pytest.approx(0.39)


# ---------------------------------------------------------------------------
# single-device equivalence (acceptance anchor)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", ["steady", "bursty", "diurnal", "mmpp"])
@pytest.mark.parametrize("make_policy", [
    AlwaysOn,
    lambda: Breakeven(PYTORCH_70B, H100),
    lambda: FixedTTL(300.0),
], ids=["always-on", "breakeven", "ttl-5min"])
def test_single_device_reproduces_simulator(pattern, make_policy):
    arr = traffic.PATTERNS[pattern](seed=7)
    sim = simulate(arr, make_policy(), H100, PYTORCH_70B)
    res = run_fleet(single_device_scenario(arr, make_policy, PYTORCH_70B,
                                           "h100"))
    assert res.energy_wh == pytest.approx(sim.energy_wh, abs=1e-6)
    assert res.cold_starts == sim.cold_starts
    assert res.requests == sim.n_requests
    assert res.added_latency_s_total == \
        pytest.approx(sim.added_latency_s_total, abs=1e-6)


def test_single_device_service_energy_matches_simulator():
    """service_s > 0: active-power accounting matches the simulator (the
    latency metric legitimately differs -- the simulator batches service,
    the fleet serializes it -- but every joule lands identically)."""
    arr = traffic.poisson(6.0, seed=3)
    sim = simulate(arr, FixedTTL(300.0), H100, QWEN25_7B_MEASURED,
                   service_s=2.0)
    res = run_fleet(single_device_scenario(
        arr, lambda: FixedTTL(300.0), QWEN25_7B_MEASURED, "h100",
        service_s=2.0))
    assert res.energy_wh == pytest.approx(sim.energy_wh, abs=1e-6)
    assert res.cold_starts == sim.cold_starts


def test_no_eviction_mid_service():
    """A short TTL must not fire while the model is being served."""
    devices = build_fleet("h100")
    spec = FleetModelSpec("m", lambda: FixedTTL(60.0),
                          loader=QWEN25_7B_MEASURED, vram_gb=10.0,
                          home="h100-0")
    # prewarm arms evict_at=60; the request lands at 50 and serves 30 s
    # across the deadline -- it must finish warm and re-arm from t=80
    sc = FleetScenario(devices=devices,
                       models=[FleetModel(spec, [50.0])],
                       horizon_s=3600.0, service_s=30.0)
    res = run_fleet(sc)
    assert res.cold_starts == 1                 # the prewarm only
    expected = (H100.p_ctx_w * 50.0
                + H100.active_power_w(0.6) * 30.0
                + H100.p_ctx_w * 60.0           # idle until TTL at 140
                + H100.p_base_w * (3600.0 - 140.0)) / 3600.0
    assert res.energy_wh == pytest.approx(expected, abs=1e-9)


def test_consolidator_period_beyond_horizon_is_inert():
    sc = _mixed_scenario(AlwaysOn, "warm-first")
    sc.consolidator = Consolidator(period_s=10 * DAY)
    res = run_fleet(sc)
    ref = run_fleet(_mixed_scenario(AlwaysOn, "warm-first"))
    assert res.energy_wh == pytest.approx(ref.energy_wh, rel=1e-12)


def test_single_device_cold_start_matches_simulator():
    arr = traffic.poisson(4.0, seed=5)
    sim = simulate(arr, FixedTTL(120.0), H100, QWEN25_7B_MEASURED,
                   start_warm=False)
    res = run_fleet(single_device_scenario(
        arr, lambda: FixedTTL(120.0), QWEN25_7B_MEASURED, "h100",
        start_warm=False))
    assert res.energy_wh == pytest.approx(sim.energy_wh, abs=1e-6)
    assert res.cold_starts == sim.cold_starts


# ---------------------------------------------------------------------------
# fleet invariants
# ---------------------------------------------------------------------------

def _mixed_scenario(policy_factory, router, *, consolidate=False,
                    n_models=6, fleet="h100+a100+l40s", horizon_s=DAY,
                    prewarm=True, seed=11):
    devices = build_fleet(fleet)
    pats = ["diurnal", "bursty", "steady"]
    models = []
    for i in range(n_models):
        arr = traffic.PATTERNS[pats[i % len(pats)]](seed=seed + i)
        arr = arr[arr < horizon_s]
        spec = FleetModelSpec(
            model_id=f"m{i}", policy_factory=policy_factory,
            checkpoint_bytes=int((4 + 3 * i) * GB),
            vram_gb=float(5 + 3 * i),
            home=devices[i % len(devices)].instance_id if prewarm else None)
        models.append(FleetModel(spec, arr))
    return FleetScenario(
        devices=devices, models=models, router=router, horizon_s=horizon_s,
        consolidator=Consolidator() if consolidate else None)


def test_fleet_energy_is_sum_of_device_meters():
    res = run_fleet(_mixed_scenario(Breakeven, "energy-greedy",
                                    consolidate=True))
    assert res.energy_wh == \
        pytest.approx(sum(d.total_wh for d in res.devices), rel=1e-12)
    # and every device's own breakdown sums to its total
    for d in res.devices:
        parts = sum(v for k, v in d.energy_wh.items() if k != "total")
        assert d.total_wh == pytest.approx(parts, rel=1e-12)


def test_warm_first_never_cold_starts_with_warm_replica():
    """With always-on policies and every model prewarmed, warm-first
    routing must never reload: cold starts stay at the initial count."""
    sc = _mixed_scenario(AlwaysOn, "warm-first", n_models=6)
    res = run_fleet(sc)
    assert res.cold_starts == 6          # the prewarms only
    assert res.added_latency_s_total == 0.0


def test_fleet_beats_or_matches_lower_bound():
    for router in ("warm-first", "least-loaded", "energy-greedy",
                   "breakeven-aware"):
        res = run_fleet(_mixed_scenario(Breakeven, router))
        assert res.energy_wh >= res.lb_nongated_wh - 1e-6


def test_energy_greedy_consolidation_beats_always_on():
    base = run_fleet(_mixed_scenario(AlwaysOn, "warm-first"))
    opt = run_fleet(_mixed_scenario(Breakeven, "energy-greedy",
                                    consolidate=True))
    assert opt.energy_wh < base.energy_wh
    assert opt.savings_vs(base) > 0.10


def test_consolidation_never_increases_fleet_idle_power():
    """The planner only drains sources onto already-on targets, so
    applying a plan strictly reduces (or keeps) instantaneous idle
    power."""
    devices = build_fleet("h100+a100+l40s")
    cluster = Cluster(devices)
    for i, did in enumerate(d.instance_id for d in devices):
        spec = FleetModelSpec(model_id=f"m{i}", policy_factory=AlwaysOn,
                              loader=QWEN25_7B_MEASURED, vram_gb=10.0)
        cluster.register_model(spec)
        cluster.replica(did, f"m{i}")
        cluster.managers[did].prewarm(f"m{i}")
    before = cluster.idle_power_w()
    moves = Consolidator().plan(cluster, cluster.clock())
    assert moves                                  # something to pack
    for mv in moves:
        cluster.start_migration(mv.model_id, mv.src, mv.dst)
        cluster.clock.advance(
            cluster.loader_for(mv.model_id, mv.dst).t_load_s)
        cluster.finish_load(mv.dst, mv.model_id)
    after = cluster.idle_power_w()
    assert after <= before
    # all three models co-parked on one device; two devices fell to bare
    on = [d for d in cluster.devices if cluster.context_on(d)]
    assert len(on) == 1


def test_consolidation_accounts_destination_extension():
    """Migrating a long-armed model onto a device whose own residents
    evict soon must charge the destination's context extension: here the
    cheap-step A100 would be drained onto the expensive-step L40S and
    hold its 66 W context up for ~18 more minutes -- a net energy LOSS
    the planner must reject."""
    devices = build_fleet("a100+2xl40s")
    cluster = Cluster(devices[:2])      # a100-0 + l40s-0
    for i in range(2):                  # two short-TTL models on the l40s
        spec = FleetModelSpec(f"short{i}",
                              policy_factory=lambda: FixedTTL(35.0),
                              loader=QWEN25_7B_MEASURED, vram_gb=5.0)
        cluster.register_model(spec)
        cluster.replica("l40s-0", f"short{i}")
        cluster.managers["l40s-0"].prewarm(f"short{i}")
    spec = FleetModelSpec("long", policy_factory=lambda: FixedTTL(1100.0),
                          loader=QWEN25_7B_MEASURED, vram_gb=5.0)
    cluster.register_model(spec)
    cluster.replica("a100-0", "long")
    cluster.managers["a100-0"].prewarm("long")
    # a100 drain benefit: 26.3 W x 1100 s ~ 29 kJ; cost: load + the L40S
    # step (66.4 W) held up ~1095 s past its own 35 s window ~ 75 kJ
    assert Consolidator().plan(cluster, 0.0) == []


def test_serving_overlaps_another_models_load():
    """Loads overlap serving (the concurrency tentpole): m1 is warm and
    its request lands DURING m2's long load on the same device -- it
    must serve instantly (zero added latency) instead of queueing behind
    the loader channel, and no spurious cold start may appear."""
    devices = build_fleet("h100")
    slow_loader = LoaderSpec("slow", 124.0, 200.0)
    m1 = FleetModel(FleetModelSpec("m1", lambda: FixedTTL(100.0),
                                   loader=QWEN25_7B_MEASURED, vram_gb=5.0,
                                   home="h100-0"),
                    [60.0])
    m2 = FleetModel(FleetModelSpec("m2", AlwaysOn, loader=slow_loader,
                                   vram_gb=5.0),
                    [50.0])
    res = run_fleet(FleetScenario(devices=devices, models=[m1, m2],
                                  horizon_s=3600.0))
    assert res.cold_starts == 2       # m1 prewarm + m2 load, nothing else
    # m2's request waited its own 200 s load; m1's served immediately
    assert res.added_latency_s_total == pytest.approx(200.0, abs=1e-9)
    assert res.p99_added_latency_s <= 200.0


def test_queued_request_pins_model_against_eviction():
    """A short-TTL model whose requests wait for a decode slot (pool
    full) must not be evicted by its armed timeout while demand queues:
    three arrivals at t=50 into max_batch=2 slots serve as 2 + 1 rounds
    with no reload (regression: spurious second cold start)."""
    devices = build_fleet("h100")
    m = FleetModel(FleetModelSpec("m", lambda: FixedTTL(60.0),
                                  loader=QWEN25_7B_MEASURED, vram_gb=5.0,
                                  home="h100-0"),
                   [50.0, 50.0, 50.0])
    res = run_fleet(FleetScenario(devices=devices, models=[m],
                                  horizon_s=3600.0, service_s=30.0,
                                  max_batch=2))
    assert res.cold_starts == 1                 # the prewarm only
    assert res.requests == 3
    # two serve 50..80 with zero wait; the third waits one 30 s round
    assert res.added_latency_s_total == pytest.approx(30.0, abs=1e-9)
    assert res.p50_added_latency_s == pytest.approx(0.0, abs=1e-9)


def test_migration_never_unloads_model_in_service():
    """Regression: a queued migration whose source started serving must
    defer, and no device may end the horizon metering 'parked' with zero
    resident models (phantom context power)."""
    for router in ("warm-first", "energy-greedy"):
        sc = _mixed_scenario(Breakeven, router, consolidate=True)
        sc.service_s = 5.0
        sc.consolidator = Consolidator(period_s=300.0)
        res = run_fleet(sc)
        for d in res.devices:
            if d.meter_state == "parked":
                assert d.resident, (router, d.instance_id)
            if d.meter_state == "bare":
                assert not d.resident, (router, d.instance_id)


def test_consolidation_skips_when_migration_not_worth_it():
    """Short armed timeouts => tiny counterfactual benefit => no moves."""
    devices = build_fleet("h100+a100")
    cluster = Cluster(devices)
    for i, did in enumerate(d.instance_id for d in devices):
        spec = FleetModelSpec(model_id=f"m{i}",
                              policy_factory=lambda: FixedTTL(1.0),
                              loader=PYTORCH_70B, vram_gb=10.0)
        cluster.register_model(spec)
        cluster.replica(did, f"m{i}")
        cluster.managers[did].prewarm(f"m{i}")
    assert Consolidator().plan(cluster, cluster.clock()) == []


def test_capacity_respected_by_placement():
    """Router placement avoids devices that cannot fit the model."""
    devices = build_fleet("l40s+h100")          # 48 GB vs 80 GB
    cluster = Cluster(devices)
    spec = FleetModelSpec(model_id="big", policy_factory=AlwaysOn,
                          loader=PYTORCH_70B, vram_gb=60.0)
    cluster.register_model(spec)
    cluster.rates["big"].observe(0.0)
    chosen = get_router("least-loaded").choose("big", 0.0, cluster)
    assert chosen == "h100-0"


# ---------------------------------------------------------------------------
# deterministic 2-device x 3-model end-to-end scenario
# ---------------------------------------------------------------------------

def test_two_device_three_model_deterministic():
    """Hand-built trace on h100+a100: energy is checkable by hand.

    Layout: m0 lives warm on the H100 all day (always-on), m1 parks on
    the A100 and evicts after its 60 s TTL, m2 is cold and gets one
    burst of 2 requests routed warm-first.
    """
    devices = build_fleet("h100+a100")
    ld = QWEN25_7B_MEASURED                     # 124 W x 30 s
    models = [
        FleetModel(FleetModelSpec("m0", AlwaysOn, loader=ld, vram_gb=15.0,
                                  home="h100-0"),
                   [3600.0]),
        FleetModel(FleetModelSpec("m1", lambda: FixedTTL(60.0), loader=ld,
                                  vram_gb=15.0, home="a100-0"),
                   [7200.0]),
        FleetModel(FleetModelSpec("m2", AlwaysOn, loader=ld, vram_gb=15.0),
                   [10000.0, 10010.0]),
    ]
    sc = FleetScenario(devices=devices, models=models, router="warm-first",
                       horizon_s=DAY)
    res = run_fleet(sc)

    # m2 placement: warm-first falls back to least-loaded = a100 (1 model
    # each, but a100 has less used VRAM at 10000 s since m1 evicted at
    # 7260 s) -> a100 hosts m2's load.
    by_id = {d.instance_id: d for d in res.devices}

    # H100: parked all 24 h (m0 always-on), no loads.
    h = by_id["h100-0"]
    assert h.total_wh == pytest.approx(H100.p_ctx_w * 24.0, rel=1e-9)
    assert h.cold_starts == 1 and h.requests == 1

    # A100 by hand: m1's prewarm arms its 60 s TTL at t=0 so it evicts at
    # 60 s; its 7200 s request cold-starts (30 s load), parks 60 s more,
    # evicts at 7290 s; m2's 10000 s burst loads 30 s then parks forever.
    expected_a = (A100.p_ctx_w * 60.0                     # m1 warm
                  + A100.p_base_w * (7200.0 - 60.0)       # evicted
                  + ld.p_load_w * 30.0                    # m1 reload
                  + A100.p_ctx_w * 60.0                   # m1 warm again
                  + A100.p_base_w * (10000.0 - 7290.0)    # evicted
                  + ld.p_load_w * 30.0                    # m2 load
                  + A100.p_ctx_w * (DAY - 10030.0)) / 3600.0
    a = by_id["a100-0"]
    assert a.total_wh == pytest.approx(expected_a, abs=1e-6)
    assert a.cold_starts == 3                   # m1 prewarm+reload, m2 load
    assert a.requests == 3
    # m1's request waited its 30 s reload; m2's first request waited out
    # the 30 s load and the second (inside the load window) the residual
    # 20 s.
    assert res.added_latency_s_total == pytest.approx(30.0 + 30.0 + 20.0,
                                                      abs=1e-9)

    assert res.energy_wh == pytest.approx(h.total_wh + a.total_wh, rel=1e-12)
    assert res.migrations == 0


def test_prewarm_respects_capacity():
    """An over-committed home falls back to a device that fits; with no
    fitting device the model simply starts cold."""
    devices = build_fleet("l40s+h100")          # 48 GB + 80 GB
    models = [
        FleetModel(FleetModelSpec("a", AlwaysOn, loader=QWEN25_7B_MEASURED,
                                  vram_gb=30.0, home="l40s-0"), [100.0]),
        FleetModel(FleetModelSpec("b", AlwaysOn, loader=QWEN25_7B_MEASURED,
                                  vram_gb=44.0, home="l40s-0"), [200.0]),
        FleetModel(FleetModelSpec("c", AlwaysOn, loader=QWEN25_7B_MEASURED,
                                  vram_gb=200.0, home="l40s-0"), []),
    ]
    res = run_fleet(FleetScenario(devices=devices, models=models,
                                  horizon_s=3600.0))
    by_id = {d.instance_id: d for d in res.devices}
    assert by_id["l40s-0"].resident == ["a"]    # b spilled to the h100
    assert by_id["h100-0"].resident == ["b"]    # c fits nowhere: cold
    assert res.cold_starts == 2                 # the two prewarms only


def test_unload_refuses_in_flight_load():
    devices = build_fleet("h100")
    cluster = Cluster(devices)
    cluster.register_model(FleetModelSpec("m", AlwaysOn,
                                          loader=QWEN25_7B_MEASURED,
                                          vram_gb=5.0))
    cluster.start_load("h100-0", "m")
    with pytest.raises(RuntimeError, match="load in flight"):
        cluster.managers["h100-0"].unload("m")
    cluster.clock.advance(QWEN25_7B_MEASURED.t_load_s)
    cluster.finish_load("h100-0", "m")
    assert cluster.managers["h100-0"].unload("m")


def test_migration_counts_and_export_hooks():
    """ModelManager unload/export hooks used by migration behave."""
    devices = build_fleet("h100+a100")
    cluster = Cluster(devices)
    spec = FleetModelSpec(model_id="m", policy_factory=AlwaysOn,
                          loader=QWEN25_7B_MEASURED, vram_gb=10.0)
    cluster.register_model(spec)
    cluster.replica("h100-0", "m")
    cluster.managers["h100-0"].prewarm("m")
    assert cluster.locations("m") == ["h100-0"]
    dt = cluster.start_migration("m", "h100-0", "a100-0")
    assert dt == pytest.approx(QWEN25_7B_MEASURED.t_load_s)
    cluster.clock.advance(dt)
    cluster.finish_load("a100-0", "m")
    assert cluster.locations("m") == ["a100-0"]
    assert not cluster.context_on("h100-0")     # fell back to bare
    assert cluster.managers["h100-0"].meter.state == "bare"
    assert cluster.migrations == 1
    # export hook removes the registry entry entirely
    rec = cluster.managers["a100-0"].export_model("m")
    assert rec.model_id == "m" and not rec.resident
    assert "m" not in cluster.managers["a100-0"].models


# ---------------------------------------------------------------------------
# concurrent device runtime (slots, service-time model, SLO routing)
# ---------------------------------------------------------------------------

def test_multi_slot_runtime_still_matches_simulator():
    """Regression pin: the refactored multi-slot runtime with
    service_s=0-equivalent settings (explicit ConstantServiceTime(0),
    8 decode slots) still reproduces core/simulator.py on 1 device x
    1 model to <=1e-6 Wh."""
    for pattern in ("bursty", "mmpp"):
        arr = traffic.PATTERNS[pattern](seed=7)
        sim = simulate(arr, FixedTTL(300.0), H100, PYTORCH_70B)
        sc = single_device_scenario(arr, lambda: FixedTTL(300.0),
                                    PYTORCH_70B, "h100", max_batch=8)
        sc.service_model = ConstantServiceTime(0.0)
        res = run_fleet(sc)
        assert res.energy_wh == pytest.approx(sim.energy_wh, abs=1e-6)
        assert res.cold_starts == sim.cold_starts
        assert res.added_latency_s_total == \
            pytest.approx(sim.added_latency_s_total, abs=1e-6)


def test_concurrent_decode_compresses_busy_time():
    """Two simultaneous arrivals with max_batch=2 decode concurrently:
    the busy window halves, the TTL re-arms earlier, and the device
    falls to bare sooner -- checkable by hand to 1e-9 Wh."""
    def scenario(max_batch):
        devices = build_fleet("h100")
        m = FleetModel(FleetModelSpec("m", lambda: FixedTTL(200.0),
                                      loader=QWEN25_7B_MEASURED,
                                      vram_gb=5.0, home="h100-0"),
                       [100.0, 100.0])
        return FleetScenario(devices=devices, models=[m],
                             horizon_s=3600.0, service_s=10.0,
                             max_batch=max_batch)

    p_serve = H100.active_power_w(0.6)
    serial = run_fleet(scenario(1))
    # serialized: serve 100..110, 110..120; evict at 120+200
    expected = (H100.p_ctx_w * 100.0 + p_serve * 20.0
                + H100.p_ctx_w * 200.0
                + H100.p_base_w * (3600.0 - 320.0)) / 3600.0
    assert serial.energy_wh == pytest.approx(expected, abs=1e-9)
    assert serial.added_latency_s_total == pytest.approx(10.0, abs=1e-9)

    conc = run_fleet(scenario(2))
    # concurrent: both serve 100..110 at p_ctx + 2*(p_serve - p_ctx)
    # (each busy slot adds its above-context increment); evict at 310
    expected = (H100.p_ctx_w * 100.0
                + (H100.p_ctx_w + 2 * (p_serve - H100.p_ctx_w)) * 10.0
                + H100.p_ctx_w * 200.0
                + H100.p_base_w * (3600.0 - 310.0)) / 3600.0
    assert conc.energy_wh == pytest.approx(expected, abs=1e-9)
    assert conc.added_latency_s_total == 0.0
    assert conc.energy_wh < serial.energy_wh


def test_latency_samples_consistent_with_totals():
    sc = _mixed_scenario(Breakeven, "energy-greedy")
    sc.service_model = RooflineServiceTime()
    res = run_fleet(sc)
    assert len(res.latencies_s) == res.requests
    assert sum(res.latencies_s) == pytest.approx(res.added_latency_s_total,
                                                 rel=1e-9)
    assert 0.0 <= res.p50_added_latency_s <= res.p99_added_latency_s
    assert res.requests_per_s == pytest.approx(res.requests / res.horizon_s)


def test_savings_vs_zero_energy_baseline_is_guarded():
    res = run_fleet(_mixed_scenario(AlwaysOn, "warm-first", n_models=2))
    degenerate = dataclasses.replace(res, energy_wh=0.0)
    assert res.savings_vs(degenerate) == 0.0      # no inf / ZeroDivision


def test_roofline_service_times_are_occupancy_dependent():
    """Calibration band + monotonicity: per-request time grows (gently)
    with batch while aggregate throughput scales; H100 decodes a
    7B-class model at 100-400 tok/s/slot (published band)."""
    svc = RooflineServiceTime()
    spec = FleetModelSpec("m", AlwaysOn,
                          checkpoint_bytes=int(14.9 * GB), vram_gb=16.0)
    h100, l40s = build_fleet("h100+l40s")
    t1 = svc.request_service_s(spec, h100, 1)
    t4 = svc.request_service_s(spec, h100, 4)
    assert 0.0 < t1 < t4                 # fuller batch: slower steps...
    tput1 = svc.decode_tokens_per_s(spec, h100, 1)
    tput4 = svc.decode_tokens_per_s(spec, h100, 4)
    assert tput4 > 3.0 * tput1           # ...but ~linear token throughput
    assert 100.0 < tput1 < 400.0         # H100 7B single-stream band
    assert svc.request_service_s(spec, l40s, 1) > t1   # slower SKU
    # exact ArchConfig-derived profiles plug into the same model
    msp = ModelServiceProfile("m7b", weight_bytes=14.9 * GB,
                              flops_per_token=2 * 7.6e9,
                              kv_bytes_per_token=57_344.0)
    spec_exact = FleetModelSpec("m7b", AlwaysOn, checkpoint_bytes=1,
                                service=msp)
    t_exact = svc.request_service_s(spec_exact, h100, 1)
    assert t_exact == pytest.approx(t1, rel=0.15)


def test_slo_router_prefers_fast_loader_for_cold_route():
    """A cold 36.5 GB model loads in ~73 s on H100 vs ~94 s on L40S:
    with an 80 s budget only the H100 fits, whatever the joule score."""
    devices = build_fleet("l40s+h100")
    cluster = Cluster(devices)
    spec = FleetModelSpec("big", AlwaysOn,
                          checkpoint_bytes=int(36.5 * GB), vram_gb=40.0)
    cluster.register_model(spec)
    cluster.rates["big"].observe(0.0)
    t_h = cluster.loader_for("big", "h100-0").t_load_s
    t_l = cluster.loader_for("big", "l40s-0").t_load_s
    assert t_h < 80.0 < t_l
    assert SLOAwareRouter(budget_s=80.0).choose("big", 0.0, cluster) \
        == "h100-0"
    # generous budget: energy scoring takes over again
    generous = SLOAwareRouter(budget_s=10 * t_l)
    eg = get_router("energy-greedy")
    assert generous.choose("big", 0.0, cluster) == \
        eg.choose("big", 0.0, cluster)


def test_slo_estimate_counts_own_queued_load_once():
    """A cold model whose load is already queued behind an in-flight
    load must be estimated at residual + its own t_load -- not with its
    queued load double-counted via the backlog (regression)."""
    devices = build_fleet("h100")
    cluster = Cluster(devices)
    for mid in ("other", "big"):
        cluster.register_model(FleetModelSpec(
            mid, AlwaysOn, checkpoint_bytes=int(10 * GB), vram_gb=11.0))
    rt = DeviceRuntime(max_batch=4)
    cluster.attach_runtime({"h100-0": rt}, ConstantServiceTime(0.0))
    rt.loading = "other"
    rt.loading_until = 50.0
    rt.load_q.append(("load", "big"))
    rt.load_queued.add("big")
    t_big = cluster.loader_for("big", "h100-0").t_load_s
    est = SLOAwareRouter(300.0).estimated_wait_s("big", "h100-0", 0.0,
                                                 cluster)
    assert est == pytest.approx(50.0 + t_big, abs=1e-9)


def test_roofline_rejects_sku_without_throughput_numbers():
    """A SKU built without tflops_bf16 (default 0.0) must fail with a
    clear error at the service model, not a ZeroDivisionError."""
    sku = dataclasses.replace(get_sku("h100"), tflops_bf16=0.0)
    dev = build_fleet("h100")[0]
    dev = dataclasses.replace(dev, sku=sku)
    spec = FleetModelSpec("m", AlwaysOn, checkpoint_bytes=GB, vram_gb=1.0)
    with pytest.raises(ValueError, match="throughput numbers"):
        RooflineServiceTime().request_service_s(spec, dev, 1)


def test_slo_router_meets_budget_on_mixed_scenario():
    """Acceptance: on the 10-model x 6-GPU scenario with roofline
    service times, slo-aware meets its p99 budget while staying within
    10% of energy-greedy's joules."""
    svc = RooflineServiceTime()
    budget = 90.0
    eg = run_fleet(mixed_fleet_scenario(Breakeven, "energy-greedy",
                                        service_model=svc))
    slo = run_fleet(mixed_fleet_scenario(Breakeven, SLOAwareRouter(budget),
                                         service_model=svc))
    assert slo.p99_added_latency_s <= budget
    assert eg.p99_added_latency_s > budget         # budget actually binds
    assert abs(slo.energy_wh / eg.energy_wh - 1.0) <= 0.10


def test_single_device_equivalence_survives_autoscaler():
    """Acceptance anchor (ISSUE 3): 1 device x 1 model with the
    autoscaler ENABLED still reproduces core/simulator.py to 1e-6 Wh --
    a single route on a single device must never scale."""
    for pattern in ("bursty", "mmpp"):
        arr = traffic.PATTERNS[pattern](seed=7)
        sim = simulate(arr, FixedTTL(300.0), H100, PYTORCH_70B)
        res = run_fleet(single_device_scenario(
            arr, lambda: FixedTTL(300.0), PYTORCH_70B, "h100",
            autoscaler=ReplicaAutoscaler(tick_s=60.0, cooldown_s=60.0,
                                         pressure_hi=0.25)))
        assert res.energy_wh == pytest.approx(sim.energy_wh, abs=1e-6)
        assert res.cold_starts == sim.cold_starts
        assert res.scale_outs == 0 and res.scale_ins == 0
        assert res.peak_replicas() <= 1


def test_autoscaled_slo_improves_p99_at_pinned_energy_delta():
    """Acceptance (ISSUE 3): on the 10-model x 6-GPU day with roofline
    service times, autoscaled SLO-aware routing buys a double-digit p99
    improvement over single-replica SLO-aware for a bounded energy
    premium -- the over-provisioning parking tax, visible as a strict
    parking_tax_wh increase.  (Measured at seed 100: p99 78.0 -> 62.9 s,
    +17.6% Wh, parking tax 594 -> 2111 Wh.)"""
    svc = RooflineServiceTime()
    single = run_fleet(mixed_fleet_scenario(
        Breakeven, SLOAwareRouter(90.0), service_model=svc, seed=100))
    auto = run_fleet(mixed_fleet_scenario(
        Breakeven, SLOAwareRouter(90.0), service_model=svc, seed=100,
        autoscaler=ReplicaAutoscaler()))
    assert auto.p99_added_latency_s <= single.p99_added_latency_s - 10.0
    assert auto.cold_starts < single.cold_starts
    assert auto.scale_outs > 0 and auto.peak_replicas() >= 2
    # pinned energy band: the tax is real but bounded
    delta = auto.energy_wh / single.energy_wh - 1.0
    assert 0.05 <= delta <= 0.25
    assert auto.parking_tax_wh > single.parking_tax_wh


def test_device_runtime_invariants():
    rt = DeviceRuntime(max_batch=2)
    assert not rt.busy
    p = rt.pool("m")
    s0, s1 = p.acquire(), p.acquire()
    assert (s0, s1) == (0, 1) and p.full and p.acquire() is None
    assert rt.busy_slots() == 2 and rt.busy
    p.release(s0)
    assert p.acquire() == 0                       # lowest-free reuse
    p.release(0)
    with pytest.raises(ValueError):
        p.release(0)                              # double release
    rt.wait_q("m").append(1.0)
    assert rt.waiting_count("m") == 1 and rt.waiting_count() == 1
