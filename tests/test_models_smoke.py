"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs forward/train + prefill/decode on CPU,
asserting output shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.models import (RunFlags, build_cache_specs, build_param_specs,
                          decode_step, materialize, prefill, train_loss)

FLAGS = RunFlags(remat="none")

# Tier-1 compiles three representative families (encoder-decoder dense,
# GQA dense, MoE+sliding-window); the remaining archs are the same code
# paths with different hyperparameters and run under `-m slow`.
FAST_ARCHS = ("whisper-base", "qwen2-5-7b", "mixtral-8x22b")
SMOKE_ARCHS = [a if a in FAST_ARCHS
               else pytest.param(a, marks=pytest.mark.slow)
               for a in ARCHS]


def _batch(cfg, key, b=2, s=16):
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    lab = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": lab}
    if cfg.encoder is not None:
        batch["source_embeds"] = 0.01 * jax.random.normal(
            key, (b, cfg.encoder.source_len, cfg.d_model))
    if cfg.n_prefix_embeddings:
        batch["prefix_embeds"] = 0.01 * jax.random.normal(
            key, (b, cfg.n_prefix_embeddings, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.total_layers == cfg.n_layers
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_reduced_smoke_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = materialize(build_param_specs(cfg), key)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(p, batch, cfg, FLAGS))(params)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_reduced_smoke_prefill_decode(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = materialize(build_param_specs(cfg), key)
    B, S = 2, 8
    batch = _batch(cfg, key, B, S)
    batch.pop("labels")
    cache_len = S + 4 + cfg.n_prefix_embeddings
    caches = materialize(build_cache_specs(cfg, B, cache_len, jnp.float32),
                         key)
    logits, caches = prefill(params, batch, caches, cfg, FLAGS)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    pos = S + cfg.n_prefix_embeddings
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches = decode_step(params, tok, caches, jnp.int32(pos), cfg,
                                  FLAGS)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), arch


def test_decode_matches_teacher_forcing():
    """Greedy decode logits must match the train-mode forward pass run on
    the same (prompt + generated) tokens: the cache path is consistent."""
    from repro.models.model import _prepare_inputs, _run_groups, build_meta
    from repro.models.layers import rmsnorm, unembed

    cfg = get_reduced("granite-20b")
    key = jax.random.PRNGKey(0)
    params = materialize(build_param_specs(cfg), key)
    B, S = 1, 6
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    caches = materialize(build_cache_specs(cfg, B, S + 3, jnp.float32), key)
    logits, caches = prefill(params, {"tokens": tok}, caches, cfg, FLAGS)
    t1 = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits_dec, _ = decode_step(params, t1, caches, jnp.int32(S), cfg, FLAGS)

    # oracle: run train-mode forward on [tok, t1] and take last logits
    full = jnp.concatenate([tok, t1], axis=1)
    x, positions, _ = _prepare_inputs(params, cfg, {"tokens": full})
    h, _, _ = _run_groups(params, cfg.groups, cfg, x, positions,
                          build_meta(cfg), mode="train", flags=FLAGS)
    h = rmsnorm(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
    want = unembed(params["embed"], h, cfg)[:, 0, :]
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_far_tokens():
    """A windowed arch must ignore tokens beyond the window."""
    import dataclasses
    cfg = get_reduced("mixtral-8x22b")          # window=8 in reduced
    key = jax.random.PRNGKey(0)
    params = materialize(build_param_specs(cfg), key)
    S = 16
    t1 = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[0, 0] + 7) % cfg.vocab_size)  # differ @ pos 0
    def last_logits(t):
        b = {"tokens": t, "labels": t}
        from repro.models.model import _prepare_inputs, _run_groups, \
            build_meta
        from repro.models.layers import rmsnorm, unembed
        x, pos, _ = _prepare_inputs(params, cfg, b)
        h, _, _ = _run_groups(params, cfg.groups, cfg, x, pos,
                              build_meta(cfg), mode="train", flags=FLAGS)
        h = rmsnorm(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
        return unembed(params["embed"], h, cfg)[:, 0, :]
    # position 0 is outside every layer's window of the last position
    # (window 8, 2 layers -> receptive field 16 > 15? No: receptive field
    # grows by window-1 per layer: 2 layers x 7 = 14 < 15) -> independent
    a, b = last_logits(t1), last_logits(t2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_moe_onehot_matches_dense_at_high_capacity():
    """With capacity >= S*k/E guaranteed no drops, onehot == dense."""
    import dataclasses
    from repro.models.moe import moe_ffn, moe_specs
    from repro.models.config import MoEConfig
    cfg = dataclasses.replace(
        get_reduced("mixtral-8x22b"),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=4.0))
    key = jax.random.PRNGKey(0)
    p = materialize(moe_specs(cfg), key)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y1, aux1 = moe_ffn(p, x, cfg, impl="onehot")
    y2, aux2 = moe_ffn(p, x, cfg, impl="dense")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_moe_grouping_matches_ungrouped_at_high_capacity():
    """Dispatch grouping (the section-Perf mixtral win) is semantics-
    preserving when capacity guarantees no drops."""
    import dataclasses
    from repro.models.moe import moe_ffn, moe_specs
    from repro.models.config import MoEConfig
    cfg = dataclasses.replace(
        get_reduced("mixtral-8x22b"),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    p = materialize(moe_specs(cfg), key)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    y1, _ = moe_ffn(p, x, cfg, impl="onehot")
    y2, _ = moe_ffn(p, x, cfg, impl="onehot", group_size=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_int8_kv_cache_decode_close_to_bf16():
    """int8 KV cache (section-Perf decode win): per-(token,head) scales
    keep decode logits argmax-identical on the reduced config."""
    cfg = get_reduced("command-r-35b")
    key = jax.random.PRNGKey(0)
    params = materialize(build_param_specs(cfg), key)
    B, S = 2, 8
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    c16 = materialize(build_cache_specs(cfg, B, S + 2, jnp.float32), key)
    c8 = materialize(build_cache_specs(cfg, B, S + 2, jnp.int8), key)
    l16, c16 = prefill(params, {"tokens": tok}, c16, cfg, FLAGS)
    l8, c8 = prefill(params, {"tokens": tok}, c8, cfg, FLAGS)
    t = jnp.argmax(l16, -1)[:, None].astype(jnp.int32)
    d16, _ = decode_step(params, t, c16, jnp.int32(S), cfg, FLAGS)
    d8, _ = decode_step(params, t, c8, jnp.int32(S), cfg, FLAGS)
    corr = np.corrcoef(np.asarray(d16).ravel(),
                       np.asarray(d8).ravel())[0, 1]
    assert corr > 0.995
    assert (jnp.argmax(d16, -1) == jnp.argmax(d8, -1)).all()


@pytest.mark.slow
def test_materialize_is_process_stable():
    """Init keys must not depend on Python's salted hash(): a leaf's
    value is a pure function of (seed, path) -- crc32-derived."""
    import subprocess, sys
    code = (
        "import jax, numpy as np;"
        "from repro.configs import get_reduced;"
        "from repro.models import build_param_specs, materialize;"
        "cfg = get_reduced('granite-20b');"
        "p = materialize(build_param_specs(cfg), jax.random.PRNGKey(0));"
        "leaf = jax.tree_util.tree_leaves(p)[3];"
        "print(float(np.asarray(leaf).ravel()[0]))")
    import os
    outs = set()
    for seed_env in ("1", "2"):
        # keep JAX_PLATFORMS: without it jax's platform discovery probes
        # for accelerators in the bare subprocess env and hangs; keep
        # XLA_FLAGS so the child compiles as cheaply as the parent
        env = {"PYTHONPATH": "src", "PYTHONHASHSEED": seed_env,
               "PATH": "/usr/bin:/bin",
               "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
               "XLA_FLAGS": os.environ.get(
                   "XLA_FLAGS", "--xla_backend_optimization_level=0")}
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, timeout=300,
                           env=env, cwd="/root/repo")
        assert r.returncode == 0, r.stderr
        outs.add(r.stdout.strip())
    assert len(outs) == 1, f"init differs across processes: {outs}"

