"""Per-device electricity zones + follow-the-sun placement.

The tentpole contract (docs/CARBON.md, "Per-device zones"):

* ``"sku@ZONE"`` fleet-spec parts pin devices to a zone; zone-less
  parts inherit the scenario zone, so every pre-zone spec parses
  unchanged;
* a uniform per-device-zone fleet IS the scenario-zone fleet: the
  pinned 10-model x 6-GPU seed-100 day reproduces bit-exactly (energy,
  carbon, p99) under ``run_fleet`` AND both ``run_mega`` backends, and
  the all-devices-in-zone-Z total matches the scenario-zone-Z total to
  1e-9 kg -- the single-resolver guarantee
  (``carbon.resolve_zone_trace`` is the only zone->trace owner);
* zone decompositions (``zone_energy_wh`` / ``zone_carbon_kg``) fsum
  back to the global totals for ANY zone assignment (property test);
* ``CarbonTrace.shifted`` realizes each zone's local solar day on the
  shared sim clock (mean-preserving, identity at zero/whole-period
  shift);
* cross-zone migrations pay the WAN checkpoint transfer: latency
  stretches the returned load duration (threads into p99), energy
  accrues to ``Cluster.transfer_j``;
* the payoff: on the seeded 3-zone day, zone-aware carbon routing +
  consolidation lands strictly below zone-blind in kgCO2e at the
  pinned p99 bound.
"""
import dataclasses
import math

import pytest

from repro.core.scheduler import Breakeven
from repro.fleet import (CarbonAwareRouter, Cluster, Consolidator,
                         FleetModelSpec, MIXES, build_fleet, flat_trace,
                         get_mix, make_trace, mixed_fleet_scenario,
                         resolve_zone_trace, run_fleet, run_mega,
                         trace_for_zone, transfer_cost_j, transfer_latency_s,
                         zone_hops)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, st

# pinned 3-zone fleet spec, seed, and latency bound live in conftest.py
# (shared with test_mega / test_pricing)
from conftest import P99_BOUND_S, PIN_SEED, ZONES3


class TestSpecParsing:
    """``sku@ZONE`` grammar on both build_fleet input shapes."""

    def test_string_spec_zone_suffix(self):
        devs = build_fleet("2xh100@DEU+1xa100@USA+l40s")
        assert [d.zone for d in devs] == ["DEU", "DEU", "USA", None]
        assert [d.instance_id for d in devs] == \
               ["h100-0", "h100-1", "a100-0", "l40s-0"]

    def test_sequence_spec_zone_suffix(self):
        devs = build_fleet(["h100@ind", "a100"])
        assert devs[0].zone == "IND"        # canonicalized via get_mix
        assert devs[1].zone is None

    def test_zoneless_spec_parses_unchanged(self):
        old = build_fleet("2xh100+2xa100+2xl40s")
        assert all(d.zone is None for d in old)
        assert [d.instance_id for d in old] == \
               ["h100-0", "h100-1", "a100-0", "a100-1", "l40s-0", "l40s-1"]

    def test_unknown_zone_raises(self):
        with pytest.raises(KeyError, match="unknown electricity mix"):
            build_fleet("h100@ATLANTIS")

    def test_scenario_zone_fills_blanks(self):
        sc = mixed_fleet_scenario(Breakeven, "warm-first",
                                  fleet="h100@DEU+a100", zone="IND")
        zones = sc.device_zones()
        assert zones["h100-0"] == "DEU" and zones["a100-0"] == "IND"


class TestShiftedTrace:
    """Zone tz offsets realize local solar days on the sim clock."""

    def test_zero_shift_is_identity_object(self):
        tr = make_trace("solar-duck", 0.4)
        assert tr.shifted(0.0) is tr
        assert tr.shifted(tr.period_s) is tr       # whole period wraps

    def test_flat_trace_shift_is_identity(self):
        fl = flat_trace(0.3)
        assert fl.shifted(7 * 3600.0) is fl

    def test_shift_moves_the_clock(self):
        tr = make_trace("solar-duck", 0.4)
        dt = 7 * 3600.0
        sh = tr.shifted(dt)
        for t in (0.0, 3 * 3600.0, 11.25 * 3600.0, 23 * 3600.0):
            assert sh.intensity_at(t) == pytest.approx(
                tr.intensity_at(t + dt), rel=1e-9, abs=1e-12)

    def test_shift_preserves_daily_mean(self):
        tr = make_trace("solar-duck", 0.4)
        sh = tr.shifted(11.5 * 3600.0)
        assert sh.daily_mean_kg_per_kwh == pytest.approx(
            tr.daily_mean_kg_per_kwh, rel=1e-9)

    def test_usa_trace_is_unshifted(self):
        # the sim clock IS US local time: the default zone's preset
        # trace must be exactly the catalog shape (tz_offset 0)
        usa = trace_for_zone("USA")
        raw = make_trace("solar-duck", get_mix("USA").gwp_kg_per_kwh)
        assert usa.points == raw.points

    def test_zone_traces_trough_at_local_noon(self):
        # DEU (UTC+1-ish vs the US sim clock): solar trough lands
        # 7 simulated hours earlier than the USA trough
        deu = trace_for_zone("DEU")
        usa_shape = make_trace("solar-duck", get_mix("DEU").gwp_kg_per_kwh)
        assert deu.intensity_at(6 * 3600.0) == pytest.approx(
            usa_shape.intensity_at(13 * 3600.0), rel=1e-9)


class TestResolver:
    """carbon.resolve_zone_trace: the one zone->trace owner."""

    def test_none_resolves_flat_at_zone_mean(self):
        for z in sorted(MIXES):
            tr = resolve_zone_trace(z)
            assert tr.is_flat
            assert tr.daily_mean_kg_per_kwh == pytest.approx(
                get_mix(z).gwp_kg_per_kwh, rel=1e-12)

    def test_zone_keyword_resolves_preset(self):
        tr = resolve_zone_trace("DEU", "zone")
        assert tr.points == trace_for_zone("DEU").points

    def test_shape_name_resolves_at_zone_mean(self):
        tr = resolve_zone_trace("IND", "solar-duck")
        assert tr.daily_mean_kg_per_kwh == pytest.approx(
            get_mix("IND").gwp_kg_per_kwh, rel=1e-9)

    def test_explicit_trace_passes_through_for_home_zone(self):
        ct = make_trace("solar-duck", 0.123)
        assert resolve_zone_trace("USA", ct) is ct
        assert resolve_zone_trace("USA", ct, scenario_zone="USA") is ct

    def test_explicit_trace_rescales_for_foreign_zone(self):
        ct = make_trace("solar-duck", 0.123)
        got = resolve_zone_trace("SWE", ct, scenario_zone="USA")
        assert got.daily_mean_kg_per_kwh == pytest.approx(
            get_mix("SWE").gwp_kg_per_kwh, rel=1e-9)

    def test_device_traces_share_scenario_object_in_home_zone(self):
        sc = mixed_fleet_scenario(Breakeven, "warm-first",
                                  carbon_trace="zone", zone="USA")
        resolved = sc.resolved_carbon_trace()
        per_dev = sc.device_carbon_traces(resolved)
        assert all(tr is resolved for tr in per_dev.values())


class TestTransferModel:
    """Cross-zone WAN checkpoint-shipping costs."""

    def test_hops(self):
        assert zone_hops("USA", "usa") == 0
        assert zone_hops("DEU", "FRA") == 1       # same region (EU)
        assert zone_hops("DEU", "USA") == 2
        assert zone_hops("WOR", "USA") == 2       # GLOBAL never adjacent

    def test_costs_scale_with_gb_and_hops(self):
        assert transfer_cost_j(10.0, "USA", "USA") == 0.0
        assert transfer_latency_s(10.0, "USA", "USA") == 0.0
        assert transfer_cost_j(10.0, "DEU", "USA") == \
            2 * transfer_cost_j(10.0, "DEU", "FRA")
        assert transfer_latency_s(4.0, "DEU", "USA") == \
            2 * transfer_latency_s(2.0, "DEU", "USA")

    def test_cross_zone_migration_accounting(self):
        devices = build_fleet("h100@DEU+h100@USA")
        c = Cluster(devices)
        c.device_zones = {d.instance_id: d.zone for d in devices}
        gb = 8.0
        c.register_model(FleetModelSpec(
            model_id="m", policy_factory=Breakeven,
            checkpoint_bytes=int(gb * 1024 ** 3), vram_gb=gb * 1.1))
        dt = c.start_load("h100-0", "m")
        c.advance_to(dt)
        c.finish_load("h100-0", "m")
        dur = c.start_migration("m", "h100-0", "h100-1")
        base = c.loader_for("m", "h100-1").t_load_s
        assert dur == base + transfer_latency_s(gb, "DEU", "USA")
        assert c.cross_zone_migrations == 1
        assert c.transfer_j == transfer_cost_j(gb, "DEU", "USA")

    def test_same_zone_migration_costs_nothing_extra(self):
        devices = build_fleet("2xh100@DEU")
        c = Cluster(devices)
        c.device_zones = {d.instance_id: d.zone for d in devices}
        c.register_model(FleetModelSpec(
            model_id="m", policy_factory=Breakeven,
            checkpoint_bytes=8 * 1024 ** 3, vram_gb=9.0))
        dt = c.start_load("h100-0", "m")
        c.advance_to(dt)
        c.finish_load("h100-0", "m")
        dur = c.start_migration("m", "h100-0", "h100-1")
        assert dur == c.loader_for("m", "h100-1").t_load_s
        assert c.cross_zone_migrations == 0 and c.transfer_j == 0.0


def _uniform_zone_fleet(zone: str) -> str:
    return f"2xh100@{zone}+2xa100@{zone}+2xl40s@{zone}"


class TestUniformZoneEquivalence:
    """All-devices-in-zone-Z == scenario-zone-Z: the resolver can never
    disagree with itself, pinned bit-exact on the seed-100 day."""

    @pytest.mark.parametrize("runner", ["fleet", "mega-numpy", "mega-jax"])
    def test_pinned_day_bit_exact(self, runner):
        def go(fleet):
            sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED,
                                      fleet=fleet, zone="DEU",
                                      carbon_trace="zone")
            if runner == "fleet":
                return run_fleet(sc)
            return run_mega(sc, backend=runner.split("-")[1])

        ref = go("2xh100+2xa100+2xl40s")          # scenario zone only
        got = go(_uniform_zone_fleet("DEU"))      # every device pinned
        assert got.energy_wh == ref.energy_wh             # bit-for-bit
        assert got.carbon_kg == ref.carbon_kg
        assert got.carbon_kg_flat == ref.carbon_kg_flat
        assert got.energy_usd == ref.energy_usd
        assert got.carbon_timeline == ref.carbon_timeline
        assert got.p99_added_latency_s == ref.p99_added_latency_s
        assert abs(got.carbon_kg - ref.carbon_kg) <= 1e-9  # issue bound
        assert set(got.zone_carbon_kg) == {"DEU"}
        assert got.zone_carbon_kg["DEU"] == pytest.approx(
            got.carbon_kg, rel=1e-12)
        assert got.zone_energy_wh["DEU"] == pytest.approx(
            got.energy_wh, rel=1e-12)

    def test_multi_zone_day_mega_matches_event_loop(self):
        # warm-first routing is zone-blind, so the mega scope covers the
        # multi-zone day too: per-zone accounting must agree
        def go(runner):
            sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED,
                                      fleet=ZONES3, carbon_trace="zone")
            return run_fleet(sc) if runner == "fleet" \
                else run_mega(sc, backend=runner)

        ref = go("fleet")
        assert set(ref.zone_carbon_kg) == {"DEU", "IND", "USA"}
        for backend in ("numpy", "jax"):
            got = go(backend)
            assert got.energy_wh == pytest.approx(ref.energy_wh, rel=1e-9)
            assert got.carbon_kg == pytest.approx(ref.carbon_kg, rel=1e-9)
            for z in ref.zone_carbon_kg:
                assert got.zone_carbon_kg[z] == pytest.approx(
                    ref.zone_carbon_kg[z], rel=1e-9)
                assert got.zone_energy_wh[z] == pytest.approx(
                    ref.zone_energy_wh[z], rel=1e-9)
            for (t1, c1), (t2, c2) in zip(ref.carbon_timeline,
                                          got.carbon_timeline):
                assert t2 == t1
                assert c2 == pytest.approx(c1, rel=1e-9, abs=1e-12)


class TestZoneDecomposition:
    """zone_energy_wh / zone_carbon_kg fsum back to the globals."""

    @settings(max_examples=5)
    @given(zones=st.lists(st.sampled_from(sorted(MIXES)),
                          min_size=6, max_size=6))
    def test_decomposition_sums_to_totals(self, zones):
        sc = mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED,
                                  horizon_s=6 * 3600.0,
                                  carbon_trace="zone")
        sc.devices[:] = [dataclasses.replace(d, zone=z)
                         for d, z in zip(sc.devices, zones)]
        res = run_fleet(sc)
        assert set(res.zone_carbon_kg) == set(zones)
        assert math.fsum(res.zone_energy_wh.values()) == pytest.approx(
            res.energy_wh, rel=1e-12)
        assert math.fsum(res.zone_carbon_kg.values()) == pytest.approx(
            res.carbon_kg, rel=1e-12)
        for z in set(zones):
            dev_kg = math.fsum(d.carbon_kg for d in res.devices
                               if d.zone == z)
            assert res.zone_carbon_kg[z] == pytest.approx(
                dev_kg, rel=1e-12, abs=1e-15)


class TestDocsExample:
    """docs/CARBON.md "Per-device zones" snippets, executed verbatim."""

    def test_build_fleet_snippet(self):
        devs = build_fleet("2xh100@DEU+1xa100@USA+l40s")
        assert [d.zone for d in devs] == ["DEU", "DEU", "USA", None]

    def test_worked_3zone_snippet(self):
        sc = mixed_fleet_scenario(Breakeven, "warm-first", n_models=4,
                                  fleet="h100@DEU+a100@USA+l40s@IND",
                                  horizon_s=6 * 3600.0, carbon_trace="zone")
        res = run_fleet(sc)
        assert set(res.zone_carbon_kg) == {"DEU", "USA", "IND"}
        assert abs(math.fsum(res.zone_carbon_kg.values())
                   - res.carbon_kg) < 1e-9
        assert abs(math.fsum(res.zone_energy_wh.values())
                   - res.energy_wh) < 1e-6


class TestFollowTheSun:
    """The tentpole payoff: chasing troughs across zones cuts kgCO2e."""

    @staticmethod
    def _run(zone_aware: bool):
        sc = mixed_fleet_scenario(
            Breakeven, CarbonAwareRouter(math.inf, zone_aware=zone_aware),
            consolidate=Consolidator(carbon_aware=True, period_s=300.0),
            fleet=ZONES3, seed=PIN_SEED, carbon_trace="zone", zone="USA")
        return run_fleet(sc)

    def test_zone_aware_beats_zone_blind_at_p99_bound(self):
        aware = self._run(True)
        blind = self._run(False)
        assert aware.carbon_kg < blind.carbon_kg          # strictly below
        assert aware.p99_added_latency_s <= P99_BOUND_S
        assert blind.p99_added_latency_s <= P99_BOUND_S

    def test_transfer_accounting_consistent(self):
        res = self._run(True)
        if res.cross_zone_migrations:
            assert res.transfer_wh > 0.0
        else:
            assert res.transfer_wh == 0.0
        # single-zone fleets can never pay the WAN
        sc = mixed_fleet_scenario(
            Breakeven, CarbonAwareRouter(math.inf),
            consolidate=Consolidator(carbon_aware=True, period_s=300.0),
            seed=PIN_SEED, carbon_trace="solar-duck")
        one = run_fleet(sc)
        assert one.cross_zone_migrations == 0
        assert one.transfer_wh == 0.0
