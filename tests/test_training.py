"""Training substrate: optimizer, compression, checkpoint/resume, loop."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # optional extra: property tests skip, rest run
    from _hypothesis_shim import given, settings, st

from repro.checkpoint import (CheckpointManager, latest_step, restore_pytree,
                              save_pytree)
from repro.configs import get_reduced
from repro.data import DataCursor, SyntheticLMDataset
from repro.training.compression import compress_grads, init_error_state
from repro.training.optimizer import AdamWConfig, adamw_update, lr_at
from repro.training.trainer import TrainConfig, init_state, train


def test_adamw_descends_quadratic():
    """AdamW minimizes a quadratic: ||p - target||^2."""
    target = jnp.array([1.0, -2.0, 3.0])
    p = {"w": jnp.zeros(3)}
    mu = {"w": jnp.zeros(3)}
    nu = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=10_000)
    for step in range(300):
        g = {"w": 2.0 * (p["w"] - target)}
        p, mu, nu, _ = adamw_update(p, g, mu, nu, jnp.int32(step), cfg)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target),
                               atol=0.05)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(jnp.int32(0), cfg)) == 0.0
    assert float(lr_at(jnp.int32(10), cfg)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(jnp.int32(100), cfg)) == pytest.approx(0.1, rel=1e-2)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_compression_error_feedback_bounded(seed):
    """Quantization residual never exceeds half a quantization step."""
    key = jax.random.PRNGKey(seed)
    g = {"a": jax.random.normal(key, (64,)) * 10.0}
    e = init_error_state(g)
    gq, e2 = compress_grads(g, e)
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127.0
    assert float(jnp.max(jnp.abs(e2["a"]))) <= 0.5 * scale + 1e-6


def test_compression_error_feedback_unbiased_sum():
    """Over many steps, compressed updates track the true gradient sum."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(16)
    comp_sum = np.zeros(16)
    e = {"g": jnp.zeros(16)}
    for _ in range(200):
        g = rng.normal(size=16).astype(np.float32)
        true_sum += g
        gq, e = compress_grads({"g": jnp.asarray(g)}, e)
        comp_sum += np.asarray(gq["g"])
    # error feedback keeps the running sums within one quant step
    assert np.max(np.abs(true_sum - comp_sum)) < 0.2


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.int32(7)}}
    save_pytree(tree, tmp_path, 3)
    assert latest_step(tmp_path) == 3
    out = restore_pytree(tree, tmp_path)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert int(out["b"]["c"]) == 7


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.ones(4)}
    for s in (1, 2, 3, 4):
        mgr.save_async(tree, s)
    mgr.close()
    steps = sorted(int(p.name.split("_")[1])
                   for p in pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]


def test_train_resume_bitexact(tmp_path):
    """Crash/restart fault tolerance: 10 straight steps == 5 + resume 5."""
    cfg = get_reduced("granite-20b")
    tc = lambda n, ck: TrainConfig(steps=n, batch_size=2, seq_len=32,
                                   checkpoint_dir=str(ck),
                                   checkpoint_every=5, log_every=100)
    h_full = train(cfg, tc(10, tmp_path / "full"), log_fn=lambda s: None)
    # run 5, then "crash", then resume to 10 in a second call
    train(cfg, tc(5, tmp_path / "resume"), log_fn=lambda s: None)
    h_resumed = train(cfg, tc(10, tmp_path / "resume"), log_fn=lambda s: None)
    np.testing.assert_allclose(h_full["loss"][-1], h_resumed["loss"][-1],
                               rtol=1e-5)


def test_loss_descends_with_grad_accum_and_compression():
    cfg = get_reduced("qwen2-5-7b")
    from repro.models.model import RunFlags
    # schedule sized to the run: the default AdamWConfig warms up over
    # 100 steps, so a 40-step run would never leave the ramp and the
    # descent assertion reduces to noise
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    h = train(cfg, TrainConfig(steps=40, batch_size=4, seq_len=64,
                               grad_compression=True, opt=opt,
                               flags=RunFlags(grad_accum=2),
                               log_every=100), log_fn=lambda s: None)
    assert np.mean(h["loss"][-8:]) < np.mean(h["loss"][:8])


def test_data_pipeline_deterministic_and_resumable():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, batch_size=2, seed=1)
    b5 = ds.batch(5)
    np.testing.assert_array_equal(b5["tokens"], ds.batch(5)["tokens"])
    # labels are next-token shifted
    full = np.concatenate([b5["tokens"][:, :1], b5["labels"]], axis=1)
    np.testing.assert_array_equal(b5["tokens"][:, 1:], full[:, 1:-1])
    # cursor resume yields the same stream
    cur = DataCursor(batch_index=7)
    it = ds.iterate(cur)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], ds.batch(7)["tokens"])
