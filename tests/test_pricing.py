"""Dollar-cost axis: pricing, spot preemption faults, and the planner.

The tentpole contract (docs/COST.md):

* billing semantics: ``on_demand`` / ``spot`` bill only powered-on
  seconds, ``reserved`` bills the whole horizon, and
  ``cost_usd = gpu_hours_usd + energy_usd`` exactly (one addition);
* fault injection: a hand-pinned spot revocation yields the
  hand-computed parked/off/bare second-and-dollar timeline to 1e-9;
* closed forms: a never-sleeping on-demand fleet bills exactly the
  flat ``fleet_price_usd`` quote, and a reserved fleet bills it even
  while gated (the commitment runs through sleep);
* decompositions: the per-device / per-zone dollar dicts fsum back to
  the totals for any fleet x tier x seed (property test, 1e-12 rel);
* preemption: revocations never lose requests (in-flight work
  re-queues and re-places), a preempted run never out-draws the
  always-on ceiling, a zero-rate model leaves every anchor
  bit-unchanged, and ``PreemptionModel.draw`` is pure, per-device
  seeded, and spot-only;
* engines: the pinned seed-100 day yields the identical ``cost_usd``
  under ``run_fleet`` and both ``run_mega`` backends (the ISSUE
  acceptance asks <=1e-9 relative; numpy holds 0.0), and actual fault
  draws make ``run_mega`` refuse loudly;
* planner: frontiers are mutually non-dominated and contain every
  single-objective optimum; on the pinned 3-zone day the frontier
  holds >=3 plans and a spot plan beats all-on-demand on dollars
  within the p99 bound under nonzero preemption.
"""
import dataclasses
import json
import math

import pytest

from repro.core import QWEN25_7B_MEASURED
from repro.core.scheduler import AlwaysOn, Breakeven
from repro.fleet import (CATALOG, Consolidator, FleetModel, FleetModelSpec,
                         FleetScenario, PlanAxes, PreemptionModel, Revocation,
                         UNBILLED_STATES, billed_seconds, build_fleet,
                         device_gpu_usd, device_tier_map, dominates,
                         energy_cost_usd, fleet_price_usd, get_mix,
                         hypervolume, mixed_fleet_scenario, pareto_front,
                         plan_fleet, run_fleet, run_mega)
from repro.fleet.mega.megasim import MegaUnsupportedError
from repro.fleet.planner import (PlanPoint, SPOT_ALL_FLEET, SPOT_H100_FLEET,
                                 pinned_day_axes, pinned_day_base)
from repro.serving import ConstantServiceTime

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, st

from conftest import P99_BOUND_S, PIN_SEED, REL, ZONES3

H6 = 6 * 3600.0


def _point(cost, wh=1.0, kg=1.0, p99=1.0, **kw):
    kw.setdefault("fleet", "f")
    kw.setdefault("router", "r")
    kw.setdefault("price_tier", "on_demand")
    kw.setdefault("preemption_rate", 0.0)
    return PlanPoint(cost_usd=cost, energy_wh=wh, carbon_kg=kg, p99_s=p99,
                     **kw)


class TestBillingSemantics:
    """billed_seconds / device_gpu_usd / device_tier_map hand math."""

    DUR = {"active": 100.0, "loading": 20.0, "bare": 50.0, "parked": 30.0,
           "sleep": 200.0, "off": 30.0}

    def test_usage_tiers_bill_powered_on_only(self):
        for tier in ("on_demand", "spot"):
            assert billed_seconds(self.DUR, tier) == 200.0
        assert set(UNBILLED_STATES) == {"sleep", "off"}

    def test_reserved_bills_everything(self):
        assert billed_seconds(self.DUR, "reserved") == 430.0

    def test_total_key_ignored(self):
        d = dict(self.DUR, total=430.0)
        assert billed_seconds(d, "reserved") == 430.0

    def test_insertion_order_invariant(self):
        fwd = dict(sorted(self.DUR.items()))
        rev = dict(sorted(self.DUR.items(), reverse=True))
        for tier in ("on_demand", "reserved", "spot"):
            assert billed_seconds(fwd, tier) == billed_seconds(rev, tier)

    def test_unknown_tier_raises(self):
        with pytest.raises(KeyError, match="unknown price tier"):
            billed_seconds(self.DUR, "preemptible")

    def test_device_gpu_usd_hand(self):
        dev = build_fleet(["h100"])[0]
        # 200 powered-on seconds at $6.98/hr
        assert device_gpu_usd(dev, self.DUR, "on_demand") == pytest.approx(
            6.98 * 200.0 / 3600.0, rel=1e-12)
        # tier names canonicalize like zones do
        assert device_gpu_usd(dev, self.DUR, "On-Demand") == \
            device_gpu_usd(dev, self.DUR, "on_demand")

    def test_tier_map_inheritance(self):
        devs = build_fleet("h100:spot+a100")
        assert device_tier_map(devs, "reserved") == \
            {"h100-0": "spot", "a100-0": "reserved"}

    def test_catalog_rate_ordering(self):
        # the tier model only makes sense if spot < reserved < on-demand
        for sku in CATALOG.values():
            assert sku.price_usd_per_hr("spot") < \
                sku.price_usd_per_hr("reserved") < \
                sku.price_usd_per_hr("on_demand")


class TestRevocation:
    def test_warning_precedes_off(self):
        rv = Revocation("d", off_at_s=600.0, warning_s=120.0, outage_s=30.0)
        assert rv.warn_at_s == 480.0
        assert rv.restore_at_s == 630.0

    def test_warning_clamps_at_zero(self):
        assert Revocation("d", off_at_s=60.0, warning_s=120.0).warn_at_s \
            == 0.0

    def test_validation(self):
        with pytest.raises(ValueError):
            Revocation("d", off_at_s=-1.0)
        with pytest.raises(ValueError):
            Revocation("d", off_at_s=0.0, outage_s=0.0)
        with pytest.raises(ValueError):
            PreemptionModel(rate_per_device_day=-1.0)


class TestHandPricedFaultTimeline:
    """One pinned revocation, one device: every second hand-priced."""

    def _run(self, arrivals, *, service_s=0.0, fleet=("h100:spot",),
             revoke=(Revocation("h100-0", off_at_s=600.0, warning_s=120.0,
                                outage_s=1800.0),)):
        devices = build_fleet(list(fleet))
        spec = FleetModelSpec(model_id="m0", policy_factory=AlwaysOn,
                              loader=QWEN25_7B_MEASURED, home="h100-0")
        sc = FleetScenario(
            devices=devices, models=[FleetModel(spec, list(arrivals))],
            router="warm-first", horizon_s=3600.0,
            service_model=(ConstantServiceTime(service_s)
                           if service_s else None),
            preemptions=PreemptionModel(schedule=tuple(revoke)))
        return run_fleet(sc)

    def test_dollar_timeline_hand_priced(self):
        # parked 0..600 (AlwaysOn holds the resident), OFF 600..2400
        # (the 1800 s outage), restored BARE 2400..3600 (the orphaned
        # model was dropped by the revocation; nothing reloads it)
        res = self._run([100.0, 200.0])
        r = res.devices[0]
        assert r.durations_s["parked"] == pytest.approx(600.0, abs=1e-9)
        assert r.durations_s["off"] == pytest.approx(1800.0, abs=1e-9)
        assert r.durations_s["bare"] == pytest.approx(1200.0, abs=1e-9)
        # OFF draws nothing; the spot meter bills 1800 powered-on
        # seconds at the h100 spot rate -- $1.45, to 1e-9 USD
        assert r.energy_wh.get("off", 0.0) == 0.0
        spot_hr = CATALOG["h100"].price_usd_per_hr("spot")
        assert res.gpu_hours_usd == pytest.approx(spot_hr * 1800.0 / 3600.0,
                                                  abs=1e-9)
        assert res.device_gpu_usd == {"h100-0": res.gpu_hours_usd}
        assert res.device_tiers == {"h100-0": "spot"}
        # the one-addition identity and the energy leg's tariff
        assert res.cost_usd == res.gpu_hours_usd + res.energy_usd
        assert res.energy_usd == pytest.approx(
            energy_cost_usd(res.energy_wh, get_mix(r.zone)), rel=1e-12)
        assert res.preemptions == 1
        assert res.requests == 2            # both served before the cut

    def test_in_flight_requests_requeue_and_replace(self):
        # arrivals at 580/590 are on the device when the 600 s cut
        # lands: both re-queue, re-place on the surviving on-demand
        # h100, and are served after its cold load -- none are lost
        res = self._run([100.0, 580.0, 590.0], service_s=50.0,
                        fleet=("h100:spot", "h100"))
        assert res.requests == 3
        assert res.requeued_requests == 2
        assert res.preemptions == 1
        assert res.devices[1].requests == 2         # re-placed work
        assert all(x >= 0.0 for x in res.latencies_s)

    def test_schedule_beyond_horizon_is_dropped(self):
        res = self._run([100.0],
                        revoke=(Revocation("h100-0", off_at_s=7200.0),))
        assert res.preemptions == 0
        assert "off" not in res.devices[0].durations_s


class TestClosedForms:
    """Uniform-tier fleets reduce to the flat fleet_price_usd quote."""

    def test_always_on_on_demand_equals_flat_quote(self):
        # no sleep, no off: every metered second is billed, so the
        # metered bill IS the flat quote (the engine meters exactly the
        # horizon: durations fsum to horizon_s per device)
        sc = mixed_fleet_scenario(AlwaysOn, "warm-first", seed=PIN_SEED,
                                  horizon_s=H6)
        res = run_fleet(sc)
        for r in res.devices:
            assert math.fsum(v for k, v in r.durations_s.items()
                             if k != "total") == pytest.approx(H6, abs=1e-6)
        assert res.gpu_hours_usd == pytest.approx(
            fleet_price_usd(sc.devices, H6, "on_demand"), rel=REL)
        assert res.gpu_hours_usd == pytest.approx(res.infra_usd, rel=REL)

    @staticmethod
    def _gated_day(**kw):
        # power gating needs the gating consolidator (test_power_states
        # idiom); without it the pinned day never sleeps
        cons = Consolidator(period_s=300.0, gate_drained_devices=True)
        return mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED,
                                    horizon_s=H6, consolidate=cons, **kw)

    def test_reserved_bills_through_sleep(self):
        sc = dataclasses.replace(self._gated_day(), price_tier="reserved")
        res = run_fleet(sc)
        assert res.gates > 0                    # the day really gated
        assert res.gpu_hours_usd == pytest.approx(
            fleet_price_usd(sc.devices, H6, "reserved"), rel=1e-12)

    def test_gating_saves_dollars_on_usage_tiers(self):
        gated = run_fleet(self._gated_day())
        flat = fleet_price_usd(build_fleet("2xh100+2xa100+2xl40s"), H6)
        assert gated.gates > 0
        # sleep seconds are unbilled: the metered bill lands strictly
        # under the hold-everything-on-demand quote (== infra_usd)
        assert gated.gpu_hours_usd < flat
        assert gated.infra_usd == pytest.approx(flat, rel=1e-12)


class TestDecompositions:
    """device/zone dollar dicts fsum to the totals (any fleet x tier)."""

    @settings(max_examples=6, deadline=None)
    @given(fleet=st.sampled_from(("2xh100+2xa100+2xl40s", ZONES3,
                                  SPOT_H100_FLEET, SPOT_ALL_FLEET)),
           tier=st.sampled_from(("on_demand", "reserved", "spot")),
           seed=st.integers(min_value=0, max_value=2))
    def test_cost_decompositions_fsum(self, fleet, tier, seed):
        sc = dataclasses.replace(
            mixed_fleet_scenario(Breakeven, "warm-first", seed=seed,
                                 horizon_s=H6, fleet=fleet,
                                 carbon_trace="zone"),
            price_tier=tier)
        res = run_fleet(sc)
        assert res.cost_usd == res.gpu_hours_usd + res.energy_usd
        assert math.fsum(res.device_gpu_usd[k]
                         for k in sorted(res.device_gpu_usd)) == \
            pytest.approx(res.gpu_hours_usd, rel=1e-12)
        assert math.fsum(res.device_cost_usd[k]
                         for k in sorted(res.device_cost_usd)) == \
            pytest.approx(res.cost_usd, rel=1e-12)
        assert math.fsum(res.zone_cost_usd[k]
                         for k in sorted(res.zone_cost_usd)) == \
            pytest.approx(res.cost_usd, rel=1e-12)
        assert res.device_tiers == sc.device_tiers()
        # per-part tier pins override the scenario default
        for d in sc.devices:
            want = d.tier or tier
            assert res.device_tiers[d.instance_id] == want

    @settings(max_examples=25, deadline=None)
    @given(secs=st.lists(st.floats(min_value=0.0, max_value=1e5),
                         min_size=6, max_size=6))
    def test_reserved_never_cheaper_seconds(self, secs):
        states = ("active", "loading", "bare", "parked", "sleep", "off")
        dur = dict(zip(states, secs))
        assert billed_seconds(dur, "reserved") >= \
            billed_seconds(dur, "on_demand")
        assert billed_seconds(dur, "on_demand") == \
            billed_seconds(dur, "spot")
        assert billed_seconds(dur, "reserved") == pytest.approx(
            math.fsum(secs), rel=1e-12, abs=1e-12)


class TestPreemptionDraw:
    """PreemptionModel.draw: pure, per-device seeded, spot-only."""

    FLEET = build_fleet(SPOT_ALL_FLEET)
    TIERS = device_tier_map(FLEET)

    def _model(self, rate=4.0, **kw):
        kw.setdefault("outage_s", 3600.0)
        return PreemptionModel(rate_per_device_day=rate, **kw)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=50),
           rate=st.sampled_from((0.5, 2.0, 8.0)))
    def test_draw_is_pure(self, seed, rate):
        m = self._model(rate, seed=seed)
        a = m.draw(self.FLEET, self.TIERS, 86400.0)
        b = m.draw(self.FLEET, self.TIERS, 86400.0)
        assert a == b

    def test_only_spot_devices_revoked(self):
        devs = build_fleet("2xh100:spot+2xa100")
        tiers = device_tier_map(devs)
        evs = self._model(50.0).draw(devs, tiers, 86400.0)
        assert evs                               # rate 50/day: some fire
        assert {e.device_id for e in evs} <= {"h100-0", "h100-1"}

    def test_adding_a_device_never_reshuffles(self):
        # per-device seeding: h100-0's fault times are a function of
        # (seed, its id) only, not of who else is in the fleet
        small = build_fleet(["h100:spot"])
        big = build_fleet("h100:spot+4xa100:spot")
        m = self._model(8.0, seed=7)
        t_small = [e.off_at_s for e in
                   m.draw(small, device_tier_map(small), 86400.0)
                   if e.device_id == "h100-0"]
        t_big = [e.off_at_s for e in
                 m.draw(big, device_tier_map(big), 86400.0)
                 if e.device_id == "h100-0"]
        assert t_small == t_big

    def test_outages_never_overlap_per_device(self):
        evs = self._model(40.0, seed=3).draw(self.FLEET, self.TIERS, 86400.0)
        by_dev = {}
        for e in evs:
            assert 0.0 <= e.off_at_s < 86400.0
            by_dev.setdefault(e.device_id, []).append(e)
        assert any(len(v) > 1 for v in by_dev.values())
        for v in by_dev.values():
            for prev, nxt in zip(v, v[1:]):
                assert nxt.off_at_s > prev.restore_at_s

    def test_infinite_outage_revokes_once(self):
        evs = PreemptionModel(rate_per_device_day=40.0).draw(
            self.FLEET, self.TIERS, 86400.0)
        per_dev = [e.device_id for e in evs]
        assert len(per_dev) == len(set(per_dev))

    def test_zero_rate_draws_nothing(self):
        assert PreemptionModel().draw(self.FLEET, self.TIERS, 86400.0) == []

    def test_schedule_short_circuits_sorted_and_clipped(self):
        m = PreemptionModel(schedule=(
            Revocation("b", off_at_s=50.0), Revocation("a", off_at_s=50.0),
            Revocation("a", off_at_s=99.0), Revocation("a", off_at_s=100.0)))
        evs = m.draw(self.FLEET, self.TIERS, 100.0)
        assert [(e.device_id, e.off_at_s) for e in evs] == \
            [("a", 50.0), ("b", 50.0), ("a", 99.0)]


class TestConservationAndEnergy:
    """Faults shed energy and dollars but never requests."""

    def _spot_day(self, rate, *, service=True):
        pre = (PreemptionModel(rate_per_device_day=rate, warning_s=120.0,
                               outage_s=4 * 3600.0, seed=0)
               if rate > 0.0 else None)
        sc = mixed_fleet_scenario(
            Breakeven, "warm-first", fleet=SPOT_H100_FLEET, seed=PIN_SEED,
            horizon_s=H6, carbon_trace="zone",
            service_model=ConstantServiceTime(2.0) if service else None)
        return dataclasses.replace(sc, preemptions=pre)

    @settings(max_examples=3, deadline=None)
    @given(rate=st.sampled_from((2.0, 8.0, 24.0)))
    def test_preemption_conserves_requests(self, rate):
        base = run_fleet(self._spot_day(0.0))
        res = run_fleet(self._spot_day(rate))
        assert res.preemptions > 0
        assert res.requests == base.requests        # none lost
        assert len(res.latencies_s) == len(base.latencies_s)

    @settings(max_examples=3, deadline=None)
    @given(rate=st.sampled_from((2.0, 8.0, 24.0)))
    def test_preempted_run_never_outdraws_always_on(self, rate):
        ceiling = run_fleet(mixed_fleet_scenario(
            AlwaysOn, "warm-first", fleet=SPOT_H100_FLEET, seed=PIN_SEED,
            horizon_s=H6, carbon_trace="zone",
            service_model=ConstantServiceTime(2.0)))
        res = run_fleet(self._spot_day(rate))
        assert res.preemptions > 0
        assert res.energy_wh <= ceiling.energy_wh
        assert res.cost_usd <= ceiling.cost_usd

    def test_zero_rate_model_is_bit_invisible(self):
        """preemptions=None, rate-0, and an empty schedule are the SAME
        run: every existing anchor stays bit-unchanged."""
        runs = []
        for pre in (None, PreemptionModel(rate_per_device_day=0.0),
                    PreemptionModel(schedule=())):
            sc = dataclasses.replace(
                mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED,
                                     horizon_s=H6),
                preemptions=pre)
            runs.append(run_fleet(sc))
        ref = runs[0]
        for got in runs[1:]:
            assert got.energy_wh == ref.energy_wh       # bit-for-bit
            assert got.carbon_kg == ref.carbon_kg
            assert got.cost_usd == ref.cost_usd
            assert got.parking_tax_wh == ref.parking_tax_wh
            assert list(got.latencies_s) == list(ref.latencies_s)
            assert got.power_timeline == ref.power_timeline
            assert got.preemptions == 0 and got.requeued_requests == 0


class TestEngineCostEquivalence:
    """cost_usd is engine-invariant (the extended equivalence anchor)."""

    def test_pinned_day_cost_identical_across_engines(self):
        ref = run_fleet(mixed_fleet_scenario(Breakeven, "warm-first",
                                             seed=PIN_SEED))
        for backend in ("numpy", "jax"):
            got = run_mega(mixed_fleet_scenario(Breakeven, "warm-first",
                                                seed=PIN_SEED),
                           backend=backend)
            # acceptance asks <=1e-9 rel; both backends hold 0.0 (the
            # billing reduction fsums sorted keys, so summand order --
            # the only engine-visible difference -- cancels)
            assert got.cost_usd == ref.cost_usd
            assert got.gpu_hours_usd == ref.gpu_hours_usd
            assert got.energy_usd == ref.energy_usd
            for did in ref.device_gpu_usd:
                assert got.device_gpu_usd[did] == pytest.approx(
                    ref.device_gpu_usd[did], rel=REL)
            assert got.device_tiers == ref.device_tiers

    def test_zone_day_cost_matches_across_engines(self):
        mk = lambda: mixed_fleet_scenario(Breakeven, "warm-first",
                                          fleet=ZONES3, seed=PIN_SEED,
                                          carbon_trace="zone")
        ref, got = run_fleet(mk()), run_mega(mk())
        assert got.cost_usd == pytest.approx(ref.cost_usd, rel=REL)
        for z in ref.zone_cost_usd:
            assert got.zone_cost_usd[z] == pytest.approx(
                ref.zone_cost_usd[z], rel=REL)

    def test_mega_refuses_actual_fault_draws(self):
        sc = dataclasses.replace(
            mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED,
                                 fleet="2xh100:spot+2xa100"),
            preemptions=PreemptionModel(rate_per_device_day=4.0))
        with pytest.raises(MegaUnsupportedError, match="preemption"):
            run_mega(sc)

    def test_mega_accepts_empty_fault_draws(self):
        # a zero-rate model (or one with no spot device to revoke)
        # draws nothing: still in scope, still bit-identical
        sc = dataclasses.replace(
            mixed_fleet_scenario(Breakeven, "warm-first", seed=PIN_SEED),
            preemptions=PreemptionModel(rate_per_device_day=4.0))
        assert sc.device_tiers()["h100-0"] == "on_demand"
        got = run_mega(sc)
        ref = run_fleet(mixed_fleet_scenario(Breakeven, "warm-first",
                                             seed=PIN_SEED))
        assert got.cost_usd == ref.cost_usd


class TestParetoMath:
    """dominates / pareto_front / hypervolume, pure."""

    def test_dominates(self):
        assert dominates((1, 1, 1, 1), (2, 1, 1, 1))
        assert not dominates((1, 1, 1, 1), (1, 1, 1, 1))    # needs strict
        assert not dominates((0, 2), (1, 1))                # trade-off

    def test_pareto_front_hand(self):
        pts = [_point(1.0, wh=3.0), _point(3.0, wh=1.0), _point(2.0, wh=2.0),
               _point(4.0, wh=4.0)]                  # last is dominated
        front = pareto_front(pts)
        assert [p.cost_usd for p in front] == [1.0, 2.0, 3.0]

    def test_pareto_front_dedupes_ties(self):
        pts = [_point(1.0, fleet="a"), _point(1.0, fleet="b")]
        front = pareto_front(pts)
        assert len(front) == 1 and front[0].fleet == "a"

    @settings(max_examples=25, deadline=None)
    @given(objs=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3),
                                   st.integers(0, 3), st.integers(0, 3)),
                         min_size=1, max_size=12))
    def test_front_properties(self, objs):
        pts = [_point(float(c), wh=float(w), kg=float(k), p99=float(p))
               for c, w, k, p in objs]
        front = pareto_front(pts)
        assert front                                 # never empty
        for a in front:                              # mutual non-domination
            for b in front:
                assert not dominates(a.objectives(), b.objectives())
        fronts = {p.objectives() for p in front}
        for p in pts:                                # everything else loses
            if p.objectives() in fronts:
                continue
            assert any(dominates(f.objectives(), p.objectives())
                       for f in front)
        for i in range(4):                           # corners survive
            assert min(f.objectives()[i] for f in front) == \
                min(p.objectives()[i] for p in pts)

    def test_hypervolume_hand_values(self):
        ref = (2.0, 2.0, 2.0, 2.0)
        assert hypervolume([], ref) == 0.0
        # the reference point itself adds nothing
        assert hypervolume([_point(2.0, 2.0, 2.0, 2.0)], ref) == 0.0
        # halving every objective dominates (1/2)^4 of the unit box
        assert hypervolume([_point(1.0, 1.0, 1.0, 1.0)], ref) == \
            pytest.approx(0.5 ** 4, rel=1e-12)
        # an ideal plan at the origin dominates the whole box
        assert hypervolume([_point(0.0, 0.0, 0.0, 0.0)], ref) == \
            pytest.approx(1.0, rel=1e-12)
        # beating ONE objective while tying the rest spans zero volume
        assert hypervolume([_point(1.0, 2.0, 2.0, 2.0)], ref) == 0.0
        # worse-than-reference clips to the reference (no negative credit)
        assert hypervolume([_point(9.0, 1.0, 1.0, 1.0)], ref) == \
            pytest.approx(hypervolume([_point(2.0, 1.0, 1.0, 1.0)], ref),
                          rel=1e-12)

    def test_hypervolume_union_not_double_counted(self):
        # a: [.2,1]x[.6,1]x[0,1]x[0,1] -> 0.32; b mirrors it -> 0.32;
        # their overlap [.6,1]x[.6,1]x... -> 0.16; union 0.48
        ref = (1.0, 1.0, 1.0, 1.0)
        a, b = _point(0.2, 0.6, 0.0, 0.0), _point(0.6, 0.2, 0.0, 0.0)
        both = hypervolume([a, b], ref)
        assert both == pytest.approx(0.32 + 0.32 - 0.16, rel=1e-12)


class TestPlannerSweep:
    """plan_fleet on the 6 h pinned day (cheap structural checks)."""

    @pytest.fixture(scope="class")
    def sweep(self):
        return plan_fleet(pinned_day_base(horizon_s=H6),
                          pinned_day_axes(), backend="numpy")

    def test_reference_is_all_on_demand(self, sweep):
        ref = sweep.reference
        assert ref.price_tier == "on_demand"
        assert ref.preemption_rate == 0.0
        assert ":" not in ref.fleet

    def test_frontier_mutually_non_dominated(self, sweep):
        assert sweep.frontier
        for a in sweep.frontier:
            for b in sweep.points:
                assert not dominates(b.objectives(), a.objectives())

    def test_frontier_contains_single_objective_optima(self, sweep):
        for i, obj in enumerate(("cost_usd", "energy_wh", "carbon_kg",
                                 "p99_s")):
            sweep_min = min(p.objectives()[i] for p in sweep.points)
            assert sweep.best(obj).objectives()[i] == sweep_min

    def test_best_rejects_unknown_objective(self, sweep):
        with pytest.raises(KeyError, match="unknown objective"):
            sweep.best("latency")

    def test_no_spot_means_no_preemption_rate_axis(self, sweep):
        # tier-less fleets skip rate > 0: evaluating them again would
        # only duplicate the rate-0 point
        for p in sweep.points:
            if ":" not in p.fleet:
                assert p.preemption_rate == 0.0

    def test_engine_dispatch(self, sweep):
        # fault-free warm-first plans ride the mega fast path; actual
        # preemption draws fall back to the event loop
        engines = {(p.router, p.preemption_rate > 0): p.engine
                   for p in sweep.points}
        assert engines[("warm-first", False)] == "mega-numpy"
        assert all(e == "fleet" for (_, pre), e in engines.items() if pre)

    def test_hypervolume_in_unit_range(self, sweep):
        assert 0.0 <= sweep.hypervolume <= 1.0

    def test_json_artifact_round_trips(self, sweep):
        doc = json.loads(sweep.to_json())
        assert doc["objectives"] == ["cost_usd", "energy_wh", "carbon_kg",
                                     "p99_s"]
        assert doc["n_evaluated"] == len(sweep.points)
        assert len(doc["frontier"]) == len(sweep.frontier)
        assert doc["reference"]["price_tier"] == "on_demand"
        assert doc["hypervolume_vs_on_demand"] == sweep.hypervolume


class TestPlannerAcceptance:
    """The ISSUE's pinned acceptance: the full 3-zone seed-100 day."""

    @pytest.fixture(scope="class")
    def sweep(self):
        return plan_fleet(pinned_day_base(), pinned_day_axes(),
                          backend="numpy")

    def test_frontier_holds_three_plans(self, sweep):
        assert len(sweep.frontier) >= 3
        for a in sweep.frontier:
            for b in sweep.frontier:
                assert not dominates(a.objectives(), b.objectives())

    def test_spot_beats_on_demand_within_slo(self, sweep):
        ref = sweep.reference
        winners = [p for p in sweep.points
                   if p.preemption_rate > 0 and ":spot" in p.fleet
                   and p.preemptions > 0
                   and p.cost_usd < ref.cost_usd
                   and p.p99_s <= P99_BOUND_S]
        assert winners
        # the best of them undercuts on-demand by more than half
        assert min(p.cost_usd for p in winners) < 0.5 * ref.cost_usd

    def test_pinned_corners(self, sweep):
        # regression anchors (exact reproduction is deterministic; the
        # tolerance only absorbs float-reduction churn)
        assert sweep.reference.cost_usd == pytest.approx(624.6396714072346,
                                                         rel=1e-6)
        best = sweep.best("cost_usd")
        assert best.cost_usd == pytest.approx(182.70635568021723, rel=1e-6)
        assert best.fleet == SPOT_ALL_FLEET
        assert best.preemption_rate > 0 and best.preemptions > 0
        assert best.p99_s <= P99_BOUND_S
        assert sweep.best("carbon_kg").carbon_kg == pytest.approx(
            2.7966818523969312, rel=1e-6)

    def test_conservation_across_the_sweep(self, sweep):
        # every plan serves the same workload: request counts match the
        # all-on-demand reference everywhere, faults included
        for p in sweep.points:
            assert p.requests == sweep.reference.requests
