"""Sharding rule resolution + small-mesh SPMD integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec

from repro.configs import get_config
from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES,
                                        partition_spec)
from repro.launch.steps import SHAPES, input_specs, rules_for, \
    shape_applicable


class FakeMesh:
    """Just axis_names + shape, enough for partition_spec resolution."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisibility_fallback():
    # 48 heads shard over model=16; 8 do not; 1 does not
    assert partition_spec(("embed", "heads", "hdim"), (6144, 48, 128),
                          TRAIN_RULES, MESH) == \
        PartitionSpec("data", "model", None)
    assert partition_spec(("embed", "heads", "hdim"), (512, 8, 64),
                          TRAIN_RULES, MESH) == \
        PartitionSpec("data", None, None)


def test_no_axis_reuse_within_tensor():
    # experts takes model; ffn then cannot reuse it
    ps = partition_spec(("experts", "embed", "ffn"), (160, 5120, 1536),
                        TRAIN_RULES, MESH)
    assert ps == PartitionSpec("model", "data", None)


def test_pod_axis_multipod_batch():
    ps = partition_spec(("batch", "seq"), (256, 4096), TRAIN_RULES, MESH3)
    assert ps == PartitionSpec(("pod", "data"), "model")
    # batch=1 long decode: falls through to replicated batch
    ps1 = partition_spec(("batch", "seq"), (1, 1), TRAIN_RULES, MESH3)
    assert ps1 == PartitionSpec(None, None)


def test_big_arch_serve_rules_shard_weights():
    big = get_config("deepseek-v2-236b")
    small = get_config("gemma3-1b")
    assert rules_for(SHAPES["decode_32k"], big)["embed"] == [("data",)]
    assert rules_for(SHAPES["decode_32k"], small)["embed"] == []


def test_skip_rules():
    assert not shape_applicable(get_config("command-r-35b"),
                                SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("xlstm-125m"),
                            SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("mixtral-8x22b"),
                            SHAPES["long_500k"])[0]


def test_input_specs_cover_all_cells():
    from repro.models.params import is_spec
    from repro.configs import ARCHS
    for arch in ARCHS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if not shape_applicable(cfg, shape)[0]:
                continue
            specs = input_specs(cfg, shape)
            leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
            assert leaves, (arch, sname)
            for leaf in leaves:
                assert all(d > 0 for d in leaf.shape), (arch, sname, leaf)


def test_spmd_train_step_on_host_mesh():
    """Real 1-device mesh execution through the jit_cell path (the same
    code the 512-device dry-run lowers)."""
    import dataclasses
    from repro.configs import get_reduced
    from repro.launch.steps import jit_cell, ShapeSpec
    from repro.models.model import RunFlags

    cfg = get_reduced("granite-20b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("tiny_train", "train", 32, 2)
    jf, args = jit_cell(cfg, shape, mesh, flags=RunFlags(remat="full"))
    # materialize the abstract args and actually run one step
    from repro.models.params import materialize
    from repro.launch.steps import input_specs as ispecs
    spec_tree = ispecs(cfg, shape)
    concrete = materialize(spec_tree, jax.random.PRNGKey(0))
    with mesh:
        state, metrics = jf(concrete["state"], concrete["batch"])
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1
