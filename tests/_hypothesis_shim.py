"""Fallback stand-ins so the suite runs without ``hypothesis`` installed.

Property tests decorated with the shim's ``@given`` skip (with a clear
reason) instead of breaking collection; every plain test in the same
module still runs.  Install the optional extra (see requirements.txt)
to run the property tests for real.
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(
            reason="hypothesis not installed (optional extra)")(fn)
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _Strategies:
    """Accepts any ``st.<name>(...)`` call at decoration time."""

    def __getattr__(self, _name):
        def _strategy(*_args, **_kwargs):
            return None
        return _strategy


st = _Strategies()
