"""Mini property-test runner so ``@given`` tests RUN without ``hypothesis``.

Drop-in for the subset of the hypothesis API this suite uses::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_shim import given, settings, st

Unlike the original shim (which skipped ``@given`` tests), this one
executes each property against a deterministic, per-test seeded stream
of examples: ``random.Random(crc32(test name))`` drives every draw, so
failures reproduce run-to-run and across machines (the deflake
contract).  With real hypothesis installed the import above picks the
real package and this module is inert.

Supported strategies: ``integers``, ``floats`` (finite ranges),
``booleans``, ``sampled_from``, ``lists``, ``tuples``, ``just``.  An
unsupported strategy skips the test at call time with a clear reason
instead of breaking collection, preserving the old shim's guarantee.

Example count: ``@settings(max_examples=N)`` is honoured, capped by the
``SHIM_MAX_EXAMPLES`` env var (default 25) so heavyweight properties
stay tier-1-friendly; hypothesis proper runs the full N.
"""
import functools
import inspect
import os
import random
import zlib

import pytest

_DEFAULT_EXAMPLES = 25


class _Strategy:
    """A draw function rng -> value (the whole strategy contract here)."""

    def __init__(self, draw):
        self.draw = draw


class _UnsupportedStrategy(_Strategy):
    def __init__(self, name):
        def draw(_rng):
            pytest.skip(f"st.{name} not implemented by the hypothesis shim "
                        f"(install hypothesis to run this property)")
        super().__init__(draw)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(int(min_value), int(max_value)))


def _floats(min_value, max_value, **_kw):
    lo, hi = float(min_value), float(max_value)
    return _Strategy(lambda rng: rng.uniform(lo, hi))


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(seq):
    pool = list(seq)
    return _Strategy(lambda rng: pool[rng.randrange(len(pool))])


def _lists(elements, min_size=0, max_size=10, **_kw):
    return _Strategy(lambda rng: [elements.draw(rng) for _ in
                                  range(rng.randint(min_size, max_size))])


def _tuples(*elements):
    return _Strategy(lambda rng: tuple(e.draw(rng) for e in elements))


def _just(value):
    return _Strategy(lambda _rng: value)


class _Strategies:
    integers = staticmethod(_integers)
    floats = staticmethod(_floats)
    booleans = staticmethod(_booleans)
    sampled_from = staticmethod(_sampled_from)
    lists = staticmethod(_lists)
    tuples = staticmethod(_tuples)
    just = staticmethod(_just)

    def __getattr__(self, name):
        return lambda *_a, **_kw: _UnsupportedStrategy(name)


st = _Strategies()


def settings(*_args, max_examples=None, **_kwargs):
    def deco(fn):
        if max_examples is not None:
            fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cap = int(os.environ.get("SHIM_MAX_EXAMPLES", _DEFAULT_EXAMPLES))
            n = min(getattr(wrapper, "_shim_max_examples", None)
                    or getattr(fn, "_shim_max_examples", None)
                    or _DEFAULT_EXAMPLES, cap)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = [s.draw(rng) for s in arg_strategies]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except pytest.skip.Exception:
                    raise
                except Exception as e:
                    note = (f"falsifying example (shim, run {i + 1}/{n}): "
                            f"args={drawn!r} kwargs={drawn_kw!r}")
                    if hasattr(e, "add_note"):       # 3.11+
                        e.add_note(note)
                    else:
                        e.args = e.args + (note,)
                    raise
        # pytest must not unwrap to the property's signature (it would
        # look for fixtures named after the drawn arguments)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
