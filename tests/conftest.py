import os

# Tier-1 runs tiny reduced configs on CPU where jit COMPILE time, not
# compute, dominates: trade optimized codegen for much faster builds.
# Must be set before the first jax backend initialization; respects a
# caller's explicit XLA_FLAGS.
os.environ.setdefault("XLA_FLAGS", "--xla_backend_optimization_level=0")

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# Pinned-day magic, hoisted from the inline duplicates the fleet suites
# (test_zones / test_mega / test_pricing) used to carry independently --
# one definition, so the anchors cannot drift apart.
# ---------------------------------------------------------------------------

# The fleet spec of the pinned 3-zone follow-the-sun day, sourced from
# the planner's canonical sweep constant (the single owner).
from repro.fleet.planner import ZONES3_FLEET as ZONES3

PIN_SEED = 100       # the pinned 10-model x 6-GPU day every anchor shares
REL = 1e-9           # cross-engine tolerance (observed worst: ~2e-15)
P99_BOUND_S = 120.0  # pinned added-latency bound, 3-zone day


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def pinned_day():
    """Factory for fresh pinned seed-100 scenarios.  Scenarios hold
    per-run mutable state, so every run (and every test) needs its own;
    the factory shape makes reuse-by-accident impossible."""
    from repro.core.scheduler import Breakeven
    from repro.fleet import mixed_fleet_scenario

    def make(router="warm-first", policy=Breakeven, **kw):
        kw.setdefault("seed", PIN_SEED)
        return mixed_fleet_scenario(policy, router, **kw)

    return make


@pytest.fixture
def zones3_day(pinned_day):
    """The 3-zone follow-the-sun variant of the pinned day (ZONES3
    fleet, zone-preset carbon traces)."""
    def make(**kw):
        kw.setdefault("fleet", ZONES3)
        kw.setdefault("carbon_trace", "zone")
        return pinned_day(**kw)

    return make
