import os

# Tier-1 runs tiny reduced configs on CPU where jit COMPILE time, not
# compute, dominates: trade optimized codegen for much faster builds.
# Must be set before the first jax backend initialization; respects a
# caller's explicit XLA_FLAGS.
os.environ.setdefault("XLA_FLAGS", "--xla_backend_optimization_level=0")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
