"""Pipeline parallelism: schedule correctness + equivalence to the plain
stack (degenerate 1-stage mesh on this 1-device container; the 2-stage
lowering is proven by repro.launch.dryrun_pipeline on 512 fake devices).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import RunFlags, build_param_specs, materialize, \
    train_loss
from repro.training.pipeline import make_pipelined_train_loss, \
    split_stage_params

FLAGS = RunFlags(remat="none")


def test_single_stage_pipeline_matches_plain_stack():
    cfg = get_reduced("granite-20b")
    params = materialize(build_param_specs(cfg), jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1,), ("pod",))
    B, S, M = 4, 16, 2
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    staged = split_stage_params(params, cfg, n_stages=1)
    loss_fn = make_pipelined_train_loss(cfg, mesh, n_microbatches=M,
                                        flags=FLAGS)
    with mesh:
        got = float(loss_fn(staged, batch))
    want = float(train_loss(params, batch, cfg, FLAGS))
    assert got == pytest.approx(want, rel=1e-4)


def test_pipeline_grad_flows():
    cfg = get_reduced("granite-20b")
    params = materialize(build_param_specs(cfg), jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1,), ("pod",))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                             cfg.vocab_size)
    staged = split_stage_params(params, cfg, n_stages=1)
    loss_fn = make_pipelined_train_loss(cfg, mesh, n_microbatches=2,
                                        flags=FLAGS)
    with mesh:
        g = jax.grad(lambda p: loss_fn(p, {"tokens": tok, "labels": tok}))(
            staged)
    gn = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
             for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_stage_split_shapes():
    cfg = get_reduced("granite-20b")            # 2 layers
    params = materialize(build_param_specs(cfg), jax.random.PRNGKey(0))
    staged = split_stage_params(params, cfg, n_stages=2)
    leaf = jax.tree_util.tree_leaves(staged["groups"]["main"]["pos0"])[0]
    assert leaf.shape[0] == 2 and leaf.shape[1] == 1
    with pytest.raises(ValueError):
        split_stage_params(params, cfg, n_stages=3)
