"""Serving engine + model manager integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import H100, PYTORCH_70B, QWEN25_7B_MEASURED
from repro.core.scheduler import AlwaysOn, Breakeven
from repro.core import traffic
from repro.core.simulator import simulate
from repro.models import RunFlags, build_param_specs, materialize
from repro.serving import EnergyMeter, ModelManager, ServingEngine, SimClock

FLAGS = RunFlags(remat="none")


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced("qwen2-5-7b")
    params = materialize(build_param_specs(cfg), jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, max_batch=3, max_len=32, flags=FLAGS)


def test_generate_deterministic(engine):
    r1 = engine.generate([1, 2, 3], max_new=5)
    r2 = engine.generate([1, 2, 3], max_new=5)
    assert r1.tokens == r2.tokens
    assert len(r1.tokens) == 5


def test_batched_slots_isolated(engine):
    """Two concurrent sequences decode exactly as they would alone."""
    alone = engine.generate([4, 5, 6, 7], max_new=4).tokens
    s1 = engine.admit([4, 5, 6, 7])
    s2 = engine.admit([9, 8])
    toks = [int(engine._slot_last[s1])]
    for _ in range(3):
        out = engine.step()
        toks.append(out[s1])
    engine.release(s1)
    engine.release(s2)
    assert toks == alone


def test_slot_exhaustion(engine):
    slots = [engine.admit([1]) for _ in range(len(engine.free_slots()))]
    with pytest.raises(RuntimeError):
        engine.admit([2])
    for s in slots:
        engine.release(s)


def test_release_then_reuse_keeps_decode_exact(engine):
    """A released slot is immediately reusable, and a sequence admitted
    into the recycled slot decodes exactly as it would in a fresh one
    (no KV-cache leakage from the previous occupant)."""
    fresh = engine.generate([4, 5, 6], max_new=4).tokens
    s0 = engine.admit([9, 8, 7, 6, 5])           # pollute slot 0's cache
    engine.step()
    engine.release(s0)
    assert engine.free_slots()[0] == s0          # lowest-free reuse
    again = engine.generate([4, 5, 6], max_new=4)
    assert again.request_id == s0
    assert again.tokens == fresh


def test_admit_when_full_does_not_corrupt_live_slots(engine):
    """Filling every slot, bouncing off the full pool, then releasing
    and re-admitting leaves the surviving slot's decode unchanged."""
    alone = engine.generate([11, 12, 13], max_new=4).tokens
    keep = engine.admit([11, 12, 13])
    others = [engine.admit([2, 3]) for _ in range(len(engine.free_slots()))]
    with pytest.raises(RuntimeError):
        engine.admit([7])
    engine.release(others[0])
    others[0] = engine.admit([5, 4, 3, 2])       # slot churn under load
    toks = [int(engine._slot_last[keep])]
    for _ in range(3):
        toks.append(engine.step()[keep])
    for s in [keep] + others:
        engine.release(s)
    assert toks == alone


def test_interleaved_generate_keeps_caches_isolated(engine):
    """A full generate() call interleaved with a live background slot
    advances that slot without disturbing it: its token stream matches a
    solo run stepped the same number of times, and the generate result
    matches its own solo run."""
    solo_bg = engine.generate([21, 22, 23], max_new=5).tokens
    solo_fg = engine.generate([31, 32], max_new=4).tokens

    bg = engine.admit([21, 22, 23])
    toks = [int(engine._slot_last[bg])]
    fg = engine.generate([31, 32], max_new=4)    # 3 step() calls inside
    assert fg.tokens == solo_fg
    # the background slot advanced exactly 3 decode steps meanwhile
    assert int(engine._slot_pos[bg]) == 3 + 3
    assert int(engine._slot_last[bg]) == solo_bg[3]
    toks.append(engine.step()[bg])               # one more to be sure
    engine.release(bg)
    assert toks[0] == solo_bg[0]
    assert toks[1] == solo_bg[4]


def test_energy_meter_states():
    clk = SimClock()
    m = EnergyMeter(H100, clk)
    clk.advance(3600)                       # 1 h bare
    m.transition("parked")
    clk.advance(3600)                       # 1 h parked
    m.transition("bare")
    wh = m.totals()
    assert wh["bare"] == pytest.approx(H100.p_base_w, rel=1e-6)
    assert wh["parked"] == pytest.approx(H100.p_ctx_w, rel=1e-6)
    assert m.parking_tax_wh() == pytest.approx(H100.dvfs_step_w, rel=1e-6)


def test_manager_matches_simulator():
    arr = traffic.poisson(6.0, seed=2)
    sim = simulate(arr, Breakeven(PYTORCH_70B, H100), H100, PYTORCH_70B)
    mm = ModelManager(H100, clock=SimClock())
    mm.register("m", policy=Breakeven(PYTORCH_70B, H100), loader=PYTORCH_70B)
    mm.handle_request("m")
    res = mm.run_trace("m", arr.tolist(), horizon_s=24 * 3600.0)
    assert res["energy_wh"]["total"] == pytest.approx(sim.energy_wh, rel=0.02)
    assert abs(res["cold_starts"] - sim.cold_starts) <= 2


def test_manager_failure_recovery():
    """Node failure: model drops; next request transparently reloads."""
    mm = ModelManager(H100, clock=SimClock())
    mm.register("m", policy=AlwaysOn(), loader=QWEN25_7B_MEASURED)
    mm.handle_request("m")
    assert mm.models["m"].resident
    starts_before = mm.models["m"].cold_starts
    mm.fail()
    assert not mm.models["m"].resident
    assert mm.meter.state == "bare"
    mm.clock.advance(60.0)
    mm.handle_request("m")
    assert mm.models["m"].resident
    assert mm.models["m"].cold_starts == starts_before + 1


def test_manager_multi_model_energy_floor():
    """With two models and one evicted, state stays parked (not bare)."""
    mm = ModelManager(H100, clock=SimClock())
    mm.register("a", policy=AlwaysOn(), loader=QWEN25_7B_MEASURED)
    mm.register("b", policy=Breakeven(QWEN25_7B_MEASURED, H100),
                loader=QWEN25_7B_MEASURED)
    mm.handle_request("a")
    mm.handle_request("b")
    # advance far past b's T*: b evicts, a keeps the context alive
    mm._advance_with_evictions(mm.clock() + 3600.0)
    assert mm.models["a"].resident and not mm.models["b"].resident
    assert mm.meter.state == "parked"


def test_checkpoint_bytes_loader_calibration():
    """loader_from_checkpoint lands near the paper's measured Qwen trace."""
    from repro.core.coldstart import loader_from_checkpoint
    ld = loader_from_checkpoint("qwen", int(14.9 * 2 ** 30), H100)
    assert 20.0 < ld.t_load_s < 40.0         # paper: 29.7 s
    assert 60.0 < ld.p_load_w < 130.0        # paper trace mean ~85 W
