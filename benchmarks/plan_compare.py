"""Serial-vs-batched planner timing comparison (the nightly artifact).

Runs the 27-point tier grid of the pinned 3-zone day (3 fleets x 3
routers x 3 default purchase tiers) through ``plan_fleet`` twice --
``batched=False`` and ``batched=True`` -- verifies the frontiers are
point-for-point identical, and reports both legs' wall-clock,
simulation counts, and fresh-compile counts as one JSON document.

Run:  PYTHONPATH=src python -m benchmarks.plan_compare [--fast]

--fast shrinks the day to 6 h and uses the numpy backend (the CI smoke
shape); the default is the full 24 h day on the jax backend, with one
untimed warm-up sweep so the comparison measures steady state and the
warm-up's compile count is reported separately.  The nightly CI lane
redirects stdout to ``plan-timings.json`` and uploads it; the
committed baseline is ``BENCH_plan.json``.
"""
from __future__ import annotations

import argparse
import json

from repro.fleet.planner import (PlanAxes, SPOT_ALL_FLEET,
                                 SPOT_H100_FLEET, ZONES3_FLEET,
                                 pinned_day_base, plan_fleet)


def _grid_axes() -> PlanAxes:
    return PlanAxes(
        fleets=(ZONES3_FLEET, SPOT_H100_FLEET, SPOT_ALL_FLEET),
        routers=("warm-first", "slo-aware", "carbon-aware"),
        price_tiers=("on_demand", "reserved", "spot"))


def compare(fast: bool = False, seed: int = 100) -> dict:
    horizon_s = 6 * 3600.0 if fast else 24 * 3600.0
    backend = "numpy" if fast else "jax"
    base = pinned_day_base(horizon_s=horizon_s, seed=seed)
    axes = _grid_axes()

    warm = plan_fleet(base, axes, backend=backend, batched=True)
    serial = plan_fleet(base, axes, backend=backend, batched=False)
    batched = plan_fleet(base, axes, backend=backend, batched=True)

    identical = bool(
        len(serial.points) == len(batched.points)
        and all(a.objectives() == b.objectives() and a.engine == b.engine
                for a, b in zip(serial.points, batched.points))
        and serial.hypervolume == batched.hypervolume)

    def leg(res) -> dict:
        return {"wall_s": round(res.stats["wall_s"], 4),
                "sims": res.stats["sims"],
                "compiles": res.stats["compiles"]}

    return {
        "bench": "fleet.plan",
        "horizon_h": horizon_s / 3600.0,
        "backend": backend,
        "points": len(batched.points),
        "warmup": leg(warm),
        "serial": leg(serial),
        "batched": leg(batched),
        "speedup_x": round(serial.stats["wall_s"]
                           / batched.stats["wall_s"], 3),
        "points_per_s": round(len(batched.points)
                              / batched.stats["wall_s"], 2),
        "identical": identical,
        "hypervolume": float(batched.hypervolume),
        "frontier_size": len(batched.frontier),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="6 h horizon + numpy backend (CI smoke shape)")
    args = ap.parse_args()
    print(json.dumps(compare(fast=args.fast), indent=2))


if __name__ == "__main__":
    main()
