"""Pallas kernel microbenchmarks (interpret mode on CPU -> correctness +
relative cost only; wall-clock MFU belongs to real TPU runs).

For each kernel: allclose vs the pure-jnp oracle + per-call timing of the
oracle path (the jnp reference is what the dry-run lowers; the kernel is
the TPU-native swap-in).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import ops, ref


def bench_flash() -> str:
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 512, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 512, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 512, 64))
    want = ref.flash_attention_ref(q, k, v, causal=True, window=128)
    got = ops.flash_attention(q, k, v, causal=True, window=128)
    err = float(jnp.max(jnp.abs(want - got)))
    assert err < 5e-3, err
    fn = jax.jit(lambda: ref.flash_attention_ref(q, k, v, causal=True,
                                                 window=128))
    fn()  # compile
    timed("kernels.flash_ref_512", lambda: jax.block_until_ready(fn()),
          repeats=5)
    emit("kernels.flash.max_err", f"{err:.2e}")
    return f"flash max|err|={err:.2e}"


def bench_decode() -> str:
    q = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 128))
    k = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 2048, 128))
    v = jax.random.normal(jax.random.PRNGKey(2), (4, 2, 2048, 128))
    length = jnp.array([2048, 1024, 17, 512])
    want = ref.decode_attention_ref(q, k, v, length)
    got = ops.decode_attention(q, k, v, length)
    err = float(jnp.max(jnp.abs(want - got)))
    assert err < 5e-3, err
    fn = jax.jit(lambda: ref.decode_attention_ref(q, k, v, length))
    fn()
    timed("kernels.decode_ref_2k", lambda: jax.block_until_ready(fn()),
          repeats=10)
    emit("kernels.decode.max_err", f"{err:.2e}")
    return f"decode max|err|={err:.2e}"


def bench_rglru() -> str:
    a = jax.random.uniform(jax.random.PRNGKey(0), (4, 1024, 256),
                           minval=0.5, maxval=0.999)
    b = jax.random.normal(jax.random.PRNGKey(1), (4, 1024, 256))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (4, 256))
    want = ref.rglru_scan_ref(a, b, h0)
    got = ops.rglru_scan(a, b, h0)
    err = float(jnp.max(jnp.abs(want - got)))
    assert err < 1e-3, err
    fn = jax.jit(lambda: ref.rglru_scan_ref(a, b, h0))
    fn()
    timed("kernels.rglru_ref_1k", lambda: jax.block_until_ready(fn()),
          repeats=10)
    emit("kernels.rglru.max_err", f"{err:.2e}")
    return f"rglru max|err|={err:.2e}"


def run_all() -> None:
    print("== Kernels:", bench_flash(), "|", bench_decode(), "|",
          bench_rglru())
