"""Roofline table from the dry-run result JSONs (launch/dryrun.py).

Reads benchmarks/dryrun_results/*.json and renders the section-Roofline
tables of EXPERIMENTS.md: per (arch x shape x mesh) the three terms,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, fits check, and the
one-line "what would move the dominant term" nudge.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from benchmarks.common import emit

RESULTS = pathlib.Path(__file__).resolve().parent / "dryrun_results"

NUDGE = {
    ("compute",): "cut redundant FLOPs (windowed/flash attention, leaner "
                  "MoE dispatch, less remat)",
    ("memory",): "shrink streamed state (weight/KV sharding, window ring "
                 "buffers, quantized cache)",
    ("collective",): "reshard to cut per-layer gathers (fewer TP hops, "
                     "bf16 reduces, overlap with compute)",
}


def load_cells(tag: str = "baseline") -> List[Dict]:
    cells = []
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("tag", "baseline") != tag:
            continue
        cells.append(d)
    return cells


def render_table(cells: List[Dict], mesh: str) -> str:
    hdr = (f"| arch | shape | compute ms | memory ms (floor) | "
           f"collective ms | dominant | useful-FLOP | roofline-frac | "
           f"GiB/dev |\n|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for c in cells:
        if c["mesh"] != mesh:
            continue
        if c["status"] == "skipped":
            lines.append(f"| {c['arch']} | {c['shape']} | -- | -- | -- | "
                         f"skipped | -- | -- | -- |")
            continue
        if c["status"] != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | ERROR: "
                         f"{c.get('error','')[:60]} | | | | | | |")
            continue
        gib = c["peak_device_bytes"] / 2 ** 30
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_s']*1e3:.1f} | "
            f"{c['memory_floor_s']*1e3:.1f} | {c['collective_s']*1e3:.1f} | "
            f"{c['dominant_floor']} | {c['useful_flops_ratio']:.2f} | "
            f"{c['roofline_fraction_floor']:.3f} | {gib:.1f} |")
    return "\n".join(lines)


def run_all() -> None:
    cells = load_cells()
    if not cells:
        print("== Roofline: no dry-run results yet "
              "(run python -m repro.launch.dryrun --all)")
        return
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    errs = [c for c in cells if c["status"] not in ("ok", "skipped")]
    print(f"== Roofline: {len(ok)} ok, {len(skipped)} skipped "
          f"(documented), {len(errs)} errors")
    for mesh in ("single", "multi"):
        sub = [c for c in ok if c["mesh"] == mesh]
        if not sub:
            continue
        print(f"-- mesh={mesh} ({len(sub)} cells)")
        print(render_table(cells, mesh))
        for c in sub:
            emit(f"roofline.{c['arch']}.{c['shape']}.{mesh}.frac",
                 f"{c['roofline_fraction_floor']:.4f}")
    # summary: worst / best cells by roofline fraction (single-pod)
    single = [c for c in ok if c["mesh"] == "single"]
    if single:
        worst = min(single, key=lambda c: c["roofline_fraction_floor"])
        best = max(single, key=lambda c: c["roofline_fraction_floor"])
        print(f"-- worst roofline fraction: {worst['arch']} x "
              f"{worst['shape']} = {worst['roofline_fraction_floor']:.3f} "
              f"({worst['dominant_floor']}-bound)")
        print(f"-- best  roofline fraction: {best['arch']} x "
              f"{best['shape']} = {best['roofline_fraction_floor']:.3f}")
