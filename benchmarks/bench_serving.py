"""Serving-engine microbench: real decode throughput on a reduced config
(CPU) + train-step timing -- the live-system counterpart of the dry-run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.configs import get_reduced
from repro.models import RunFlags, build_param_specs, materialize
from repro.serving import ServingEngine
from repro.training.trainer import TrainConfig, train


def bench_decode_throughput() -> str:
    cfg = get_reduced("qwen2-5-7b")
    params = materialize(build_param_specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=4, max_len=64,
                        flags=RunFlags(remat="none"))
    for i in range(4):
        eng.admit([1 + i, 2, 3])
    eng.step()                                  # compile
    t0 = time.perf_counter()
    n = 20
    for _ in range(n):
        eng.step()
    dt = time.perf_counter() - t0
    tps = 4 * n / dt
    emit("serving.decode_tokens_per_s_cpu", f"{tps:.0f}")
    return f"decode {tps:.0f} tok/s (reduced cfg, CPU, batch 4)"


def bench_train_step() -> str:
    cfg = get_reduced("gemma3-1b")
    hist = train(cfg, TrainConfig(steps=8, batch_size=4, seq_len=64,
                                  log_every=100), log_fn=lambda s: None)
    step_ms = float(np.mean(hist["step_time_s"][2:])) * 1e3
    emit("serving.train_step_ms_cpu", f"{step_ms:.1f}")
    return f"train step {step_ms:.1f} ms (reduced gemma3, CPU)"


def run_all() -> None:
    print("== Serving:", bench_decode_throughput(), "|", bench_train_step())
