"""Serving-engine microbench: real decode throughput on a reduced config
(CPU) + train-step timing -- the live-system counterpart of the dry-run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.configs import get_reduced
from repro.models import RunFlags, build_param_specs, materialize
from repro.serving import ServingEngine
from repro.training.trainer import TrainConfig, train

# single explicit seed for every random draw in this bench (param init);
# timing numbers still vary with the host, token streams do not
SEED = 0


def bench_decode_throughput() -> str:
    cfg = get_reduced("qwen2-5-7b")
    params = materialize(build_param_specs(cfg), jax.random.PRNGKey(SEED))
    eng = ServingEngine(cfg, params, max_batch=4, max_len=64,
                        flags=RunFlags(remat="none"))
    for i in range(4):
        eng.admit([1 + i, 2, 3])
    eng.step()                                  # compile
    t0 = time.perf_counter()
    n = 20
    for _ in range(n):
        eng.step()
    dt = time.perf_counter() - t0
    tps = 4 * n / dt
    emit("serving.decode_tokens_per_s_cpu", f"{tps:.0f}")
    return f"decode {tps:.0f} tok/s (reduced cfg, CPU, batch 4)"


def bench_request_churn() -> str:
    """Continuous-batching request churn on the live engine: admit /
    step / release under slot contention, reporting requests/s, p99
    request latency, and the metered energy estimate for the run (H100
    active power over the wall time -- catalog estimate, not measured)."""
    from repro.core import H100

    cfg = get_reduced("qwen2-5-7b")
    params = materialize(build_param_specs(cfg), jax.random.PRNGKey(SEED))
    eng = ServingEngine(cfg, params, max_batch=4, max_len=64,
                        flags=RunFlags(remat="none"))
    eng.admit([1, 2, 3])
    eng.step()                                  # compile
    eng.release(0)

    n_requests, max_new = 12, 6
    pending = [[1 + i, 2, 3] for i in range(n_requests)]
    lat: list = []
    t0 = time.perf_counter()
    births: dict = {}
    left: dict = {}
    while pending or births:
        while pending and eng.free_slots():
            slot = eng.admit(pending.pop())
            births[slot] = time.perf_counter()
            left[slot] = max_new - 1
        eng.step()
        for slot in list(births):
            left[slot] -= 1
            if left[slot] <= 0:
                lat.append(time.perf_counter() - births.pop(slot))
                del left[slot]
                eng.release(slot)
    wall = time.perf_counter() - t0
    rps = n_requests / wall
    p99_ms = float(np.percentile(np.asarray(lat), 99)) * 1e3
    wh_est = H100.active_power_w(0.6) * wall / 3600.0
    emit("serving.requests_per_s_cpu", f"{rps:.1f}")
    emit("serving.p99_request_latency_ms_cpu", f"{p99_ms:.0f}")
    emit("serving.churn_wh_est", f"{wh_est:.4f}")
    return (f"churn {rps:.1f} req/s, p99 {p99_ms:.0f} ms, "
            f"~{wh_est:.3f} Wh (H100-active est)")


def bench_train_step() -> str:
    cfg = get_reduced("gemma3-1b")
    hist = train(cfg, TrainConfig(steps=8, batch_size=4, seq_len=64,
                                  log_every=100), log_fn=lambda s: None)
    step_ms = float(np.mean(hist["step_time_s"][2:])) * 1e3
    emit("serving.train_step_ms_cpu", f"{step_ms:.1f}")
    return f"train step {step_ms:.1f} ms (reduced gemma3, CPU)"


def run_all() -> None:
    print("== Serving:", bench_decode_throughput(), "|",
          bench_request_churn(), "|", bench_train_step())
