"""Per-architecture parking decisions: the paper's framework applied to
all ten assigned architectures (+ the paper's Qwen2.5-7B).

For each arch: checkpoint bytes from the real param-spec tree ->
loader_from_checkpoint (calibrated on the paper's measured Qwen trace) ->
T* / lambda* on H100 (measured profile) and TPU-v5e (estimated profile).
This is the paper's central table the authors could not build: the
model-size INDEPENDENCE of the tax means T* varies only through t_load,
so a 125M xLSTM and a 236B DeepSeek differ 200x in load time but pay the
same 49.9 W to stay warm.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import ARCHS, get_config
from repro.core import H100, TPU_V5E, loader_from_checkpoint
from repro.core.breakeven import breakeven_seconds, critical_rate_per_hr, \
    format_t_star
from repro.models import build_param_specs, param_bytes


def run_all() -> None:
    print("== Per-arch parking decisions (H100 measured / TPU-v5e est.):")
    print(f"   {'arch':22s} {'ckpt':>9s} {'t_load':>8s} "
          f"{'T*(H100)':>9s} {'lam*(H100)':>11s} {'T*(v5e)':>9s}")
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        bytes_ = param_bytes(build_param_specs(cfg))
        ld_h = loader_from_checkpoint(arch, bytes_, H100)
        ld_t = loader_from_checkpoint(arch, bytes_, TPU_V5E)
        t_h = breakeven_seconds(ld_h, H100)
        lam = critical_rate_per_hr(ld_h, H100)
        t_t = breakeven_seconds(ld_t, TPU_V5E)
        rows.append((t_h, arch))
        print(f"   {arch:22s} {bytes_/2**30:7.1f}GiB "
              f"{ld_h.t_load_s:7.1f}s {format_t_star(t_h):>9s} "
              f"{lam:9.1f}/hr {format_t_star(t_t):>9s}")
        emit(f"archs.{arch}.t_star_h100_s", f"{t_h:.0f}")
    rows.sort()
    print(f"   -> most evictable: {rows[0][1]} (T*={format_t_star(rows[0][0])}); "
          f"least: {rows[-1][1]} (T*={format_t_star(rows[-1][0])}) -- the "
          f"paper's 'small models are the worst always-on candidates'.")
