"""Fleet bench: cluster-scale parking tax across heterogeneous GPUs.

The headline table of the fleet subsystem: a mixed H100/A100/L40S fleet
serving 10 models under a diurnal + bursty + heavy-tail traffic mix,
comparing always-on warm-everywhere against routing x eviction x
consolidation, with the clairvoyant lower bound as the floor.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_fleet [--fast]
(--fast is the CI smoke mode: 4 models x 3 devices x 6 h.)
"""
from __future__ import annotations

import sys

import math
import time

from benchmarks.common import emit
from repro.core.scheduler import AlwaysOn, Breakeven
from repro.fleet import (CarbonAwareRouter, CarbonBreakeven, Consolidator,
                         MIXES, ReplicaAutoscaler, SLOAwareRouter,
                         flash_crowd, mixed_fleet_scenario, run_fleet,
                         run_mega, trace_for_zone)
from repro.serving import RooflineServiceTime

SLO_BUDGET_S = 90.0
# every scenario below derives its traffic from this seed, so bench
# numbers are reproducible run-to-run (deflake contract)
SEED = 100


def _floor_kg(res) -> float:
    """Bare-idle floor of the fleet's emissions under the bench's trace.

    The floor is sum(p_base) integrated over the run's intensity curve
    -- the part of kgCO2e no scheduler can move while the devices stay
    powered.  The delta carbon-aware scheduling CAN win lives in
    (total - floor).  Integrated over the ACTUAL horizon: a partial-day
    window does not average the trace to its daily mean (the 6 h fast
    smoke sits on the morning shoulder at ~0.41, not 0.39)."""
    from repro.fleet import get_mix, get_sku, make_trace
    p_base = sum(get_sku(d.sku).profile.p_base_w for d in res.devices)
    trace = make_trace("solar-duck", get_mix("USA").gwp_kg_per_kwh)
    return trace.carbon_kg(p_base, 0.0, res.horizon_s)


def run_all(fast: bool = False, seed: int = SEED) -> None:
    kw = dict(n_models=4, fleet="h100+a100+l40s", horizon_s=6 * 3600.0,
              seed=seed) if fast else dict(seed=seed)
    tag = "fleet6h" if fast else "fleet24h"
    base = run_fleet(mixed_fleet_scenario(AlwaysOn, "warm-first",
                                          consolidate=False, **kw))
    print(f"== Fleet ({'fast smoke' if fast else '10 models x 6 GPUs, 24 h'};"
          f" {base.requests} requests) ==")
    hdr = (f"   {'configuration':38s} {'Wh':>9s} {'save%':>6s} {'cold':>5s}"
           f" {'migr':>5s} {'req/s':>6s} {'p99_s':>7s}")
    print(hdr)

    def report(name: str, res) -> None:
        save = 100.0 * res.savings_vs(base)
        print(f"   {name:38s} {res.energy_wh:9.1f} {save:6.1f}"
              f" {res.cold_starts:5d} {res.migrations:5d}"
              f" {res.requests_per_s:6.3f} {res.p99_added_latency_s:7.2f}")
        emit(f"{tag}.{name}.wh", f"{res.energy_wh:.1f}")
        emit(f"{tag}.{name}.savings_pct", f"{save:.1f}")
        emit(f"{tag}.{name}.cold_starts", str(res.cold_starts))
        emit(f"{tag}.{name}.mean_added_latency_s",
             f"{res.mean_added_latency_s:.2f}")
        emit(f"{tag}.{name}.requests_per_s", f"{res.requests_per_s:.3f}")
        emit(f"{tag}.{name}.p99_added_latency_s",
             f"{res.p99_added_latency_s:.2f}")

    report("always-on_warm-everywhere", base)
    for router in ("warm-first", "least-loaded", "energy-greedy",
                   "breakeven-aware"):
        for cons in (False, True):
            name = f"breakeven_{router}" + ("_consolidate" if cons else "")
            report(name, run_fleet(mixed_fleet_scenario(
                Breakeven, router, consolidate=cons, **kw)))
    report("always-on_consolidate", run_fleet(mixed_fleet_scenario(
        AlwaysOn, "warm-first", consolidate=True, **kw)))

    # concurrent serving: roofline service times (occupancy-dependent),
    # loads overlapping decode, and the energy/latency Pareto the
    # SLO-aware router trades along
    svc = RooflineServiceTime()
    print("   -- concurrent serving (roofline service times, "
          f"max_batch=4, SLO budget {SLO_BUDGET_S:.0f} s) --")
    report("svc_always-on_warm-first", run_fleet(mixed_fleet_scenario(
        AlwaysOn, "warm-first", service_model=svc, **kw)))
    eg_svc = run_fleet(mixed_fleet_scenario(
        Breakeven, "energy-greedy", service_model=svc, **kw))
    report("svc_breakeven_energy-greedy", eg_svc)
    slo_single = run_fleet(mixed_fleet_scenario(
        Breakeven, SLOAwareRouter(SLO_BUDGET_S), service_model=svc, **kw))
    report("svc_breakeven_slo-aware", slo_single)

    # replica auto-scaling: the headline the paper's framing demands --
    # what does a unit of p99 improvement COST in over-provisioned
    # warm-replica energy?
    # fast smoke traffic is too sparse for the default thresholds --
    # use a hair-trigger controller there so the path still exercises
    scaler = ReplicaAutoscaler(tick_s=30.0, pressure_hi=0.25,
                               pressure_lo=0.1, cooldown_s=120.0) \
        if fast else ReplicaAutoscaler()
    slo_auto = run_fleet(mixed_fleet_scenario(
        Breakeven, SLOAwareRouter(SLO_BUDGET_S), service_model=svc,
        autoscaler=scaler, **kw))
    report("svc_breakeven_slo-aware_autoscaled", slo_auto)
    d_wh = slo_auto.energy_wh - slo_single.energy_wh
    d_p99 = slo_single.p99_added_latency_s - slo_auto.p99_added_latency_s
    tax = slo_auto.parking_tax_wh - slo_single.parking_tax_wh
    wh_per_p99 = d_wh / d_p99 if d_p99 > 0 else float("inf")
    print(f"   -- autoscaler: {slo_auto.scale_outs} scale-outs /"
          f" {slo_auto.scale_ins} scale-ins, peak"
          f" {slo_auto.peak_replicas()} replicas --")
    print(f"   over-provisioning parking tax {tax:+9.1f} Wh, p99"
          f" {d_p99:+.2f} s better => {wh_per_p99:.1f} Wh per p99-second")
    emit(f"{tag}.autoscale.overprovision_tax_wh", f"{tax:.1f}")
    emit(f"{tag}.autoscale.energy_delta_wh", f"{d_wh:.1f}")
    emit(f"{tag}.autoscale.p99_improvement_s", f"{d_p99:.2f}")
    emit(f"{tag}.autoscale.wh_per_p99_s", f"{wh_per_p99:.1f}")
    emit(f"{tag}.autoscale.peak_replicas", str(slo_auto.peak_replicas()))

    # carbon-intensity-aware scheduling: the same day under a solar-duck
    # grid trace.  kgCO2e is a trace INTEGRAL over the metered power
    # timeline, so the flat-trace rows match the scalar accounting and
    # the duck rows price WHEN each joule was drawn.  The carbon stack
    # (carbon-breakeven eviction + carbon routing + carbon-aware
    # consolidation) must cut kgCO2e vs energy-greedy at equal-or-better
    # p99 (the acceptance row); the budgeted variants trace the
    # carbon/latency Pareto.
    print("   -- carbon (solar-duck trace, daily mean = USA 0.39 "
          "kgCO2e/kWh) --")
    ckw = dict(service_model=svc, carbon_trace="solar-duck", **kw)
    eg_c = run_fleet(mixed_fleet_scenario(Breakeven, "energy-greedy",
                                          **ckw))
    carbon_runs = [("carbon_energy-greedy", eg_c)]
    for label, budget in (("carbon-aware_b90", SLO_BUDGET_S),
                          ("carbon-greedy", math.inf)):
        res = run_fleet(mixed_fleet_scenario(
            CarbonBreakeven, CarbonAwareRouter(budget),
            consolidate=Consolidator(carbon_aware=True, period_s=300.0),
            **ckw))
        carbon_runs.append((f"carbon_{label}", res))
    for name, res in carbon_runs:
        print(f"   {name:38s} {res.energy_wh:9.1f} {'':6s}"
              f" {res.cold_starts:5d} {res.migrations:5d}"
              f" {res.requests_per_s:6.3f} {res.p99_added_latency_s:7.2f}"
              f"   {res.carbon_kg:.4f} kg")
        emit(f"{tag}.carbon.{name}.kg", f"{res.carbon_kg:.4f}")
        emit(f"{tag}.carbon.{name}.wh", f"{res.energy_wh:.1f}")
        emit(f"{tag}.carbon.{name}.p99_added_latency_s",
             f"{res.p99_added_latency_s:.2f}")
    cg = carbon_runs[-1][1]
    d_kg = eg_c.carbon_kg - cg.carbon_kg
    sched_kg = eg_c.carbon_kg - _floor_kg(eg_c)
    print(f"   -- carbon-aware vs energy-greedy: {d_kg:+.4f} kg "
          f"({100 * cg.carbon_savings_vs(eg_c):.2f}% of total, "
          f"{100 * d_kg / sched_kg if sched_kg > 0 else 0:.1f}% of "
          f"schedulable) at p99 {cg.p99_added_latency_s:.1f} vs "
          f"{eg_c.p99_added_latency_s:.1f} s --")
    emit(f"{tag}.carbon.delta_kg", f"{d_kg:.4f}")
    emit(f"{tag}.carbon.delta_pct", f"{100 * cg.carbon_savings_vs(eg_c):.2f}")
    emit(f"{tag}.carbon.schedulable_kg", f"{sched_kg:.4f}")
    # zone sweep: re-price the SAME schedule on each zone's preset trace
    # (carbon is a post-hoc integral over the recorded power timeline)
    for zone in sorted(MIXES):
        kg = cg.carbon_with(trace_for_zone(zone))
        emit(f"{tag}.carbon.zone.{zone}.kg", f"{kg:.4f}")

    # per-device zones + follow-the-sun: the SAME day on a geo-split
    # fleet (each device priced on its zone's local-time trace), with
    # zone-aware cold placement/consolidation vs the zone-blind router.
    # The delta is what knowing WHERE (not just when) each joule is
    # drawn buys at the same p99 budget.
    zfleet = "h100@DEU+a100@USA+l40s@IND" if fast \
        else "2xh100@DEU+2xa100@USA+2xl40s@IND"
    zkw = dict(kw, fleet=zfleet, carbon_trace="zone", zone="USA")
    print(f"   -- zones: follow-the-sun on {zfleet} --")
    zruns = {}
    for label, aware in (("follow-the-sun", True), ("zone-blind", False)):
        res = run_fleet(mixed_fleet_scenario(
            CarbonBreakeven, CarbonAwareRouter(math.inf, zone_aware=aware),
            consolidate=Consolidator(carbon_aware=True, period_s=300.0),
            **zkw))
        zruns[label] = res
        per_zone = " ".join(f"{z}={kg:.4f}"
                            for z, kg in sorted(res.zone_carbon_kg.items()))
        print(f"   {'zones_' + label:38s} {res.energy_wh:9.1f} {'':6s}"
              f" {res.cold_starts:5d} {res.migrations:5d}"
              f" {res.requests_per_s:6.3f} {res.p99_added_latency_s:7.2f}"
              f"   {res.carbon_kg:.4f} kg [{per_zone}]")
        emit(f"{tag}.zones.{label}.kg", f"{res.carbon_kg:.4f}")
        emit(f"{tag}.zones.{label}.wh", f"{res.energy_wh:.1f}")
        emit(f"{tag}.zones.{label}.p99_added_latency_s",
             f"{res.p99_added_latency_s:.2f}")
        emit(f"{tag}.zones.{label}.migrations", str(res.migrations))
        emit(f"{tag}.zones.{label}.cross_zone_migrations",
             str(res.cross_zone_migrations))
        emit(f"{tag}.zones.{label}.transfer_wh", f"{res.transfer_wh:.2f}")
        for z, zkg in sorted(res.zone_carbon_kg.items()):
            emit(f"{tag}.zones.{label}.zone.{z}.kg", f"{zkg:.4f}")
    fts, blind = zruns["follow-the-sun"], zruns["zone-blind"]
    zd_kg = blind.carbon_kg - fts.carbon_kg
    print(f"   -- follow-the-sun vs zone-blind: {zd_kg:+.4f} kg "
          f"({100 * fts.carbon_savings_vs(blind):.2f}%) at p99 "
          f"{fts.p99_added_latency_s:.1f} vs "
          f"{blind.p99_added_latency_s:.1f} s --")
    emit(f"{tag}.zones.delta_kg", f"{zd_kg:.4f}")
    emit(f"{tag}.zones.delta_pct",
         f"{100 * fts.carbon_savings_vs(blind):.2f}")

    # device power gating: the first mechanism that cuts BELOW p_base.
    # The consolidator's packing drains devices; gate_drained_devices
    # then puts them to SLEEP past the wake-energy breakeven, and the
    # SLO router prices wake latency+energy into cold placement so the
    # p99 budget still holds.  Acceptance: total Wh strictly below the
    # best non-gated policy at p99 within the budget.
    print("   -- device power gating (sleep/wake state machine, "
          f"SLO budget {SLO_BUDGET_S:.0f} s) --")
    # baseline: best non-gated policy under the SAME service model
    # (a service-free run would mix energy bases), INCLUDING a
    # consolidated one -- so the saved_vs row isolates what gating adds
    # on top of packing, not packing itself
    eg_svc_cons = run_fleet(mixed_fleet_scenario(
        Breakeven, "energy-greedy", consolidate=True, service_model=svc,
        **kw))
    report("svc_breakeven_energy-greedy_consolidate", eg_svc_cons)
    nongated = min((eg_svc, eg_svc_cons, slo_single),
                   key=lambda r: r.energy_wh)
    gate_cons = Consolidator(period_s=300.0, gate_drained_devices=True)
    gated = run_fleet(mixed_fleet_scenario(
        Breakeven, SLOAwareRouter(SLO_BUDGET_S), service_model=svc,
        consolidate=gate_cons, **kw))
    report("svc_breakeven_slo-aware_gated", gated)
    sleep_h = gated.state_durations_s.get("sleep", 0.0) / 3600.0
    print(f"   -- gating: {gated.gates} gates / {gated.wakes} wakes, "
          f"{sleep_h:.1f} device-hours asleep, "
          f"{gated.gated_wh_saved:.1f} Wh recovered from the bare-idle "
          f"floor ({gated.energy_wh:.1f} vs best non-gated "
          f"{nongated.energy_wh:.1f} Wh) --")
    emit(f"{tag}.gating.wh", f"{gated.energy_wh:.1f}")
    emit(f"{tag}.gating.best_nongated_wh", f"{nongated.energy_wh:.1f}")
    emit(f"{tag}.gating.saved_vs_best_nongated_wh",
         f"{nongated.energy_wh - gated.energy_wh:.1f}")
    emit(f"{tag}.gating.gated_wh_saved", f"{gated.gated_wh_saved:.1f}")
    emit(f"{tag}.gating.p99_added_latency_s",
         f"{gated.p99_added_latency_s:.2f}")
    emit(f"{tag}.gating.gates", str(gated.gates))
    emit(f"{tag}.gating.wakes", str(gated.wakes))
    emit(f"{tag}.gating.sleep_device_hours", f"{sleep_h:.1f}")
    for state in ("sleep", "bare", "parked", "loading", "active"):
        emit(f"{tag}.gating.state.{state}.wh",
             f"{gated.state_energy_wh.get(state, 0.0):.1f}")

    print(f"   {'clairvoyant non-gated bound':38s}"
          f" {base.lb_nongated_wh:9.1f} {100 * (1 - base.lb_nongated_wh / base.energy_wh):6.1f}")
    print(f"   {'per-model clairvoyant (no sharing)':38s}"
          f" {base.cv_per_model_wh:9.1f}")
    emit(f"{tag}.clairvoyant_lb.wh", f"{base.lb_nongated_wh:.1f}")
    print(f"   infra {base.infra_usd:.0f} USD/day (on-demand), baseline "
          f"energy {base.energy_usd:.2f} USD, {base.carbon_kg:.1f} kgCO2e "
          f"(USA mix; catalog estimates)")

    _run_mega_bench(fast, seed, tag, kw)
    _run_megax_bench(fast, seed, tag)
    _run_pareto_bench(fast, seed, tag)
    _run_plan_bench(fast, seed, tag)


def _run_plan_bench(fast: bool, seed: int, tag: str) -> None:
    """`{tag}.plan.*`: batched vs serial plan_fleet on the 27-point
    tier grid (3 fleets x 3 routers x 3 default tiers of the pinned
    3-zone day) -- wall-clock both ways, throughput, simulation and
    compile counts, and the identity check the batched mode promises
    (point-for-point equal frontiers)."""
    from benchmarks.plan_compare import compare

    print("   -- plan: batched vs serial sweep execution --")
    doc = compare(fast=fast, seed=seed)
    print(f"   {doc['points']} plans: serial {doc['serial']['wall_s']:.2f} s "
          f"({doc['serial']['sims']} sims) vs batched "
          f"{doc['batched']['wall_s']:.2f} s ({doc['batched']['sims']} sims)"
          f" -> {doc['speedup_x']:.2f}x, "
          f"{doc['points_per_s']:.1f} points/s, identical="
          f"{doc['identical']}")
    emit(f"{tag}.plan.points", str(doc["points"]))
    emit(f"{tag}.plan.serial_s", f"{doc['serial']['wall_s']:.2f}",
         us=doc["serial"]["wall_s"] * 1e6)
    emit(f"{tag}.plan.batched_s", f"{doc['batched']['wall_s']:.2f}",
         us=doc["batched"]["wall_s"] * 1e6)
    emit(f"{tag}.plan.speedup_x", f"{doc['speedup_x']:.2f}")
    emit(f"{tag}.plan.points_per_s", f"{doc['points_per_s']:.1f}")
    emit(f"{tag}.plan.sims", str(doc["batched"]["sims"]))
    emit(f"{tag}.plan.compiles", str(doc["warmup"]["compiles"]))
    emit(f"{tag}.plan.identical", str(doc["identical"]))


def _run_pareto_bench(fast: bool, seed: int, tag: str) -> None:
    """`{tag}.pareto.*`: the four-objective fleet planner on the pinned
    3-zone day -- frontier size, the best-cost and best-carbon corner
    points, and the frontier's hypervolume against the all-on-demand
    singleton (0 would mean no plan in the sweep beats always-buying
    on-demand anywhere)."""
    from repro.fleet.planner import pinned_day_axes, pinned_day_base, \
        plan_fleet

    print("   -- pareto: 4-objective fleet planner (cost/energy/carbon/"
          "p99) --")
    horizon = 6 * 3600.0 if fast else 24 * 3600.0
    routers = ("warm-first", "slo-aware") if fast else \
        ("warm-first", "slo-aware", "carbon-aware")
    base = pinned_day_base(horizon_s=horizon, seed=seed)
    axes = pinned_day_axes(routers=routers)
    t0 = time.perf_counter()
    res = plan_fleet(base, axes, backend="numpy" if fast else "jax")
    wall = time.perf_counter() - t0
    ref = res.reference
    best_cost = res.best("cost_usd")
    best_kg = res.best("carbon_kg")
    print(f"   {len(res.points)} plans in {wall:.1f} s -> frontier "
          f"{len(res.frontier)}, hypervolume {res.hypervolume:.4f} vs "
          f"on-demand ${ref.cost_usd:.2f}")
    print(f"   best cost   ${best_cost.cost_usd:8.2f} "
          f"({1 - best_cost.cost_usd / ref.cost_usd:5.0%} under on-demand, "
          f"p99 {best_cost.p99_s:.1f} s)  {best_cost.label()}")
    print(f"   best carbon {best_kg.carbon_kg:9.3f} kg "
          f"(vs {ref.carbon_kg:.3f})  {best_kg.label()}")
    emit(f"{tag}.pareto.plans", str(len(res.points)))
    emit(f"{tag}.pareto.wall_s", f"{wall:.2f}", us=wall * 1e6)
    emit(f"{tag}.pareto.frontier_size", str(len(res.frontier)))
    emit(f"{tag}.pareto.hypervolume", f"{res.hypervolume:.4f}")
    emit(f"{tag}.pareto.best_cost_usd", f"{best_cost.cost_usd:.2f}")
    emit(f"{tag}.pareto.best_cost_p99_s", f"{best_cost.p99_s:.2f}")
    emit(f"{tag}.pareto.best_carbon_kg", f"{best_kg.carbon_kg:.4f}")
    emit(f"{tag}.pareto.on_demand_cost_usd", f"{ref.cost_usd:.2f}")
    emit(f"{tag}.pareto.cost_saving_pct",
         f"{100 * (1 - best_cost.cost_usd / ref.cost_usd):.1f}")


def _run_mega_bench(fast: bool, seed: int, tag: str, kw: dict) -> None:
    """`{tag}.mega.*`: the vectorized simulator's wall-clock story.

    Three legs: (1) speedup vs the event loop on the pinned anchor day
    (same physics, anchored bit-exact in tests/test_mega.py, so the row
    is pure wall-clock); (2) a device-count sweep on generated
    flash-crowd days; (3) full mode only, the ISSUE acceptance -- a
    ~600-device, >1M-request synthetic day, which must complete in
    under 30 s."""
    print("   -- mega: vectorized simulator (trace replay at scale) --")
    sc_kw = {k: v for k, v in kw.items() if k != "seed"}
    t0 = time.perf_counter()
    ref = run_fleet(mixed_fleet_scenario(Breakeven, "warm-first",
                                         seed=seed, **sc_kw))
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    got = run_mega(mixed_fleet_scenario(Breakeven, "warm-first",
                                        seed=seed, **sc_kw))
    t_mega = time.perf_counter() - t0
    speedup = t_ref / t_mega if t_mega > 0 else float("inf")
    n0 = len(got.devices)
    print(f"   anchor day (n={n0}): event loop {t_ref:.2f} s, mega "
          f"{t_mega:.3f} s => {speedup:.1f}x at {got.energy_wh:.1f} Wh "
          f"(= event loop's {ref.energy_wh:.1f})")
    emit(f"{tag}.mega.speedup.n{n0}", f"{speedup:.1f}")
    emit(f"{tag}.mega.wall_s.n{n0}", f"{t_mega:.3f}", us=t_mega * 1e6)
    emit(f"{tag}.mega.wh.n{n0}", f"{got.energy_wh:.1f}")

    # device-count sweep: generated flash-crowd days, scaled traffic
    sweep = ((6, "2xh100+2xa100+2xl40s", 24),
             (60, "20xh100+20xa100+20xl40s", 80)) if fast else \
            ((6, "2xh100+2xa100+2xl40s", 24),
             (60, "20xh100+20xa100+20xl40s", 80),
             (600, "200xh100+200xa100+200xl40s", 600))
    horizon = 6 * 3600.0 if fast else 24 * 3600.0
    for n_dev, fleet, n_routes in sweep:
        trace = flash_crowd(n_routes=n_routes, fleet=fleet, seed=seed,
                            horizon_s=horizon, base_rate_hr=40.0)
        t0 = time.perf_counter()
        res = run_mega(trace.to_scenario(Breakeven), compute_bound=False)
        wall = time.perf_counter() - t0
        rate = res.requests / wall if wall > 0 else float("inf")
        print(f"   flash-crowd n={n_dev:4d}: {res.requests:8d} requests, "
              f"{res.energy_wh:11.1f} Wh, wall {wall:6.2f} s "
              f"({rate:,.0f} req/s simulated)")
        emit(f"{tag}.mega.wall_s.n{n_dev}", f"{wall:.3f}", us=wall * 1e6)
        emit(f"{tag}.mega.wh.n{n_dev}", f"{res.energy_wh:.1f}")
        emit(f"{tag}.mega.requests.n{n_dev}", str(res.requests))

    if not fast:
        # the ISSUE 6 acceptance row: >=1M-request day, <30 s wall
        trace = flash_crowd(n_routes=600,
                            fleet="200xh100+200xa100+200xl40s",
                            seed=seed, base_rate_hr=130.0, spike_x=60.0)
        t0 = time.perf_counter()
        res = run_mega(trace.to_scenario(Breakeven), compute_bound=False)
        wall = time.perf_counter() - t0
        print(f"   mega day: {res.requests:,} requests on "
              f"{len(res.devices)} devices in {wall:.1f} s "
              f"({res.energy_wh / 1e3:.1f} kWh, "
              f"{res.cold_starts} cold starts)")
        emit(f"{tag}.mega.megaday.requests", str(res.requests))
        emit(f"{tag}.mega.megaday.wall_s", f"{wall:.2f}", us=wall * 1e6)
        emit(f"{tag}.mega.megaday.wh", f"{res.energy_wh:.1f}")


def _run_megax_bench(fast: bool, seed: int, tag: str) -> None:
    """`{tag}.megax.*`: the compiled (jax) bulk-scan backend vs numpy.

    Both backends drive the identical structural event loop (totals
    anchored to <=1e-9 in tests/test_mega.py), so the rows isolate the
    BULK-SCAN phases -- big-gap scans, deferred billing, energy
    segment-sums, and the carbon trapezoid integral -- which is where
    the jit-compiled array programs (and the segment_trapz kernel) do
    their work.  Benched on a solar-duck carbon trace: time-varying
    intensity is the paper's carbon-aware setting, and it is exactly
    where the numpy path pays a per-segment Python integral.  The
    sweep leg shows compile amortization: every compiled program is
    shared across same-shaped points, so point 1 is compile-bound and
    the rest run hot."""
    from repro.fleet import make_trace
    from repro.fleet.mega import run_mega_sweep

    print("   -- megax: compiled (jax) bulk-scan backend --")
    ct = make_trace("solar-duck", 0.39)
    if fast:
        trace = flash_crowd(n_routes=24, fleet="2xh100+2xa100+2xl40s",
                            seed=seed, horizon_s=6 * 3600.0,
                            base_rate_hr=40.0)
    else:
        # the mega-day acceptance trace: ~600 devices, >1M requests
        trace = flash_crowd(n_routes=600,
                            fleet="200xh100+200xa100+200xl40s",
                            seed=seed, base_rate_hr=130.0, spike_x=60.0)
    # first jax run pays the jit compiles; time the warm steady state
    run_mega(trace.to_scenario(Breakeven, carbon_trace=ct),
             compute_bound=False, backend="jax")
    runs = {}
    for backend in ("numpy", "jax"):
        sc = trace.to_scenario(Breakeven, carbon_trace=ct)
        t0 = time.perf_counter()
        res = run_mega(sc, compute_bound=False, backend=backend)
        runs[backend] = (time.perf_counter() - t0, res)
    (w_np, r_np), (w_jx, r_jx) = runs["numpy"], runs["jax"]
    b_np = r_np.phase_timings["bulk_scan_s"]
    b_jx = r_jx.phase_timings["bulk_scan_s"]
    speedup = b_np / b_jx if b_jx > 0 else float("inf")
    drift = abs(r_jx.energy_wh - r_np.energy_wh) / r_np.energy_wh
    print(f"   bulk-scan ({r_np.requests:,} requests, "
          f"{len(r_np.devices)} devices): numpy {b_np:.2f} s, jax "
          f"{b_jx:.2f} s => {speedup:.1f}x (wall {w_np:.1f} vs "
          f"{w_jx:.1f} s; energy drift {drift:.1e})")
    for phase in ("biggap_s", "billing_s", "energy_s", "carbon_s"):
        print(f"      {phase:10s} numpy {r_np.phase_timings[phase]:6.2f} s"
              f"   jax {r_jx.phase_timings[phase]:6.2f} s")
    emit(f"{tag}.megax.bulk_scan.numpy_s", f"{b_np:.3f}", us=b_np * 1e6)
    emit(f"{tag}.megax.bulk_scan.jax_s", f"{b_jx:.3f}", us=b_jx * 1e6)
    emit(f"{tag}.megax.bulk_scan.speedup", f"{speedup:.2f}")
    emit(f"{tag}.megax.wall_s.numpy", f"{w_np:.2f}", us=w_np * 1e6)
    emit(f"{tag}.megax.wall_s.jax", f"{w_jx:.2f}", us=w_jx * 1e6)
    emit(f"{tag}.megax.carbon_s.numpy", f"{r_np.phase_timings['carbon_s']:.3f}")
    emit(f"{tag}.megax.carbon_s.jax", f"{r_jx.phase_timings['carbon_s']:.3f}")

    # vmapped sweep: one compiled trace-generation batch + shared bulk
    # programs across every point
    n_pts = 4 if fast else 24
    skw = dict(n_routes=6, fleet="2xh100+2xa100+2xl40s", base_rate_hr=30.0,
               horizon_s=6 * 3600.0 if fast else 24 * 3600.0,
               scenario_kw=dict(carbon_trace=ct))
    t0 = time.perf_counter()
    results = run_mega_sweep(seeds=range(n_pts), **skw)
    wall = time.perf_counter() - t0
    bulks = [r.phase_timings["bulk_scan_s"] for r in results]
    amort = bulks[0] / bulks[-1] if bulks[-1] > 0 else float("inf")
    print(f"   sweep: {n_pts} points in {wall:.1f} s "
          f"({n_pts / wall:.2f} pts/s); bulk-scan point 1 "
          f"{bulks[0]:.2f} s (compile) -> point {n_pts} {bulks[-1]:.3f} s "
          f"({amort:.0f}x amortized)")
    emit(f"{tag}.megax.sweep.points", str(n_pts))
    emit(f"{tag}.megax.sweep.wall_s", f"{wall:.2f}", us=wall * 1e6)
    emit(f"{tag}.megax.sweep.points_per_s", f"{n_pts / wall:.2f}")
    emit(f"{tag}.megax.sweep.first_bulk_s", f"{bulks[0]:.3f}")
    emit(f"{tag}.megax.sweep.last_bulk_s", f"{bulks[-1]:.3f}")


if __name__ == "__main__":
    from benchmarks.common import print_csv
    run_all(fast="--fast" in sys.argv)
    print_csv()
