"""Beyond-paper results (EXPERIMENTS.md section Beyond-paper):

  1. exact-convention breakeven (charges loading power above bare idle):
     shorter T*, strictly better energy on every trace.
  2. adaptive breakeven (EWMA rate + hysteresis + Eq.13 immediate evict):
     fixes the diurnal oscillation the paper reports (sec 8).
  3. clairvoyant bound: fraction of offline-optimal savings captured.
  4. MMPP heavy-tail stress (the paper's Future Work workload).
  5. serving-level validation: ModelManager (the system) agrees with the
     analytic simulator on Table-6 energies.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import H100, PYTORCH_70B
from repro.core import traffic
from repro.core.scheduler import (AdaptiveBreakeven, AlwaysOn, Breakeven,
                                  Clairvoyant, ExactBreakeven, FixedTTL)
from repro.core.simulator import compare_policies, simulate
from repro.serving import ModelManager, SimClock


def bench_policies() -> str:
    gens = {"steady": lambda s: traffic.poisson(5.0, seed=s),
            "bursty": lambda s: traffic.bursty(seed=s),
            "diurnal": lambda s: traffic.diurnal(seed=s),
            "mmpp": lambda s: traffic.mmpp(seed=s)}
    mk = lambda: [AlwaysOn(), Breakeven(PYTORCH_70B, H100),
                  ExactBreakeven(PYTORCH_70B, H100),
                  AdaptiveBreakeven(PYTORCH_70B, H100),
                  Clairvoyant(PYTORCH_70B, H100)]
    lines = []
    for name, gen in gens.items():
        sav = {p.name: [] for p in mk()}
        for s in range(5):
            arr = gen(s)
            res = compare_policies(arr, mk(), H100, PYTORCH_70B)
            base = res[0]
            for r in res:
                sav[r.policy].append(r.savings_vs(base))
        means = {k: float(np.mean(v)) for k, v in sav.items()}
        paper = means["breakeven-paper(T*=271s)"]
        exact = means["breakeven-exact(T*=206s)"]
        adapt = [v for k, v in means.items() if "adaptive" in k][0]
        clair = means["clairvoyant-optimal"]
        # exact convention must never lose to the paper convention
        assert exact >= paper - 0.005, (name, exact, paper)
        captured = adapt / clair if clair > 0 else 0.0
        lines.append(f"{name}: paper={100*paper:.1f}% exact={100*exact:.1f}% "
                     f"adaptive={100*adapt:.1f}% optimal={100*clair:.1f}% "
                     f"(adaptive captures {100*captured:.0f}%)")
        emit(f"beyond.{name}.adaptive_savings_pct", f"{100*adapt:.1f}")
        emit(f"beyond.{name}.optimal_savings_pct", f"{100*clair:.1f}")
    return "\n   ".join(lines)


def bench_manager_agreement() -> str:
    """The serving-system energy accounting must agree with the analytic
    simulator (two independent implementations of Table 6)."""
    arr = traffic.poisson(5.0, seed=1)
    sim = simulate(arr, Breakeven(PYTORCH_70B, H100), H100, PYTORCH_70B)

    def run_mgr():
        mm = ModelManager(H100, clock=SimClock())
        mm.register("m", policy=Breakeven(PYTORCH_70B, H100),
                    loader=PYTORCH_70B)
        mm.handle_request("m")                    # initial load
        return mm.run_trace("m", arr.tolist(), horizon_s=24 * 3600.0)

    mgr = timed("beyond.manager_trace", run_mgr)
    sim_wh = sim.energy_wh
    mgr_wh = mgr["energy_wh"]["total"]
    rel = abs(mgr_wh - sim_wh) / sim_wh
    assert rel < 0.02, (mgr_wh, sim_wh)           # within 2%
    assert abs(mgr["cold_starts"] - sim.cold_starts) <= 2
    emit("beyond.manager_vs_sim_rel_err", f"{rel:.4f}")
    return (f"manager={mgr_wh:.0f}Wh sim={sim_wh:.0f}Wh rel_err={rel:.3%} "
            f"cold {mgr['cold_starts']}/{sim.cold_starts} "
            f"parking_tax={mgr['parking_tax_wh']:.0f}Wh")


def run_all() -> None:
    print("== Beyond-paper policies:\n  ", bench_policies())
    print("== Manager/simulator agreement:", bench_manager_agreement())
