"""Shared benchmark plumbing: timing + CSV rows (name,us_per_call,derived)."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timed(name: str, fn: Callable[[], Any], *, repeats: int = 1
          ) -> Any:
    """Run fn, record (name, us_per_call, derived-from-return)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    us = (time.perf_counter() - t0) / repeats * 1e6
    derived = out if isinstance(out, str) else ""
    ROWS.append((name, us, derived))
    return out


def emit(name: str, derived: str, us: float = 0.0) -> None:
    ROWS.append((name, us, derived))


def print_csv() -> None:
    print("name,us_per_call,derived")
    for name, us, derived in ROWS:
        print(f"{name},{us:.1f},{derived}")
