"""Benchmark entrypoint: `PYTHONPATH=src python -m benchmarks.run [--fast]`.

Runs every paper-table reproduction (with tolerance gates), the
beyond-paper policy study, the kernel microbenches, the live serving
bench, the fleet-orchestration bench, and renders the roofline table
from the dry-run results.  Ends with the machine-readable CSV
(name,us_per_call,derived).  ``--fast`` switches the fleet bench to its
smoke scenario (CI mode).
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_archs, bench_beyond, bench_fleet,
                            bench_kernels, bench_paper_tables,
                            bench_roofline, bench_serving)
    from benchmarks.common import print_csv

    fast = "--fast" in sys.argv
    print("#" * 72)
    print("# The Model Parking Tax -- reproduction + framework benchmarks")
    print("#" * 72)
    bench_paper_tables.run_all()
    bench_beyond.run_all()
    bench_archs.run_all()
    bench_kernels.run_all()
    bench_serving.run_all()
    bench_fleet.run_all(fast=fast)
    bench_roofline.run_all()
    print("#" * 72)
    print_csv()


if __name__ == "__main__":
    main()
