"""Benchmarks reproducing every table of the paper, with tolerance checks
against the published values.  One function per table; each returns a
markdown-ish block (printed) and appends CSV rows (common.py).

Paper values are hard-coded as the EXPECTED targets; a reproduction
failure raises, so `python -m benchmarks.run` doubles as the faithfulness
gate (EXPERIMENTS.md section Reproduction).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import (A100, H100, L40S, PYTORCH_70B, TABLE4_LOADERS)
from repro.core.breakeven import format_t_star, table4
from repro.core.coldstart import QWEN25_7B_H100_TRACE
from repro.core.doseresponse import run_simulated_dose_response, table2_row
from repro.core.impact import TABLE5
from repro.core.phase1 import analyze_fleet
from repro.core.scheduler import AlwaysOn, Breakeven, FixedTTL
from repro.core.simulator import compare_policies
from repro.core.telemetry import SimulatedPowerReader, simulate_fleet
from repro.core import traffic

# per-device thermal drift (W/hr) calibrated so the A100 reproduces its
# paper-reported tiny-but-significant negative slope (section 4.2)
DRIFT = {"h100": 0.0, "a100": 0.05, "l40s": 0.0}
PROFILES = {"h100": H100, "a100": A100, "l40s": L40S}

PAPER_TABLE2 = {   # (bare W, ctx W, step W, max |beta|)
    "h100": (71.8, 121.7, 49.9, 0.02),
    "a100": (53.7, 80.0, 26.3, 0.02),
    "l40s": (35.6, 102.1, 66.4, 0.02),
}


def bench_phase1() -> str:
    """Section 4.1: production telemetry bimodality (335,267 idle samples).
    Uses the PRODUCTION fleet profile (SXM nodes: +70.9 W effect), not the
    Phase-2 bench unit (+49.9 W) -- the paper's two H100 populations."""
    ds = simulate_fleet(seed=7)
    res = timed("phase1.analyze", lambda: analyze_fleet(ds))
    assert res.n_raw == 336_226, res.n_raw
    assert abs(res.n_idle - 335_267) < 2_000, res.n_idle
    assert 60 < res.context_effect_w < 85       # paper: +70.9 W
    assert res.cohens_d > 4.0                   # paper: 7.3
    assert abs(res.pooled_slope_w_per_gb) < 0.2  # paper: 0.013, p=.95
    out = (f"n={res.n_idle} bare={res.bare_mean_w:.1f}+-{res.bare_std_w:.1f} "
           f"ctx={res.ctx_mean_w:.1f}+-{res.ctx_std_w:.1f} "
           f"effect=+{res.context_effect_w:.1f}W d={res.cohens_d:.1f} "
           f"pooled_slope={res.pooled_slope_w_per_gb:+.3f} "
           f"N_eff={res.n_eff_low:.0f}-{res.n_eff_high:.0f}")
    emit("phase1.context_effect_w", f"{res.context_effect_w:.1f}")
    emit("phase1.cohens_d", f"{res.cohens_d:.2f}")
    return out


def bench_table2() -> str:
    """Section 4.2 / Table 2: cross-architecture dose-response."""
    lines = []
    for key, prof in PROFILES.items():
        dr = timed(f"table2.{key}.doseresponse",
                   lambda p=prof, k=key: run_simulated_dose_response(
                       p, seed=42, thermal_drift_w_per_hr=DRIFT[k]))
        row = table2_row(dr, prof)
        bare, ctx, step, bmax = PAPER_TABLE2[key]
        assert abs(row["bare_idle_w"] - bare) < 1.5, (key, row)
        assert abs(row["ctx_power_w"] - ctx) < 1.5, (key, row)
        assert abs(row["context_overhead_w"] - step) < 2.0, (key, row)
        assert abs(row["beta_w_per_gb"]) < bmax, (key, row)
        assert dr.tost.equivalent, (key, "TOST must bound |beta|<0.1")
        assert row["context_share_pct"] > 98.0, (key, row)
        lines.append(
            f"{key}: bare={row['bare_idle_w']} ctx={row['ctx_power_w']} "
            f"step=+{row['context_overhead_w']}W beta={row['beta_w_per_gb']:+.4f} "
            f"p={row['p_beta']:.3g} p_tost={row['p_tost']:.2g} "
            f"range={row['power_range_w']}W share={row['context_share_pct']}%")
        emit(f"table2.{key}.beta_w_per_gb", f"{row['beta_w_per_gb']:+.4f}")
        emit(f"table2.{key}.dvfs_step_w", f"{row['context_overhead_w']}")
    # A100's negative-slope confound (section 4.2): drift makes beta negative
    dr_a100 = run_simulated_dose_response(A100, seed=42,
                                          thermal_drift_w_per_hr=0.05)
    assert dr_a100.regression.slope < 0, "A100 drift confound not negative"
    emit("table2.a100.drift_confound_beta",
         f"{dr_a100.regression.slope:+.4f}(p={dr_a100.regression.p_value:.3f})")
    return " | ".join(lines)


def bench_table3() -> str:
    """Section 4.3 / Table 3: real-model validation -- a loaded HF model
    idles within noise of a same-context reference on every arch."""
    results = []
    specs = [  # (profile, instance offset W, ref vram GB, model vram GB)
        (H100, 0.0, 16.0, 14.9),        # torch.empty reference
        (A100, 25.4, 0.5, 14.8),        # post-unload reference; 105 W node
        (L40S, -4.8, 0.5, 14.8),
    ]
    for prof, off, ref_v, model_v in specs:
        rd = SimulatedPowerReader(prof, seed=3, instance_offset_w=off)
        def mean_at(v):
            rd.set_state(context_active=True, vram_gb=v)
            return float(np.mean([rd.sample(i * 30.0).power_w
                                  for i in range(30)]))
        m_model = mean_at(model_v)
        m_ref = mean_at(ref_v)
        delta = m_model - m_ref
        assert abs(delta) < 0.5, (prof.name, delta)   # paper: <=0.47 W
        results.append(f"{prof.name}: model={m_model:.2f}W "
                       f"ref={m_ref:.2f}W delta={delta:+.2f}W")
        emit(f"table3.{prof.name}.delta_w", f"{delta:+.3f}")
    # cold-start profile (measured H100 trace, section 4.3)
    tr = QWEN25_7B_H100_TRACE
    emit("table3.coldstart.total_s", f"{tr.total_s:.1f}")
    emit("table3.coldstart.mean_w", f"{tr.mean_power_w:.1f}")
    assert 29.0 < tr.total_s < 30.5                   # paper: 29.7 s
    return " | ".join(results)


def bench_table4() -> str:
    """Section 5 / Table 4: cold-start breakeven."""
    paper = {"Qwen2.5-7B (measured)": 74.5,       # 1.2 min
             "Standard PyTorch (70B)": 270.5,     # 4.5 min
             "ServerlessLLM (70B)": 48.1,
             "Run:ai Streamer (8B)": 20.0}
    rows = timed("table4.breakeven", lambda: table4(H100))
    lines = []
    for r in rows:
        want = paper[r.loader]
        assert abs(r.t_star_s - want) / want < 0.02, (r.loader, r.t_star_s)
        lines.append(f"{r.loader}: T*={format_t_star(r.t_star_s)} "
                     f"(exact {format_t_star(r.t_star_exact_s)}) "
                     f"lambda*={r.lambda_star_per_hr:.1f}/hr")
        emit(f"table4.{r.loader}.t_star_s", f"{r.t_star_s:.1f}")
    # cross-arch (section 5): A100 ~8.5 min, L40S ~3.4 min for PyTorch-70B
    a = table4(A100)[1].t_star_s
    l = table4(L40S)[1].t_star_s
    assert abs(a - 513) < 6 and abs(l - 203) < 6, (a, l)
    emit("table4.a100.pytorch70b_t_star_s", f"{a:.0f}")
    emit("table4.l40s.pytorch70b_t_star_s", f"{l:.0f}")
    return " | ".join(lines)


def bench_table5() -> str:
    """Section 6 / Table 5: industry impact 92-1745 GWh/yr."""
    paper = {"low": 92.0, "base": 462.0, "high": 1745.0}
    lines = []
    for sc in TABLE5:
        got = sc.energy_gwh_per_year
        assert abs(got - paper[sc.name]) / paper[sc.name] < 0.01, (sc, got)
        lines.append(f"{sc.name}={got:.0f}GWh/yr({sc.co2_kt_per_year:.0f}kT)")
        emit(f"table5.{sc.name}.gwh_per_year", f"{got:.0f}")
    return " ".join(lines)


def bench_table6() -> str:
    """Section 7 / Table 6: policy simulation, 5-seed averages."""
    gens = {"steady": lambda s: traffic.poisson(5.0, seed=s),
            "bursty": lambda s: traffic.bursty(seed=s),
            "diurnal": lambda s: traffic.diurnal(seed=s)}
    paper_sav = {"steady": 0.181, "bursty": 0.230, "diurnal": 0.082}
    lines = []
    for name, gen in gens.items():
        sav_ttl, sav_be, colds = [], [], []
        for s in range(5):
            arr = gen(s)
            res = compare_policies(
                arr, [AlwaysOn(), FixedTTL(300),
                      Breakeven(PYTORCH_70B, H100)], H100, PYTORCH_70B)
            base = res[0]
            assert abs(base.energy_wh - 2921) < 2, base.energy_wh
            sav_ttl.append(res[1].savings_vs(base))
            sav_be.append(res[2].savings_vs(base))
            colds.append(res[2].cold_starts)
        ttl, be = np.mean(sav_ttl), np.mean(sav_be)
        # faithfulness: within 8 pp of the paper's savings for its trace
        assert abs(be - paper_sav[name]) < 0.08, (name, be)
        lines.append(f"{name}: ttl5={100*ttl:.1f}% breakeven={100*be:.1f}% "
                     f"(paper {100*paper_sav[name]:.1f}%) "
                     f"cold={np.mean(colds):.0f}")
        emit(f"table6.{name}.breakeven_savings_pct", f"{100*be:.1f}")
        emit(f"table6.{name}.paper_savings_pct",
             f"{100*paper_sav[name]:.1f}")
    return " | ".join(lines)


def run_all() -> None:
    print("== Phase 1 (sec 4.1):", bench_phase1())
    print("== Table 2 (sec 4.2):", bench_table2())
    print("== Table 3 (sec 4.3):", bench_table3())
    print("== Table 4 (sec 5):  ", bench_table4())
    print("== Table 5 (sec 6):  ", bench_table5())
    print("== Table 6 (sec 7):  ", bench_table6())
