"""Production meshes (DESIGN.md section 5).

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") -- the
"pod" axis crosses DCN; gradients all-reduce over it, weights replicate.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests run on one
CPU device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many (CPU) devices exist -- tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))
