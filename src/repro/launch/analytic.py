"""Analytic HBM-traffic floor per (arch x shape x mesh) cell.

WHY: the CPU dry-run backend lowers every bf16 dot as convert-to-f32 +
f32 dot, and hoists loop-invariant converts of the whole stacked weight /
KV-cache tensors out of the scan.  ``cost_analysis()['bytes accessed']``
therefore reflects CPU lowering (observed ~20x inflation on decode
cells), not TPU behavior where bf16 feeds the MXU natively.  FLOP counts
are dtype-independent (trustworthy) and collective shapes keep their
stated dtypes (trustworthy); bytes are the one term that needs an
analytic model.

The floor counts, per device, the traffic a TPU implementation cannot
avoid (weights streamed once per pass, KV cache read, optimizer state
read+written, remat carries saved+reloaded, logits materialized).  It
excludes intra-layer activation traffic that a fused implementation keeps
in VMEM -- so it is a lower bound, labeled as such in EXPERIMENTS.md.
Both the measured-HLO bytes and this floor are recorded per cell.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.distributed.sharding import RuleSet, partition_spec
from repro.launch import steps as steps_lib
from repro.models.config import ArchConfig
from repro.models.params import ParamSpec, is_spec

Tree = Any


def _sharded_bytes(spec_tree: Tree, rules: RuleSet, mesh: Mesh) -> int:
    """Exact per-device bytes of a ParamSpec tree under the rule set."""
    total = 0
    for s in jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec):
        ps = partition_spec(s.axes, s.shape, rules, mesh)
        shards = 1
        for entry in ps:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shards *= mesh.shape[a]
        n = math.prod(s.shape) // shards
        total += n * jnp.dtype(s.dtype).itemsize
    return total


def analytic_bytes_per_device(cfg: ArchConfig, shape: "steps_lib.ShapeSpec",
                              mesh: Mesh, *, remat: str = "full",
                              flags=None) -> Dict[str, float]:
    rules = steps_lib.rules_for(shape, cfg)
    specs = steps_lib.input_specs(cfg, shape, flags)
    dsize = mesh.size

    if shape.kind == "train":
        p_bytes = _sharded_bytes(specs["state"]["params"], rules, mesh)
        m_bytes = _sharded_bytes(specs["state"]["mu"], rules, mesh) \
            + _sharded_bytes(specs["state"]["nu"], rules, mesh)
        # local tokens: batch and seq sharding per rules
        tok_local = shape.global_batch * shape.seq_len
        bspec = partition_spec(("batch", "seq"),
                               (shape.global_batch, shape.seq_len), rules,
                               mesh)
        for entry in bspec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                tok_local //= mesh.shape[a]
        act = jnp.dtype(cfg.compute_dtype).itemsize
        # passes: fwd reads params, bwd reads params; full remat re-reads
        passes = 3 if remat != "none" else 2
        weights = p_bytes * passes
        grads = p_bytes                                  # write grads
        opt = m_bytes * 2 + p_bytes                      # rw moments, write p
        carries = tok_local * cfg.d_model * act * cfg.n_layers * 2
        vocab_local = cfg.vocab_size
        vspec = partition_spec(("vocab",), (cfg.vocab_size,), rules, mesh)
        if vspec[0] is not None:
            axes = vspec[0] if isinstance(vspec[0], tuple) else (vspec[0],)
            for a in axes:
                vocab_local //= mesh.shape[a]
        logits = tok_local * vocab_local * 4 * 2         # fp32 rw
        total = weights + grads + opt + carries + logits
        return {"params": p_bytes, "optimizer": m_bytes, "total": total,
                "weights_traffic": weights, "carries": carries,
                "logits": logits}

    p_bytes = _sharded_bytes(specs["params"], rules, mesh)
    c_bytes = _sharded_bytes(specs["caches"], rules, mesh)
    if shape.kind == "decode":
        # one token: stream weights once, read the whole cache, tiny writes
        total = p_bytes + c_bytes
        return {"params": p_bytes, "cache": c_bytes, "total": total}
    # prefill: stream weights, write cache once, activation rw per layer
    tok_local = shape.global_batch * shape.seq_len // dsize * \
        max(mesh.shape.get("model", 1), 1)   # batch over data(,pod) only
    act = jnp.dtype(cfg.compute_dtype).itemsize
    acts = tok_local * cfg.d_model * act * cfg.n_layers * 2
    total = p_bytes + c_bytes + acts
    return {"params": p_bytes, "cache": c_bytes, "acts": acts,
            "total": total}
