"""Training launcher: `PYTHONPATH=src python -m repro.launch.train
--arch <id> [--steps N] [--reduced]`.

On this CPU container use --reduced (the full configs are exercised via
the dry-run); on a real TPU slice the same entrypoint builds the
production mesh and shards per TRAIN_RULES.
"""
from __future__ import annotations

import argparse

from repro.configs import ARCHS, get_config, get_reduced
from repro.models.model import RunFlags
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import TrainConfig, train


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU-runnable) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    tc = TrainConfig(
        steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        checkpoint_dir=args.ckpt, grad_compression=args.grad_compression,
        opt=AdamWConfig(lr=args.lr, total_steps=args.steps),
        flags=RunFlags(grad_accum=args.grad_accum))
    train(cfg, tc)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
