"""Dry-run proof for the optional GPipe pipeline over the "pod" axis:
lower + compile a 2-stage pipelined train loss (+grad) for granite-20b on
the (2,16,16) production mesh.

    PYTHONPATH=src python -m repro.launch.dryrun_pipeline
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.models.model import RunFlags, build_param_specs
from repro.models.params import ParamSpec, abstract, is_spec, tree_map_specs
from repro.training.pipeline import make_pipelined_train_loss


def main() -> int:
    mesh = make_production_mesh(multi_pod=True)       # (2, 16, 16)
    cfg = get_config("granite-20b")                   # 52L dense: 2x26
    flags = RunFlags(remat="full")
    n_stages = mesh.shape["pod"]

    # staged abstract params: leading stage dim, sharded over "pod";
    # within a stage, TP over "model" (heads/ffn/vocab as usual)
    specs = build_param_specs(cfg)
    gname = cfg.groups[0].name
    L = cfg.groups[0].repeats

    def stage_spec(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n_stages, L // n_stages) + s.shape[1:], s.dtype,
                         ("stage",) + s.axes, s.init)
    specs["groups"] = {gname: {"pos0": tree_map_specs(
        stage_spec, specs["groups"][gname]["pos0"])}}

    from repro.distributed.sharding import TRAIN_RULES, partition_spec
    rules = dict(TRAIN_RULES, stage=[("pod",)], batch=[("data",)])

    def shard_of(s: ParamSpec):
        return NamedSharding(mesh, partition_spec(s.axes, s.shape, rules,
                                                  mesh))
    param_sh = tree_map_specs(shard_of, specs)
    params_abs = abstract(specs)

    B, S, M = 64, 1024, 4
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    batch_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch_abs}

    loss_fn = make_pipelined_train_loss(cfg, mesh, n_microbatches=M,
                                        flags=flags)
    grad_fn = jax.value_and_grad(loss_fn)
    jf = jax.jit(grad_fn, in_shardings=(param_sh, batch_sh),
                 out_shardings=(NamedSharding(mesh, P()), param_sh))
    t0 = time.time()
    with mesh:
        compiled = jf.lower(params_abs, batch_abs).compile()
    dt = time.time() - t0
    ma = compiled.memory_analysis()
    print(f"[dryrun-pipeline] granite-20b 2-stage GPipe (M={M}) on "
          f"(2,16,16): compiled in {dt:.0f}s")
    print(f"  memory_analysis: {ma}")
    txt = compiled.as_text()
    n_permute = txt.count("collective-permute")
    print(f"  collective-permute ops in HLO: {n_permute} "
          f"(the cross-pod activation handoffs)")
    assert n_permute > 0, "pipeline must lower to collective-permute"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
