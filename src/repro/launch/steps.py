"""Step builders + abstract input specs for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input (tokens/labels/caches/frontend stubs) --
shardable, zero-allocation -- plus the matching logical-axes trees the
sharding rules consume.  ``make_*_step`` return the pure functions that
jit/lower against those specs; the dry-run, the roofline benchmarks and
the real launchers (train.py / serve.py) all go through here so the
lowered computation is identical everywhere.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.distributed.sharding import (LONG_SERVE_BIG_RULES,
                                        LONG_SERVE_RULES, SERVE_BIG_RULES,
                                        SERVE_RULES, TRAIN_RULES, RuleSet,
                                        activation_sharding, partition_spec,
                                        shardings_for_specs)
from repro.models.config import ArchConfig
from repro.models.model import (RunFlags, build_cache_specs,
                                build_param_specs, decode_step, prefill,
                                train_loss)
from repro.models.params import ParamSpec, abstract, is_spec, spec
from repro.training.compression import compress_grads
from repro.training.optimizer import AdamWConfig, adamw_init_specs, \
    adamw_update

Tree = Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) -- DESIGN.md section 4 skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: O(seq) KV per layer at "
                       "524k is architecturally unbounded; skipped per "
                       "assignment (DESIGN.md section 4)")
    return True, ""


def rules_for(shape: ShapeSpec, cfg: Optional[ArchConfig] = None
              ) -> RuleSet:
    if shape.kind == "train":
        return TRAIN_RULES
    big = cfg is not None and cfg.param_count() * 2 / 16 > 12e9
    if shape.global_batch == 1:
        return LONG_SERVE_BIG_RULES if big else LONG_SERVE_RULES
    return SERVE_BIG_RULES if big else SERVE_RULES


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct) + logical axes, per shape kind
# ---------------------------------------------------------------------------

def _batch_specs(cfg: ArchConfig, b: int, s: int) -> Tree:
    t = {"tokens": spec([b, s], ["batch", "seq"], jnp.int32, "zeros"),
         "labels": spec([b, s], ["batch", "seq"], jnp.int32, "zeros")}
    if cfg.encoder is not None:
        t["source_embeds"] = spec(
            [b, cfg.encoder.source_len, cfg.d_model],
            ["batch", "seq", None], jnp.bfloat16, "zeros")
    if cfg.n_prefix_embeddings > 0:
        t["prefix_embeds"] = spec(
            [b, cfg.n_prefix_embeddings, cfg.d_model],
            ["batch", "seq", None], jnp.bfloat16, "zeros")
    return t


def train_state_specs(cfg: ArchConfig, *, compression: bool = False
                      ) -> Tree:
    p = build_param_specs(cfg)
    mu, nu = adamw_init_specs(p)
    state = {"params": p, "mu": mu, "nu": nu,
             "step": spec([], [], jnp.int32, "zeros")}
    if compression:
        # error-feedback residuals for int8 gradient compression
        ef, _ = adamw_init_specs(p)
        state["ef"] = ef
    return state


def _cache_dt(flags: Optional[RunFlags]):
    if flags is not None and flags.cache_dtype == "int8":
        return jnp.int8
    return jnp.bfloat16


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                flags: Optional[RunFlags] = None) -> Dict[str, Tree]:
    """All abstract inputs for one cell, keyed by step argument name."""
    if shape.kind == "train":
        return {"state": train_state_specs(cfg),
                "batch": _batch_specs(cfg, shape.global_batch,
                                      shape.seq_len)}
    if shape.kind == "prefill":
        batch = _batch_specs(cfg, shape.global_batch, shape.seq_len)
        batch.pop("labels")
        # VLM prefix embeddings extend the prefill sequence past seq_len
        cache_len = shape.seq_len + cfg.n_prefix_embeddings
        return {"params": build_param_specs(cfg),
                "batch": batch,
                "caches": build_cache_specs(cfg, shape.global_batch,
                                            cache_len, _cache_dt(flags))}
    if shape.kind == "decode":
        b = shape.global_batch
        return {"params": build_param_specs(cfg),
                "tokens": spec([b, 1], ["batch", "seq"], jnp.int32, "zeros"),
                "caches": build_cache_specs(cfg, b, shape.seq_len,
                                            _cache_dt(flags)),
                "pos": spec([], [], jnp.int32, "zeros")}
    raise ValueError(shape.kind)


def abstract_inputs(cfg: ArchConfig, shape: ShapeSpec,
                    flags: Optional[RunFlags] = None) -> Dict[str, Tree]:
    return {k: abstract(v)
            for k, v in input_specs(cfg, shape, flags).items()}


def input_shardings(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                    flags: Optional[RunFlags] = None) -> Dict[str, Tree]:
    rules = rules_for(shape, cfg)
    return {k: shardings_for_specs(v, rules, mesh)
            for k, v in input_specs(cfg, shape, flags).items()}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def _act_ctx(mesh: Optional[Mesh], rules: Optional[RuleSet]):
    """Activation-hint context for traced step bodies (no-op when unset)."""
    if mesh is None or rules is None:
        return contextlib.nullcontext()
    return activation_sharding(mesh, rules)


def make_train_step(cfg: ArchConfig, opt: AdamWConfig = AdamWConfig(),
                    flags: RunFlags = RunFlags(),
                    mesh: Optional[Mesh] = None,
                    rules: Optional[RuleSet] = None,
                    compression: bool = False) -> Callable:
    def train_step(state: Tree, batch: Tree) -> Tuple[Tree, Tree]:
        with _act_ctx(mesh, rules):
            accum = max(flags.grad_accum, 1)
            if accum == 1:
                def loss_fn(p):
                    return train_loss(p, batch, cfg, flags)
                loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            else:
                # microbatch gradient accumulation: splits the global batch
                # on the leading axis; shrinks saved activations by `accum`
                # and overlaps per-microbatch DCN gradient reduction with
                # the next microbatch's compute under the XLA scheduler.
                micro = jax.tree_util.tree_map(
                    lambda x: x.reshape((accum, x.shape[0] // accum)
                                        + x.shape[1:]), batch)

                def body(carry, mb):
                    loss_acc, grad_acc = carry
                    def loss_fn(p):
                        return train_loss(p, mb, cfg, flags)
                    l, g = jax.value_and_grad(loss_fn)(state["params"])
                    grad_acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(a.dtype), grad_acc, g)
                    return (loss_acc + l, grad_acc), None

                zero_g = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                (loss, grads), _ = jax.lax.scan(
                    body, (jnp.zeros((), jnp.float32), zero_g), micro)
                loss = loss / accum
                grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            new_ef = None
            if compression:
                # int8 round-trip + error feedback BEFORE the (DCN)
                # gradient reduction consumes them (training/compression)
                grads, new_ef = compress_grads(grads, state["ef"])
            new_p, new_mu, new_nu, gnorm = adamw_update(
                state["params"], grads, state["mu"], state["nu"],
                state["step"], opt)
            new_state = {"params": new_p, "mu": new_mu, "nu": new_nu,
                         "step": state["step"] + 1}
            if compression:
                new_state["ef"] = new_ef
            return new_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_prefill_step(cfg: ArchConfig, flags: RunFlags = RunFlags(),
                      mesh: Optional[Mesh] = None,
                      rules: Optional[RuleSet] = None) -> Callable:
    def prefill_step(params: Tree, batch: Tree, caches: Tree):
        with _act_ctx(mesh, rules):
            return prefill(params, batch, caches, cfg, flags)
    return prefill_step


def make_decode_step(cfg: ArchConfig, flags: RunFlags = RunFlags(),
                     mesh: Optional[Mesh] = None,
                     rules: Optional[RuleSet] = None) -> Callable:
    def serve_step(params: Tree, tokens: jnp.ndarray, caches: Tree,
                   pos: jnp.ndarray):
        with _act_ctx(mesh, rules):
            return decode_step(params, tokens, caches, pos, cfg, flags)
    return serve_step


def jit_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
             flags: RunFlags = RunFlags(),
             opt: AdamWConfig = AdamWConfig()):
    """jit-with-shardings for one (arch x shape) cell.  Returns
    (jitted_fn, abstract_args_tuple) ready for .lower(*args)."""
    shard = input_shardings(cfg, shape, mesh, flags)
    abstr = abstract_inputs(cfg, shape, flags)
    rules = rules_for(shape, cfg)

    def logits_sharding(b):
        return NamedSharding(mesh, partition_spec(
            ("batch", "vocab"), (b, cfg.vocab_size), rules, mesh))

    if shape.kind == "train":
        fn = make_train_step(cfg, opt, flags, mesh=mesh, rules=rules)
        in_sh = (shard["state"], shard["batch"])
        out_sh = (shard["state"],
                  {"loss": NamedSharding(mesh, PartitionSpec()),
                   "grad_norm": NamedSharding(mesh, PartitionSpec())})
        args = (abstr["state"], abstr["batch"])
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0,))
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg, flags, mesh=mesh, rules=rules)
        in_sh = (shard["params"], shard["batch"], shard["caches"])
        out_sh = (logits_sharding(shape.global_batch), shard["caches"])
        args = (abstr["params"], abstr["batch"], abstr["caches"])
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(2,))
    else:
        fn = make_decode_step(cfg, flags, mesh=mesh, rules=rules)
        in_sh = (shard["params"], shard["tokens"], shard["caches"],
                 shard["pos"])
        out_sh = (logits_sharding(shape.global_batch), shard["caches"])
        args = (abstr["params"], abstr["tokens"], abstr["caches"],
                abstr["pos"])
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(2,))
    return jf, args
