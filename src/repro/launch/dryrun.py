"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles, and fits (DESIGN.md sections 5-6).

Per cell:  jit(step, in_shardings, out_shardings).lower(**abstract).compile()
then record memory_analysis / cost_analysis / parsed collective bytes into
benchmarks/dryrun_results/<arch>_<shape>_<mesh>[_<tag>].json, which the
roofline benchmark and EXPERIMENTS.md tables read.

Usage:
    python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k \
        --mesh single [--tag baseline] [--moe-impl onehot] [--remat full]
    python -m repro.launch.dryrun --all --mesh single       # every cell
    python -m repro.launch.dryrun --list                    # cell matrix
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) fakes 512 host devices so
# jax.make_mesh can build the production meshes; smoke tests and benches
# see the real single CPU device.

import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.launch import steps as steps_lib
from repro.launch.analytic import analytic_bytes_per_device
from repro.launch.hloanalysis import HBM_BW, PEAK_FLOPS, analyze_compiled
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import SHAPES, jit_cell, shape_applicable
from repro.models.model import RunFlags

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "dryrun_results"

# the ten assigned archs (qwen2-5-7b is the paper-validation extra)
ASSIGNED = [a for a in ARCHS if a != "qwen2-5-7b"]


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             flags: RunFlags = RunFlags(), tag: str = "baseline",
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "tag": tag, "status": "skipped", "reason": why}
    if shape.kind == "train" and flags.grad_accum == 0:
        # auto policy: the >=100B archs need microbatching to fit 16 GB HBM
        accum = 4 if cfg.param_count() > 1e11 else 1
        flags = dataclasses.replace(flags, grad_accum=accum)
    elif flags.grad_accum == 0:
        flags = dataclasses.replace(flags, grad_accum=1)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    t0 = time.time()
    jf, args = jit_cell(cfg, shape, mesh, flags=flags)
    with mesh:
        lowered = jf.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()          # memory/fits proof (real cfg)
        t_compile = time.time() - t0 - t_lower
        # Cost extrapolation pair: XLA counts while bodies once, so the
        # layer scan AND the grad-accum scan undercount.  Totals (flops /
        # bytes / collective volume) of accum=k equal accum=1 up to
        # per-microbatch overhead, so the cost pair is compiled at
        # accum=1 with scan unroll 1 vs 2 (see hloanalysis).
        scan_repeats = max((g.repeats for g in cfg.groups), default=1)
        cost_flags = dataclasses.replace(flags, grad_accum=1)
        if flags.grad_accum > 1:
            jfc, argsc = jit_cell(cfg, shape, mesh, flags=cost_flags)
            compiled_cost = jfc.lower(*argsc).compile()
        else:
            compiled_cost = compiled
        compiled_u2 = None
        if scan_repeats > 1 and flags.scan_unroll == 1:
            flags_u2 = dataclasses.replace(cost_flags, scan_unroll=2)
            jf2, args2 = jit_cell(cfg, shape, mesh, flags=flags_u2)
            compiled_u2 = jf2.lower(*args2).compile()
    train = shape.kind == "train"
    # decode steps process 1 token per sequence; train/prefill the full seq
    tokens = shape.global_batch * \
        (1 if shape.kind == "decode" else shape.seq_len)
    model_flops = cfg.model_flops_per_token(train=train) * tokens
    rep = analyze_compiled(
        compiled_cost, arch=arch, shape=shape_name, mesh_name=mesh_name,
        n_devices=mesh.size, model_flops_global=model_flops, tag=tag,
        compiled_unroll2=compiled_u2, scan_repeats=scan_repeats)
    # memory/fits numbers must come from the real-config compile
    ma_real = compiled.memory_analysis()
    rep = dataclasses.replace(
        rep,
        argument_bytes=int(getattr(ma_real, "argument_size_in_bytes", 0)),
        output_bytes=int(getattr(ma_real, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma_real, "temp_size_in_bytes", 0)),
        alias_bytes=int(getattr(ma_real, "alias_size_in_bytes", 0)))
    out = rep.to_dict()
    # analytic HBM floor (CPU byte counts are fp32-upcast-inflated; see
    # launch/analytic.py) -- recorded alongside, used for the memory term
    # in the roofline table with the measured value kept for reference.
    ab = analytic_bytes_per_device(cfg, shape, mesh, remat=flags.remat,
                                   flags=flags)
    out["analytic_bytes"] = {k: float(v) for k, v in ab.items()}
    out["memory_floor_s"] = float(ab["total"]) / HBM_BW
    terms = {"compute": rep.compute_s, "memory": out["memory_floor_s"],
             "collective": rep.collective_s}
    out["dominant_floor"] = max(terms, key=terms.get)
    useful_s = model_flops / (mesh.size * PEAK_FLOPS)
    out["bound_floor_s"] = max(terms.values())
    out["roofline_fraction_floor"] = (useful_s / out["bound_floor_s"]
                                      if out["bound_floor_s"] else 0.0)
    out.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1),
               flags={"remat": flags.remat, "moe_impl": flags.moe_impl,
                      "scan_unroll": flags.scan_unroll,
                      "grad_accum": flags.grad_accum,
                      "attn_chunk": flags.attn_chunk})
    if verbose:
        ma_gib = rep.peak_device_bytes / 2**30
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ({tag}): "
              f"compile {t_compile:.0f}s | {ma_gib:.2f} GiB/dev | "
              f"compute {rep.compute_s*1e3:.2f} ms, "
              f"memory(floor) {out['memory_floor_s']*1e3:.2f} ms "
              f"(hlo {rep.memory_s*1e3:.0f} ms), "
              f"collective {rep.collective_s*1e3:.2f} ms "
              f"-> {out['dominant_floor']}-bound | useful-FLOP ratio "
              f"{rep.useful_flops_ratio:.2f} | roofline-frac "
              f"{out['roofline_fraction_floor']:.3f}")
        print("  memory_analysis:", compiled.memory_analysis())
        ca = compiled.cost_analysis() or {}
        print(f"  cost_analysis: flops/dev={ca.get('flops', 0):.3e} "
              f"bytes/dev={ca.get('bytes accessed', 0):.3e}")
        print(f"  collectives: {rep.collective_counts} "
              f"bytes={rep.collective_detail}")
    return out


def save_result(res: dict) -> pathlib.Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    tag = res.get("tag", "baseline")
    name = f"{res['arch']}_{res['shape']}_{res['mesh']}"
    if tag != "baseline":
        name += f"_{tag}"
    path = RESULTS_DIR / f"{name}.json"
    path.write_text(json.dumps(res, indent=1, default=str))
    return path


def cell_matrix():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            yield arch, sname, ok, why


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--moe-impl", default=None, choices=["onehot", "dense"])
    ap.add_argument("--scan-unroll", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=0,
                    help="microbatches per step (0 = auto: 4 for >100B-"
                         "param archs on train shapes, else 1)")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--cache-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--moe-group", type=int, default=0)
    ap.add_argument("--all", action="store_true",
                    help="run every applicable cell on --mesh")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have results")
    args = ap.parse_args(argv)

    if args.list:
        for arch, sname, ok, why in cell_matrix():
            print(f"{arch:22s} {sname:12s} "
                  f"{'RUN' if ok else 'SKIP: ' + why}")
        return 0

    flags = RunFlags(remat=args.remat, moe_impl=args.moe_impl,
                     scan_unroll=args.scan_unroll,
                     attn_chunk=args.attn_chunk,
                     grad_accum=args.grad_accum,
                     cache_dtype=args.cache_dtype,
                     moe_group=args.moe_group)
    if args.all:
        failures = []
        for arch, sname, ok, why in cell_matrix():
            name = f"{arch}_{sname}_{args.mesh}"
            if args.tag != "baseline":
                name += f"_{args.tag}"
            path = RESULTS_DIR / f"{name}.json"
            if path.exists() and not args.force:
                print(f"[dryrun] {name}: cached")
                continue
            try:
                res = run_cell(arch, sname, args.mesh, flags=flags,
                               tag=args.tag)
            except Exception as e:                      # noqa: BLE001
                traceback.print_exc()
                res = {"arch": arch, "shape": sname, "mesh": args.mesh,
                       "tag": args.tag, "status": "error", "error": str(e)}
                failures.append(name)
            save_result(res)
        if failures:
            print("FAILED cells:", failures)
            return 1
        return 0

    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all/--list)")
    res = run_cell(args.arch, args.shape, args.mesh, flags=flags,
                   tag=args.tag)
    save_result(res)
    return 0 if res["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
