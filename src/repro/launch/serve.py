"""Serving launcher: `PYTHONPATH=src python -m repro.launch.serve
--arch <id> --reduced [--policy breakeven] [--trace bursty]`.

Spins up the energy-aware ModelManager + ServingEngine for one arch and
replays a traffic trace (see examples/serve_parking.py for the annotated
walkthrough).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, get_config, get_reduced
from repro.core import H100, PROFILES, loader_from_checkpoint
from repro.core.scheduler import (AdaptiveBreakeven, AlwaysOn, Breakeven,
                                  FixedTTL)
from repro.core import traffic
from repro.models import RunFlags, build_param_specs, materialize, \
    param_bytes
from repro.serving import ModelManager, ServingEngine, SimClock


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="breakeven",
                    choices=["always-on", "ttl", "breakeven", "adaptive"])
    ap.add_argument("--trace", default="bursty",
                    choices=list(traffic.PATTERNS))
    ap.add_argument("--device", default="h100", choices=list(PROFILES))
    ap.add_argument("--hours", type=float, default=6.0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    profile = PROFILES[args.device]
    # per-arch loader derived from the FULL config's checkpoint bytes
    full_bytes = param_bytes(build_param_specs(get_config(args.arch)))
    loader = loader_from_checkpoint(args.arch, full_bytes, profile)
    print(f"[serve] {cfg.name} on {profile.name}: checkpoint "
          f"{full_bytes/2**30:.1f} GiB -> t_load {loader.t_load_s:.1f}s, "
          f"parking tax {profile.dvfs_step_w:.1f} W")

    policy = {
        "always-on": AlwaysOn(),
        "ttl": FixedTTL(300.0),
        "breakeven": Breakeven(loader, profile),
        "adaptive": AdaptiveBreakeven(loader, profile),
    }[args.policy]

    params = materialize(build_param_specs(cfg), jax.random.PRNGKey(0))

    def load_engine():
        return ServingEngine(cfg, params, max_batch=4, max_len=48,
                             flags=RunFlags(remat="none"))

    mm = ModelManager(profile, clock=SimClock())
    mm.register(cfg.name, policy=policy, loader=loader,
                load_fn=load_engine)
    arrivals = traffic.PATTERNS[args.trace](seed=0)
    arrivals = [a for a in arrivals if a < args.hours * 3600.0]
    mm.handle_request(cfg.name,
                      work_fn=lambda e: e.generate([1, 2, 3], max_new=4))
    for a in arrivals:
        mm._advance_with_evictions(max(float(a), mm.clock()))
        mm.handle_request(cfg.name,
                          work_fn=lambda e: e.generate([1, 2, 3],
                                                       max_new=4))
    mm._advance_with_evictions(args.hours * 3600.0)
    m = mm.models[cfg.name]
    wh = mm.meter.totals()
    print(f"[serve] {policy.name}: {m.requests} requests, "
          f"{m.cold_starts} cold starts, energy {wh['total']:.1f} Wh "
          f"(parking tax {mm.meter.parking_tax_wh():.1f} Wh), "
          f"mean added latency {m.added_latency_s/max(m.requests,1):.2f} s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
