"""Roofline-term extraction from a compiled SPMD module (DESIGN.md sec. 6).

Terms per the assignment:

    compute    = HLO_FLOPs      / (chips * 197 TFLOP/s)
    memory     = HLO_bytes      / (chips * 819 GB/s)
    collective = coll_bytes     / (chips * 50 GB/s)

``compiled.cost_analysis()`` reports flops / bytes-accessed of the
*per-device* partitioned module, so global = per-device * chips and the
chips factor cancels: each term is simply per-device quantity / per-chip
rate.  Collective bytes are not in cost_analysis; we parse the compiled
HLO text, build a %name -> result-bytes table, and sum *operand* sizes of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute (operand convention per the assignment; async
``*-done`` halves are skipped to avoid double counting).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

# TPU v5e-class constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per chip (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                   "all-to-all", "collective-permute")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every dtype[dims] literal in `text` (tuples too)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of collective ops in (per-device) HLO text."""
    # pass 1: result sizes of every named instruction
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # result type(s) = everything before the op name token
        # e.g.  "f32[32,64]{1,0} all-reduce(%dot.1), channel_id=..."
        op_pos = rhs.find("(")
        head = rhs[:op_pos] if op_pos > 0 else rhs
        sizes[name] = _shape_bytes(head)

    bytes_by: Dict[str, int] = {op: 0 for op in _COLLECTIVE_OPS}
    count_by: Dict[str, int] = {op: 0 for op in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        for op in _COLLECTIVE_OPS:
            # match "  all-reduce(" / "all-reduce-start(" but not "-done("
            hit = re.search(rf"\b{op}(-start)?\(", rhs)
            if not hit:
                continue
            if f"{op}-done" in rhs:
                continue
            # operands: %refs inside the call parens
            inner = rhs[rhs.find("(") + 1:]
            refs = re.findall(r"%[\w.\-]+", inner)
            if refs:
                b = sum(sizes.get(r, 0) for r in refs)
            else:
                b = _shape_bytes(rhs[:rhs.find(op)])
            bytes_by[op] += b
            count_by[op] += 1
            break
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_detail: Dict[str, int]
    collective_counts: Dict[str, int]
    # memory_analysis
    argument_bytes: int
    output_bytes: int
    temp_bytes: int
    alias_bytes: int
    model_flops_global: float           # 6 N_active D (or 2 N_active D)
    tag: str = "baseline"

    # -- derived ----------------------------------------------------------
    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline step time lower bound (no overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs(global) -- remat/dispatch waste meter."""
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful compute time / roofline-bound step
        time, i.e. (MODEL_FLOPS/(chips*peak)) / max(terms)."""
        useful_s = self.model_flops_global / (self.n_devices * PEAK_FLOPS)
        return useful_s / self.bound_s if self.bound_s else 0.0

    @property
    def peak_device_bytes(self) -> int:
        return self.argument_bytes + self.output_bytes + self.temp_bytes \
            - self.alias_bytes

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        for k in ("compute_s", "memory_s", "collective_s", "dominant",
                  "bound_s", "useful_flops_ratio", "roofline_fraction",
                  "peak_device_bytes"):
            d[k] = getattr(self, k)
        return d


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     n_devices: int, model_flops_global: float,
                     tag: str = "baseline",
                     compiled_unroll2=None,
                     scan_repeats: int = 1) -> RooflineReport:
    """Build a RooflineReport from compiled artifacts.

    XLA's HloCostAnalysis visits a while (lax.scan) body ONCE -- it does
    not multiply by trip count -- so flops / bytes / in-loop collective
    counts of a scanned model are undercounted by ~the layer count.  When
    ``compiled_unroll2`` (same cell lowered with scan unroll=2) is given,
    we use two-point extrapolation: unroll=2 duplicates the body once, so

        body_cost  = cost(u2) - cost(u1)
        true_cost  = cost(u1) + (R - 1) * body_cost

    with R = scan_repeats.  Costs OUTSIDE the loop (e.g. the gradient
    all-reduce over stacked layer params) cancel in the difference and are
    correctly not scaled.  Length-1 scan groups never unroll (see
    models/model.py), so their single execution stays in the constant.
    """
    def metrics(c):
        ca = c.cost_analysis() or {}
        coll = parse_collectives(c.as_text())
        return (float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)),
                coll)

    f1, b1, coll1 = metrics(compiled)
    if compiled_unroll2 is not None and scan_repeats > 1:
        f2, b2, coll2 = metrics(compiled_unroll2)
        r = scan_repeats
        flops = f1 + (r - 1) * max(f2 - f1, 0.0)
        bts = b1 + (r - 1) * max(b2 - b1, 0.0)
        coll_bytes = {
            op: coll1.bytes_by_op[op] + (r - 1) * max(
                coll2.bytes_by_op[op] - coll1.bytes_by_op[op], 0)
            for op in coll1.bytes_by_op}
        coll_counts = {
            op: coll1.count_by_op[op] + (r - 1) * max(
                coll2.count_by_op[op] - coll1.count_by_op[op], 0)
            for op in coll1.count_by_op}
        coll_total = sum(coll_bytes.values())
    else:
        flops, bts = f1, b1
        coll_bytes, coll_counts = coll1.bytes_by_op, coll1.count_by_op
        coll_total = coll1.total_bytes

    ma = compiled.memory_analysis()
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=flops,
        bytes_per_device=bts,
        collective_bytes_per_device=float(coll_total),
        collective_detail=coll_bytes,
        collective_counts=coll_counts,
        argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
        alias_bytes=int(getattr(ma, "alias_size_in_bytes", 0)),
        model_flops_global=model_flops_global,
        tag=tag,
    )
