"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are the semantics the kernels must match; tests sweep shapes/dtypes
and assert against these.  They are intentionally simple -- full softmax,
full materialization -- and correct.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q: [B,H,S,D]; k,v: [B,Hkv,T,D] with H a multiple of Hkv.
    Positions are 0..S-1 / 0..T-1 (prefill semantics, S == T)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / math.sqrt(d)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((s, k.shape[2]), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= qi - ki < window
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, vv.astype(jnp.float32)) \
        .astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         length: jnp.ndarray | int) -> jnp.ndarray:
    """Single-token GQA decode.  q: [B,H,D]; k,v: [B,Hkv,T,D]; `length` =
    number of valid cache entries (attend to positions < length)."""
    b, h, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / math.sqrt(d)
    valid = jnp.arange(t)[None, None, :] < jnp.asarray(length).reshape(-1, 1, 1)
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", w, vv.astype(jnp.float32)) \
        .astype(q.dtype)


def segment_trapz_ref(a: jnp.ndarray, b: jnp.ndarray, w: jnp.ndarray,
                      kt: jnp.ndarray, kv: jnp.ndarray, cum: jnp.ndarray, *,
                      period: float) -> jnp.ndarray:
    """Per-segment trapezoid integrals of a periodic piecewise-linear
    curve: ``out_i = w_i * (F(b_i) - F(a_i))`` with F the prefix
    integral of the curve described by extended knots (kt, kv) and
    prefix integrals cum over [0, period] (``CarbonTrace`` internals).
    a, b, w: [N]; kt, kv, cum: [K]."""
    total = cum[-1]

    def prefix(t):
        k = jnp.floor(t / period)
        p = t - k * period
        j = jnp.clip(jnp.searchsorted(kt, p, side="right") - 1,
                     0, kt.shape[0] - 2)
        span = kt[j + 1] - kt[j]
        dt = p - kt[j]
        v_p = kv[j] + (kv[j + 1] - kv[j]) * dt / jnp.where(span > 0, span,
                                                           1.0)
        return k * total + cum[j] + dt * (kv[j] + v_p) * 0.5

    return w * (prefix(b) - prefix(a))


def fused_meter_ref(a: jnp.ndarray, b: jnp.ndarray, dt: jnp.ndarray,
                    w: jnp.ndarray, g: jnp.ndarray,
                    kt: jnp.ndarray, kv: jnp.ndarray, cum: jnp.ndarray,
                    periods: jnp.ndarray):
    """Fused metering pass (see ``segment_trapz.fused_meter``): per
    charge-log entry emit energy ``w * dt``, seconds ``dt``, carbon
    increment ``w * (F_g(b) - F_g(a))``, and ``F_g(a)``.  kt, kv, cum
    are stacked ``[G, K]`` extended knot tables (rows padded by
    repeating the last knot); g: [N] int32 selects each entry's row;
    periods: [G].  Uses the same compare-and-sum knot lookup as the
    kernel (row-wise tables rule out a shared ``searchsorted``)."""
    ktg = jnp.take(kt, g, axis=0)               # [N, K]
    kvg = jnp.take(kv, g, axis=0)
    cumg = jnp.take(cum, g, axis=0)
    per = jnp.take(periods, g)
    total = cumg[:, -1]

    def prefix(t):
        k = jnp.floor(t / per)
        p = t - k * per
        j = jnp.sum((ktg <= p[:, None]).astype(jnp.int32), axis=1) - 1
        j = jnp.clip(j, 0, ktg.shape[1] - 2)[:, None]
        take = jnp.take_along_axis
        kt_j = take(ktg, j, axis=1)[:, 0]
        kv_j = take(kvg, j, axis=1)[:, 0]
        span = take(ktg, j + 1, axis=1)[:, 0] - kt_j
        d = p - kt_j
        v_p = kv_j + (take(kvg, j + 1, axis=1)[:, 0] - kv_j) * d \
            / jnp.where(span > 0, span, 1.0)
        return (k * total + take(cumg, j, axis=1)[:, 0]
                + d * (kv_j + v_p) * 0.5)

    fa = prefix(a)
    return w * dt, dt, w * (prefix(b) - fa), fa


def rglru_scan_ref(a: jnp.ndarray, bx: jnp.ndarray,
                   h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + b_t.
    a, bx: [B,S,W] fp32; h0: [B,W] or None.  Returns h: [B,S,W]."""
    a = a.astype(jnp.float32)
    bx = bx.astype(jnp.float32)
    if h0 is not None:
        bx = bx.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h
