"""Flash attention (prefill) as a Pallas TPU kernel.

TPU adaptation of the flash algorithm (DESIGN.md section 3): the grid is
(batch, q_heads, S/BQ); each program streams K/V blocks of BK rows from
HBM through VMEM, keeping the online-softmax running max/denominator and
the output accumulator in fp32 VMEM scratch.  Block sizes are multiples
of 128 so the MXU sees aligned matmuls; GQA is handled in the BlockSpec
index maps (q head h reads kv head h // group -- no jnp.repeat
materialization).  Sliding windows skip fully-masked K blocks via
jax.lax.cond on block bounds.

Forward-only: the serving hot path (prefill/decode) is where the paper's
framework spends its compute; training uses the XLA/chunked path.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                  seq_k: int, causal: bool, window: Optional[int],
                  scale: float):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # [BQ, D]
    d = q.shape[-1]

    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, d), jnp.float32)

    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)        # absolute q rows

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.ds(ki * bk, bk)].astype(jnp.float32)   # [BK, D]
        v = v_ref[0, 0, pl.ds(ki * bk, bk)].astype(jnp.float32)
        s = q @ k.T                                       # [BQ, BK]
        k_pos = ki * bk + jax.lax.iota(jnp.int32, bk)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    # block range: causal/window lets us skip fully-masked K blocks
    hi = seq_k // bk
    if causal:
        hi_dyn = (qi * bq + bq + bk - 1) // bk
        hi_dyn = jnp.minimum(hi_dyn, hi)
    else:
        hi_dyn = hi
    if window is not None:
        lo_dyn = jnp.maximum((qi * bq - window) // bk, 0)
    else:
        lo_dyn = 0
    m, l, acc = jax.lax.fori_loop(lo_dyn, hi_dyn, body, (m, l, acc))
    out = acc / jnp.maximum(l, 1e-20)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B,H,S,D]; k,v: [B,Hkv,T,D].  Returns [B,H,S,D].

    interpret=True runs the kernel body in Python on CPU (this container);
    on TPU pass interpret=False for the compiled Mosaic kernel.
    """
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, "seq lens must divide block sizes"
    scale = 1.0 / math.sqrt(d)

    grid = (b, h, s // bq)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, seq_k=t,
                               causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, t, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, t, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
