"""Per-segment trapezoid integrals of a periodic piecewise-linear
function, as a Pallas kernel: the carbon-integration primitive of the
mega-simulator's jax backend (``fleet/mega/jaxback.py``).

Given a metered power timeline -- segments ``(a_i, b_i, w_i)`` with
constant power ``w_i`` over ``[a_i, b_i]`` -- and a periodic
piecewise-linear intensity curve ``i(t)`` described by its extended
knots (``CarbonTrace`` internals: knot times ``kt`` covering
``[0, period]``, knot values ``kv``, and prefix trapezoid integrals
``cum``), compute per segment

    out_i = w_i * (F(b_i) - F(a_i)),   F(t) = \\int_0^t i(u) du

exactly (trapezoids between knots, whole periods factored out) -- the
same closed form ``CarbonTrace.integral`` evaluates one segment at a
time in Python, across a million metered segments in one pass.

The kernel is embarrassingly parallel over segments: grid over
``BN``-sized segment blocks, the (small, <=64-knot) curve tables
broadcast to every program.  The knot lookup is branchless -- a
``[BN, K]`` compare-and-sum instead of a binary search -- which is the
VPU-friendly shape (K is tiny, so the redundant compares are free
next to the HBM stream of segment endpoints).  ``jnp.take`` gathers
along the knot axis stay in VMEM.

Numerics: runs in whatever dtype the inputs carry -- float64 under an
``enable_x64`` scope (the fleet accounting convention, CPU/interpret),
float32 on real TPU hardware (which has no f64; the jnp reference in
``ref.py`` is the allclose oracle either way).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segment_trapz_kernel(a_ref, b_ref, w_ref, kt_ref, kv_ref, cum_ref,
                          o_ref, *, period: float):
    kt = kt_ref[...]
    kv = kv_ref[...]
    cum = cum_ref[...]
    total = cum[kt.shape[0] - 1]        # integral over one full period

    def prefix(t):
        """F(t) for t >= 0: whole periods times `total` plus the
        in-period prefix read off the knot tables."""
        k = jnp.floor(t / period)
        p = t - k * period
        # branchless bisect_right(kt, p) - 1: count knots <= p
        j = jnp.sum((kt[None, :] <= p[:, None]).astype(jnp.int32), axis=1) - 1
        j = jnp.clip(j, 0, kt.shape[0] - 2)
        kt_j = jnp.take(kt, j)
        kv_j = jnp.take(kv, j)
        span = jnp.take(kt, j + 1) - kt_j
        dt = p - kt_j
        v_p = kv_j + (jnp.take(kv, j + 1) - kv_j) * dt \
            / jnp.where(span > 0, span, 1.0)
        return k * total + jnp.take(cum, j) + dt * (kv_j + v_p) * 0.5

    o_ref[...] = w_ref[...] * (prefix(b_ref[...]) - prefix(a_ref[...]))


def _fused_meter_kernel(a_ref, b_ref, dt_ref, w_ref, g_ref,
                        kt_ref, kv_ref, cum_ref, per_ref,
                        e_ref, s_ref, c_ref, fa_ref):
    """One pass over the metered charge log: energy, seconds, carbon
    increment, and the prefix integral at each segment start.

    Same closed form as ``_segment_trapz_kernel`` but with STACKED knot
    tables: ``kt/kv/cum`` are ``[G, K]`` (one row per distinct carbon
    trace, rows padded by repeating the last knot -- in-period offsets
    are strictly below the period, so padding never matches a compare)
    and ``per`` is ``[G]``; every log entry gathers its own trace row
    through ``g``.  ``dt`` is passed THROUGH, never recomputed as
    ``b - a``: the energy/seconds outputs must be bit-identical to the
    unfused segment-sum inputs so the 0.0-USD engine anchors survive.
    """
    g = g_ref[...]
    kt = jnp.take(kt_ref[...], g, axis=0)          # [BN, K]
    kv = jnp.take(kv_ref[...], g, axis=0)
    cum = jnp.take(cum_ref[...], g, axis=0)
    per = jnp.take(per_ref[...], g)                # [BN]
    total = cum[:, -1]          # one-period integral (pad repeats last)

    def prefix(t):
        k = jnp.floor(t / per)
        p = t - k * per
        # branchless bisect_right(kt_row, p) - 1, row-wise
        j = jnp.sum((kt <= p[:, None]).astype(jnp.int32), axis=1) - 1
        j = jnp.clip(j, 0, kt.shape[1] - 2)[:, None]
        take = jnp.take_along_axis
        kt_j = take(kt, j, axis=1)[:, 0]
        kv_j = take(kv, j, axis=1)[:, 0]
        span = take(kt, j + 1, axis=1)[:, 0] - kt_j
        dt = p - kt_j
        v_p = kv_j + (take(kv, j + 1, axis=1)[:, 0] - kv_j) * dt \
            / jnp.where(span > 0, span, 1.0)
        return (k * total + take(cum, j, axis=1)[:, 0]
                + dt * (kv_j + v_p) * 0.5)

    dt_v = dt_ref[...]
    w_v = w_ref[...]
    fa = prefix(a_ref[...])
    e_ref[...] = w_v * dt_v
    s_ref[...] = dt_v
    c_ref[...] = w_v * (prefix(b_ref[...]) - fa)
    fa_ref[...] = fa


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def fused_meter(a: jnp.ndarray, b: jnp.ndarray, dt: jnp.ndarray,
                w: jnp.ndarray, g: jnp.ndarray,
                kt: jnp.ndarray, kv: jnp.ndarray, cum: jnp.ndarray,
                periods: jnp.ndarray, *, bn: int = 512,
                interpret: bool = True):
    """Fused metering pass over ``N`` charge-log entries.

    a, b: [N] absolute segment bounds; dt: [N] the metered interval
    (passed through); w: [N] watts; g: [N] int32 trace-group index;
    kt, kv, cum: [G, K] stacked extended knot tables; periods: [G].

    Returns ``(e, s, c, fa)``, all [N]: per-entry joules ``w * dt``,
    seconds ``dt``, carbon increment ``w * (F_g(b) - F_g(a))``, and
    ``F_g(a)`` (the straddle-correction input for the hourly timeline).
    N pads internally to a ``bn`` multiple; pad rows carry w = dt = 0
    and group 0, so every padded output is exactly zero (fa pad values
    are sliced off).
    """
    n = a.shape[0]
    bn = min(bn, max(n, 1))
    pad = (-n) % bn if n else bn
    if pad:
        zf = jnp.zeros(pad, a.dtype)
        a = jnp.concatenate([a, zf])
        b = jnp.concatenate([b, zf])
        dt = jnp.concatenate([dt, zf])
        w = jnp.concatenate([w, zf])
        g = jnp.concatenate([g, jnp.zeros(pad, g.dtype)])
    gk, k = kt.shape
    seg_spec = pl.BlockSpec((bn,), lambda i: (i,))
    tab_spec = pl.BlockSpec((gk, k), lambda i: (0, 0))
    per_spec = pl.BlockSpec((gk,), lambda i: (0,))
    out = pl.pallas_call(
        _fused_meter_kernel,
        grid=(a.shape[0] // bn,),
        in_specs=[seg_spec, seg_spec, seg_spec, seg_spec, seg_spec,
                  tab_spec, tab_spec, tab_spec, per_spec],
        out_specs=(seg_spec, seg_spec, seg_spec, seg_spec),
        out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                        for _ in range(4)),
        interpret=interpret,
    )(a, b, dt, w, g, kt, kv, cum, periods)
    return tuple(o[:n] for o in out)


@functools.partial(jax.jit,
                   static_argnames=("period", "bn", "interpret"))
def segment_trapz(a: jnp.ndarray, b: jnp.ndarray, w: jnp.ndarray,
                  kt: jnp.ndarray, kv: jnp.ndarray, cum: jnp.ndarray, *,
                  period: float, bn: int = 512,
                  interpret: bool = True) -> jnp.ndarray:
    """a, b, w: [N] segment starts/ends/weights; kt, kv, cum: [K]
    extended knot times/values/prefix integrals covering [0, period]
    (``CarbonTrace._kt/_kv/_cum``).  Returns [N] per-segment
    ``w * (F(b) - F(a))``; N is padded internally to a ``bn`` multiple
    (padding contributes exact zeros via w=0)."""
    n = a.shape[0]
    bn = min(bn, max(n, 1))
    pad = (-n) % bn if n else bn
    if pad:
        a = jnp.concatenate([a, jnp.zeros(pad, a.dtype)])
        b = jnp.concatenate([b, jnp.zeros(pad, b.dtype)])
        w = jnp.concatenate([w, jnp.zeros(pad, w.dtype)])
    k = kt.shape[0]
    grid = (a.shape[0] // bn,)
    seg_spec = pl.BlockSpec((bn,), lambda i: (i,))
    knot_spec = pl.BlockSpec((k,), lambda i: (0,))
    kernel = functools.partial(_segment_trapz_kernel, period=float(period))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seg_spec, seg_spec, seg_spec,
                  knot_spec, knot_spec, knot_spec],
        out_specs=seg_spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b, w, kt, kv, cum)
    return out[:n]
