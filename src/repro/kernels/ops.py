"""Public jit'd wrappers for the Pallas kernels.

One switch (``use_pallas``) selects the kernel or the pure-jnp reference;
the serving engine and benchmarks call through here so swapping in the
TPU kernels is a one-line config change.  On this CPU container kernels
run with interpret=True (Python-executed kernel bodies, same arithmetic);
on TPU set REPRO_PALLAS_INTERPRET=0.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pl
from repro.kernels.flash_attention import flash_attention as _flash_pl
from repro.kernels.rglru_scan import rglru_scan as _rglru_pl
from repro.kernels.segment_trapz import fused_meter as _fused_pl
from repro.kernels.segment_trapz import segment_trapz as _trapz_pl

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    use_pallas: bool = True) -> jnp.ndarray:
    if use_pallas:
        return _flash_pl(q, k, v, causal=causal, window=window,
                         interpret=INTERPRET)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def decode_attention(q, k, v, length, *, use_pallas: bool = True
                     ) -> jnp.ndarray:
    if use_pallas:
        return _decode_pl(q, k, v, length, interpret=INTERPRET)
    return ref.decode_attention_ref(q, k, v, length)


def rglru_scan(a, b, h0, *, use_pallas: bool = True) -> jnp.ndarray:
    if use_pallas:
        return _rglru_pl(a, b, h0, interpret=INTERPRET)
    return ref.rglru_scan_ref(a, b, h0)


def segment_trapz(a, b, w, kt, kv, cum, *, period: float,
                  use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Per-segment trapezoid integrals of a periodic piecewise-linear
    curve (the carbon-integration primitive; see segment_trapz.py).

    ``use_pallas=None`` (the default) picks the kernel on real hardware
    and the jnp reference when kernels would run interpreted: unlike
    the attention kernels above (called on a handful of activations per
    step), this one streams millions of metered segments per fleet day,
    where a Python-interpreted kernel body would dominate the very
    bulk-scan phase it exists to accelerate.
    """
    if use_pallas is None:
        use_pallas = not INTERPRET
    if use_pallas:
        return _trapz_pl(a, b, w, kt, kv, cum, period=period,
                         interpret=INTERPRET)
    return ref.segment_trapz_ref(a, b, w, kt, kv, cum, period=period)


def fused_meter(a, b, dt, w, g, kt, kv, cum, periods, *,
                use_pallas: Optional[bool] = None):
    """Fused metering pass: per charge-log entry energy / billed
    seconds / carbon increment / start-prefix in one launch (see
    segment_trapz.fused_meter).  Same ``use_pallas=None`` policy as
    ``segment_trapz``: this streams the whole metered charge log, so
    interpret-mode containers take the jnp reference."""
    if use_pallas is None:
        use_pallas = not INTERPRET
    if use_pallas:
        return _fused_pl(a, b, dt, w, g, kt, kv, cum, periods,
                         interpret=INTERPRET)
    return ref.fused_meter_ref(a, b, dt, w, g, kt, kv, cum, periods)
