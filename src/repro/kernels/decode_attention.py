"""GQA decode attention (one token vs. a long KV cache) as a Pallas kernel.

TPU adaptation of flash-decode: on GPUs the KV split is parallelized
across thread blocks with a separate combine kernel; TPU grid steps are
sequential per core, so the kernel keeps a running online softmax over KV
blocks in VMEM scratch -- same arithmetic, no combine pass.  The cache
frontier (`length`) masks out unwritten entries; scalar prefetch carries
it so block iteration can stop early at ceil(length / BK).

q: [B, H, D]; k,v: [B, Hkv, T, D]; length: [B] valid entries per row.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, bk: int,
                   seq_k: int, scale: float):
    bi = pl.program_id(0)
    q = q_ref[0, 0].astype(jnp.float32) * scale            # [G, D]
    gdim, d = q.shape
    length = len_ref[bi]

    m = jnp.full((gdim,), NEG_INF, jnp.float32)
    l = jnp.zeros((gdim,), jnp.float32)
    acc = jnp.zeros((gdim, d), jnp.float32)

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.ds(ki * bk, bk)].astype(jnp.float32)  # [BK, D]
        v = v_ref[0, 0, pl.ds(ki * bk, bk)].astype(jnp.float32)
        s = q @ k.T                                         # [G, BK]
        k_pos = ki * bk + jax.lax.iota(jnp.int32, bk)
        s = jnp.where((k_pos < length)[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    n_blocks = (length + bk - 1) // bk                      # early stop
    n_blocks = jnp.minimum(n_blocks, seq_k // bk)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     length: jnp.ndarray, *, bk: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    """Returns [B,H,D].  `length` broadcasts to [B] (valid cache rows)."""
    b, h, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    bk = min(bk, t)
    assert t % bk == 0
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    scale = 1.0 / math.sqrt(d)

    # group query heads by kv head: [B, Hkv, G, D]
    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv)
    kernel = functools.partial(_decode_kernel, bk=bk, seq_k=t, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),      # length: scalar-ish
            pl.BlockSpec((1, 1, g, d), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, t, d), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, t, d), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(length, qg, k, v)
    return out.reshape(b, h, d)
