"""RG-LRU diagonal linear recurrence as a Pallas TPU kernel.

    h_t = a_t * h_{t-1} + b_t        (elementwise over the LRU width)

The recurrence is serial in time but embarrassingly parallel across
(batch, width).  Grid: (B, W/BW); each program walks the sequence in
order with the running state h in fp32, streaming [CT, BW] time-chunks of
a and b through VMEM.  Width blocks are 128-aligned for the VPU.  The
associative-scan reference (log-depth, more flops) is what XLA runs; on
TPU the serial-in-time kernel trades depth for zero redundant work --
which wins when S/CT chunks pipeline against the HBM stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, *, ct: int, seq: int):
    h = h0_ref[0].astype(jnp.float32)                     # [BW]

    def chunk(ci, h):
        a = a_ref[0, pl.ds(ci * ct, ct)].astype(jnp.float32)   # [CT, BW]
        bx = b_ref[0, pl.ds(ci * ct, ct)].astype(jnp.float32)

        def step(ti, h):
            h_new = a[ti] * h + bx[ti]
            o_ref[0, ci * ct + ti] = h_new.astype(o_ref.dtype)
            return h_new

        return jax.lax.fori_loop(0, ct, step, h)

    jax.lax.fori_loop(0, seq // ct, chunk, h)


@functools.partial(jax.jit, static_argnames=("bw", "ct", "interpret"))
def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray, *,
               bw: int = 128, ct: int = 128,
               interpret: bool = True) -> jnp.ndarray:
    """a, b: [B,S,W]; h0: [B,W].  Returns h: [B,S,W] (fp32 accumulate)."""
    bsz, s, w = a.shape
    bw = min(bw, w)
    ct = min(ct, s)
    assert w % bw == 0 and s % ct == 0
    grid = (bsz, w // bw)
    kernel = functools.partial(_rglru_kernel, ct=ct, seq=s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, bw), lambda bi, wi: (bi, 0, wi)),
            pl.BlockSpec((1, s, bw), lambda bi, wi: (bi, 0, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((1, s, bw), lambda bi, wi: (bi, 0, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        interpret=interpret,
    )(a, b, h0)
