from repro.distributed.sharding import (LONG_SERVE_RULES, SERVE_RULES,
                                        TRAIN_RULES, partition_spec,
                                        shardings_for_specs,
                                        shardings_for_tree)

__all__ = ["TRAIN_RULES", "SERVE_RULES", "LONG_SERVE_RULES",
           "partition_spec", "shardings_for_specs", "shardings_for_tree"]
