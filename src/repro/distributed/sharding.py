"""Logical-axis -> mesh-axis sharding rules (DESIGN.md section 5).

A *rule set* maps each logical axis name (models/params.py specs) to an
ordered list of candidate mesh-axis tuples.  ``partition_spec`` picks, per
tensor dimension, the first candidate whose mesh axes (a) all exist in the
mesh, (b) evenly divide the dimension, and (c) are not already used by
another dimension of the same tensor.  Unsatisfiable dims replicate.

This shape-aware fallback is what lets one rule set serve all ten
architectures: whisper's 8 heads replicate on a 16-way model axis while
granite's 48 heads shard; gemma's kv_heads=1 replicates everywhere; the
batch=1 long-context cells fall through to sequence sharding.

Rule sets:
  * TRAIN_RULES: FSDP on the "embed" axis over data (ZeRO-style weight
    gathering by GSPMD) + tensor/expert parallel over "model"; batch over
    (pod, data).
  * SERVE_RULES: weights replicated over data (no optimizer state, decode
    all-gathers would dominate), TP/EP over "model"; KV-cache length over
    "model" (kv_heads are rarely divisible: 1-8 on most archs).
  * LONG_SERVE_RULES: batch=1 long-context decode -- cache length sharded
    over (data, model) (sequence parallelism over the cache).
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models.params import ParamSpec, is_spec

Tree = Any
Candidate = Tuple[str, ...]
RuleSet = Dict[str, List[Candidate]]

TRAIN_RULES: RuleSet = {
    "batch": [("pod", "data"), ("data",)],
    "embed": [("data",)],                 # FSDP / ZeRO weight sharding
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "ffn": [("model",)],
    "experts": [("model",)],
    "vocab": [("model",)],
    "lora": [],
    "layers": [],
    "hdim": [], "hdim2": [], "ffn2": [], "conv": [],
    "kv_len": [],
    # Megatron-style sequence parallelism for residual activations: the
    # block-boundary hint ("batch","seq",None) shards the carry over
    # "model", so lax.scan's saved-for-backward stack is 1/16th the size
    # (the 236B archs do not fit otherwise); GSPMD inserts the
    # all-gather / reduce-scatter pair around attention/FFN.
    "seq": [("model",)],
}

SERVE_RULES: RuleSet = {
    "batch": [("pod", "data"), ("data",)],
    "embed": [],                          # replicate over data for decode
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "ffn": [("model",)],
    "experts": [("model",)],
    "vocab": [("model",)],
    "lora": [],
    "layers": [],
    # hdim shards W_k/W_v over "model" when kv_heads (1-8 on most archs)
    # cannot -- caches are unaffected (their kv_len takes "model" first)
    "hdim": [("model",)], "hdim2": [], "ffn2": [], "conv": [],
    "kv_len": [("model",)],               # cache length over model axis
    "seq": [],
}

LONG_SERVE_RULES: RuleSet = dict(
    SERVE_RULES,
    kv_len=[("pod", "data", "model"), ("data", "model"), ("model",)],
)

# >= ~100B-param archs cannot replicate weights across the data axis at
# serve time (deepseek-v2 params/16 = 29.5 GB > 16 GB HBM): shard the
# "embed" dim over data too (weights all-gathered per layer by GSPMD --
# the memory-for-collectives trade the roofline table quantifies).
SERVE_BIG_RULES: RuleSet = dict(SERVE_RULES, embed=[("data",)])
LONG_SERVE_BIG_RULES: RuleSet = dict(LONG_SERVE_RULES, embed=[("data",)])


def partition_spec(axes: Sequence[Optional[str]], shape: Sequence[int],
                   rules: RuleSet, mesh: Mesh) -> PartitionSpec:
    taken: set = set()
    parts: List[Optional[Any]] = []
    for dim, ax in zip(shape, axes):
        chosen = None
        for cand in (rules.get(ax) or []) if ax else []:
            if not all(a in mesh.axis_names for a in cand):
                continue
            size = math.prod(mesh.shape[a] for a in cand)
            if size <= 1 or dim % size != 0:
                continue
            if any(a in taken for a in cand):
                continue
            chosen = cand
            taken.update(cand)
            break
        if chosen is None:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(chosen)
    return PartitionSpec(*parts)


def shardings_for_specs(spec_tree: Tree, rules: RuleSet, mesh: Mesh) -> Tree:
    """NamedSharding tree from a ParamSpec tree (params, caches)."""
    def one(s: ParamSpec) -> NamedSharding:
        return NamedSharding(mesh, partition_spec(s.axes, s.shape, rules,
                                                  mesh))
    return jax.tree_util.tree_map(one, spec_tree, is_leaf=is_spec)


def shardings_for_tree(axes_tree: Tree, abstract_tree: Tree, rules: RuleSet,
                       mesh: Mesh) -> Tree:
    """NamedSharding tree for ad-hoc pytrees: ``axes_tree`` mirrors
    ``abstract_tree`` with tuples of logical axis names as leaves."""
    def one(axes, arr):
        return NamedSharding(mesh, partition_spec(axes, arr.shape, rules,
                                                  mesh))
    return jax.tree_util.tree_map(one, axes_tree, abstract_tree,
                                  is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Activation sharding hints.
#
# FSDP-style weight sharding ("embed" -> data) and batch sharding share the
# "data" mesh axis.  Inside an einsum that contracts a weight's FSDP dim
# against a batch-sharded activation, GSPMD must gather one side -- and left
# to itself it sometimes gathers the *activation* (observed: gemma3 train
# scores materialized with a global 256 batch, 64 GiB/buffer).  Anchoring
# activations with with_sharding_constraint at block boundaries forces the
# standard ZeRO resolution: weights are all-gathered, activations stay
# sharded.  The hints are no-ops outside a jit traced under
# ``activation_sharding`` (unit tests, reduced smokes).
# ---------------------------------------------------------------------------

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: RuleSet):
    tok = _ACT_CTX.set((mesh, rules))
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def shard_hint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Constrain `x`'s sharding per the active rule set (no-op if none)."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    ps = partition_spec(axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))
