"""Fault-tolerant checkpointing (DESIGN.md section 5).

Layout: <dir>/step_<N>/shard_<i>.npz + manifest.json, committed by atomic
rename of a ".tmp" directory -- a partially-written checkpoint is never
visible, so a crash mid-save costs nothing (restart resumes from the
previous commit).  ``CheckpointManager`` adds:

  * async saves on a worker thread (training never blocks on disk),
  * retention (keep the newest K),
  * deterministic resume: step counter, RNG key and the data-pipeline
    cursor ride inside the pytree.

On a multi-host deployment each host writes the shards of its addressable
devices; here (single host) everything lands in shard_0.
"""
from __future__ import annotations

import json
import pathlib
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

Tree = Any


def _flatten_with_paths(tree: Tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), np.asarray(leaf))
            for path, leaf in flat]


def save_pytree(tree: Tree, directory: str | pathlib.Path, step: int) -> \
        pathlib.Path:
    """Synchronous atomic save of one pytree as step_<N>."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": arr for i, (_, arr) in enumerate(leaves)}
    np.savez(tmp / "shard_0.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "paths": [p for p, _ in leaves],
        "dtypes": [str(a.dtype) for _, a in leaves],
        "shapes": [list(a.shape) for _, a in leaves],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic commit
    return final


def restore_pytree(template: Tree, directory: str | pathlib.Path,
                   step: Optional[int] = None) -> Tree:
    """Restore into the structure of `template` (shape/dtype-checked)."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = directory / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "shard_0.npz") as data:
        arrays = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    flat, treedef = jax.tree_util.tree_flatten(template)
    if len(flat) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, template {len(flat)}")
    out = []
    for tmpl, arr in zip(flat, arrays):
        if tuple(tmpl.shape) != tuple(arr.shape):
            raise ValueError(f"shape mismatch {tmpl.shape} vs {arr.shape}")
        out.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str | pathlib.Path) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if p.is_dir() and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


class CheckpointManager:
    """Async checkpointing with retention."""

    def __init__(self, directory: str | pathlib.Path, *, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: List[Exception] = []

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, step = item
            try:
                save_pytree(tree, self.directory, step)
                self._gc()
            except Exception as e:            # noqa: BLE001
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*")
                       if p.is_dir() and not p.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)

    def save_async(self, tree: Tree, step: int) -> None:
        # device_get now so the step can donate/mutate its buffers
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        self._q.put((host_tree, step))

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._worker.join(timeout=10)
