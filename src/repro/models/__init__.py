"""Model substrate: configs, layers, attention variants, MoE, recurrence,
and the composable stack (train / prefill / decode)."""
from repro.models.config import (ArchConfig, BlockSpec, FFN, Mixer,
                                 MLAConfig, MoEConfig, RecurrentConfig,
                                 ScanGroup, dense_lm)
from repro.models.model import (RunFlags, build_cache_specs,
                                build_param_specs, decode_step, prefill,
                                train_loss)
from repro.models.params import (ParamSpec, abstract, materialize,
                                 param_bytes, param_count, spec)

__all__ = [
    "ArchConfig", "BlockSpec", "FFN", "Mixer", "MLAConfig", "MoEConfig",
    "RecurrentConfig", "ScanGroup", "dense_lm", "RunFlags",
    "build_cache_specs", "build_param_specs", "decode_step", "prefill",
    "train_loss", "ParamSpec", "abstract", "materialize", "param_bytes",
    "param_count", "spec",
]
