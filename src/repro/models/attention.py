"""Attention mixers: GQA/MQA/MHA (full + sliding-window), MLA, cross-attn.

Design notes (DESIGN.md section 5):
  * The sliding window is a *traced scalar* riding through lax.scan metadata,
    so local and global layers (gemma3's 5:1) share one scanned block: a
    global layer simply carries window = max_position.
  * KV caches are full-length ring-free buffers written with
    dynamic_update_slice; window locality is enforced by the mask.  (A
    ring-buffer window cache is a memory optimization explored in
    EXPERIMENTS.md section Perf.)
  * MLA keeps the paper-faithful two-path structure: naive (materialized
    per-head K/V) for train/prefill, absorbed (score and output computed in
    the compressed kv_lora space) for decode, where materializing per-head
    K/V for a 32k cache would be prohibitive.
  * The pure-jnp paths here are the dry-run/reference implementations; the
    Pallas kernels in repro/kernels implement the same contracts for TPU
    (swap via ops.use_pallas, validated against these in tests).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models.config import ArchConfig, MLAConfig
from repro.models.params import spec

WINDOW_SLICE_OFF = 2 ** 29     # windows this large never slice (full attn)

Tree = Any


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """Rotary embedding. x: [..., S, H, K] (K even), positions: [..., S],
    theta may be a python float or a traced scalar (per-layer metadata)."""
    k = x.shape[-1]
    half = k // 2
    freq_exp = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = jnp.asarray(theta, jnp.float32) ** (-freq_exp)
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # [...,S,half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]          # broadcast over heads
    cos = cos[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ArchConfig, *, cross: bool = False) -> Tree:
    d, hq, hkv, k = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = cfg.param_dtype
    p = {
        "wq": spec([d, hq, k], ["embed", "heads", "hdim"], dt),
        "wk": spec([d, hkv, k], ["embed", "kv_heads", "hdim"], dt),
        "wv": spec([d, hkv, k], ["embed", "kv_heads", "hdim"], dt),
        "wo": spec([hq, k, d], ["heads", "hdim", "embed"], dt),
    }
    return p


def _mask(pos_q: jnp.ndarray, pos_k: jnp.ndarray, window,
          causal: bool) -> jnp.ndarray:
    """[..., S_q, S_k] boolean validity mask from absolute positions."""
    dq = pos_q[..., :, None]
    dk = pos_k[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    if causal:
        m = dk <= dq
    if window is not None:
        m = m & (dq - dk < window)
    return m


def _sdpa(q, k, v, mask, *, softcap: Optional[float] = None) -> jnp.ndarray:
    """q:[B,S,Hkv,G,K] k:[B,T,Hkv,K] v:[B,T,Hkv,K] mask:[B or 1,S,T]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bshgk,bthk->bhgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = shard_hint(scores, ("batch", "kv_heads", None, "seq", "kv_len"))
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthk->bshgk", w.astype(v.dtype), v)
    return shard_hint(out, ("batch", "seq", "kv_heads", None, None))


def _sdpa_chunked(q, k, v, pos_q, pos_k, window, causal, *,
                  softcap: Optional[float] = None,
                  valid_upto=None, chunk: int = 1024) -> jnp.ndarray:
    """Memory-efficient SDPA: sequential scan over query chunks so the fp32
    score working set is [B, chunk, T] instead of [B, S, T] (Rabe-Staats;
    the Pallas flash kernel is the TPU-native equivalent).  Falls back to
    one-shot _sdpa when S <= chunk.

    When ``window`` is a STATIC int and positions are contiguous (the
    train/prefill path), each chunk slices K/V to its causal window span
    -- span = window-1 past keys + chunk in-chunk keys -- so sliding-
    window layers pay O(S * window) score FLOPs instead of O(S^2).  This
    is the chunked-JAX analogue of the flash kernel's block skipping.
    """
    b, s = q.shape[0], q.shape[1]
    t = k.shape[1]
    if s <= chunk or s % chunk != 0:
        mask = _mask(pos_q, pos_k, window, causal)
        if mask.ndim == 2:
            mask = mask[None]
        if valid_upto is not None:
            mask = mask & (pos_k <= valid_upto)[:, None, :]
        return _sdpa(q, k, v, mask, softcap=softcap)
    nq = s // chunk
    qs = jnp.moveaxis(q.reshape((b, nq, chunk) + q.shape[2:]), 1, 0)
    pq = jnp.moveaxis(
        jnp.broadcast_to(pos_q, (b, s)).reshape(b, nq, chunk), 1, 0)

    static_window = isinstance(window, int) and window < WINDOW_SLICE_OFF
    span = min(((window - 1 + chunk + chunk - 1) // chunk) * chunk, t) \
        if static_window else t
    use_slice = static_window and causal and span < t

    def one(args):
        qc, pqc = args
        if use_slice:
            # positions are uniform across batch on this path (prefill/
            # train count 0..S-1); slice the K/V span this chunk can see
            start = jnp.clip(pqc[0, 0] - (span - chunk), 0, t - span)
            kc = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            pkc = start + jnp.arange(span)[None, :]
        else:
            kc, vc, pkc = k, v, pos_k
        mask = _mask(pqc, pkc, window, causal)
        if mask.ndim == 2:
            mask = mask[None]
        if valid_upto is not None:
            mask = mask & (pkc <= valid_upto)[:, None, :]
        return _sdpa(qc, kc, vc, mask, softcap=softcap)

    out = jax.lax.map(one, (qs, pq))
    return jnp.moveaxis(out, 0, 1).reshape((b, s) + out.shape[3:])


def gqa_attention(
    p: Tree,
    x: jnp.ndarray,                       # [B,S,D]
    positions: jnp.ndarray,               # [B,S] absolute positions
    *,
    cfg: ArchConfig,
    window=None,                          # None | int | traced scalar
    rope_theta=10_000.0,
    causal: bool = True,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_offset: Optional[jnp.ndarray] = None,   # scalar write index
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross
    chunk_q: int = 1024,                          # memory-efficient chunking
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full/windowed GQA.  With a cache: writes K/V at cache_offset and
    attends over the whole buffer (mask handles validity via positions)."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    g = hq // hkv

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    valid_upto = None
    if kv_override is not None:
        k, v = kv_override
        new_cache = cache
        pos_k = jnp.arange(k.shape[1])[None, :]
        causal = False
        window = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
        if cache is not None:
            t = cache["k"].shape[1]
            off = cache_offset if cache_offset is not None else 0
            new_cache = dict(cache)
            new_cache.update(_kv_write(cache, "k", k, (0, off, 0, 0)))
            new_cache.update(_kv_write(cache, "v", v, (0, off, 0, 0)))
            if s == t:
                # prefill covering the whole cache: attend with the fresh
                # batch-local K/V and write the (possibly differently-
                # sharded) cache as a side effect.  Reading attention
                # inputs back through the model-sharded cache would
                # all-gather ~cache-size bytes per query chunk per layer.
                pos_k = positions
            else:
                k = _kv_read(new_cache, "k", q.dtype)
                v = _kv_read(new_cache, "v", q.dtype)
                pos_k = jnp.arange(t)[None, :]
                # entries at/after the write frontier are invalid
                valid_upto = jnp.asarray(off + s - 1, jnp.int32)
        else:
            new_cache = None
            pos_k = positions

    q = q.reshape(b, s, hkv, g, hd)
    out = _sdpa_chunked(q, k.astype(q.dtype), v.astype(q.dtype),
                        positions, pos_k, window, causal,
                        softcap=cfg.attn_logit_softcap,
                        valid_upto=valid_upto, chunk=chunk_q)
    out = out.reshape(b, s, hq, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def _kv_write(cache: dict, name: str, val: jnp.ndarray, idx
              ) -> dict:
    """Write K or V into the cache, quantizing per (token, head) when the
    buffer is int8 (scales stored alongside as `<name>_scale`)."""
    buf = cache[name]
    out = {}
    if buf.dtype == jnp.int8:
        vf = val.astype(jnp.float32)
        amax = jnp.max(jnp.abs(vf), axis=-1, keepdims=True)   # [B,S,H,1]
        scale = jnp.maximum(amax, 1e-6) / 127.0
        q = jnp.clip(jnp.round(vf / scale), -127, 127).astype(jnp.int8)
        out[name] = jax.lax.dynamic_update_slice(buf, q, idx)
        out[f"{name}_scale"] = jax.lax.dynamic_update_slice(
            cache[f"{name}_scale"],
            scale[..., 0].astype(cache[f"{name}_scale"].dtype), idx[:-1])
    else:
        out[name] = jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype),
                                                 idx)
    return out


def _kv_read(cache: dict, name: str, dtype) -> jnp.ndarray:
    buf = cache[name]
    if buf.dtype == jnp.int8:
        scale = cache[f"{name}_scale"].astype(jnp.float32)[..., None]
        return (buf.astype(jnp.float32) * scale).astype(dtype)
    return buf.astype(dtype)


def gqa_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Tree:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    c = {
        "k": spec([batch, max_len, hkv, hd],
                  ["batch", "kv_len", "kv_heads", "hdim"], dtype, "zeros"),
        "v": spec([batch, max_len, hkv, hd],
                  ["batch", "kv_len", "kv_heads", "hdim"], dtype, "zeros"),
    }
    if dtype == jnp.int8:
        # per-(token, head) symmetric quantization scales (1/head_dim the
        # footprint of the int8 payload)
        for nm in ("k", "v"):
            c[f"{nm}_scale"] = spec(
                [batch, max_len, hkv],
                ["batch", "kv_len", "kv_heads"], jnp.bfloat16, "ones")
    return c


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention) -- DeepSeek-V2 / MiniCPM3
# ---------------------------------------------------------------------------

def mla_specs(cfg: ArchConfig) -> Tree:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = cfg.param_dtype
    qk = m.qk_nope_head_dim
    qr = m.qk_rope_head_dim
    return {
        "wq_a": spec([d, m.q_lora_rank], ["embed", "lora"], dt),
        "q_norm": spec([m.q_lora_rank], ["lora"], jnp.float32, "ones"),
        "wq_b": spec([m.q_lora_rank, h, qk + qr], ["lora", "heads", "hdim"], dt),
        "wkv_a": spec([d, m.kv_lora_rank + qr], ["embed", "lora"], dt),
        "kv_norm": spec([m.kv_lora_rank], ["lora"], jnp.float32, "ones"),
        "wk_b": spec([m.kv_lora_rank, h, qk], ["lora", "heads", "hdim"], dt),
        "wv_b": spec([m.kv_lora_rank, h, m.v_head_dim],
                     ["lora", "heads", "hdim"], dt),
        "wo": spec([h, m.v_head_dim, d], ["heads", "hdim", "embed"], dt),
    }


def _rms(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * scale).astype(x.dtype)


def mla_project(p: Tree, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ArchConfig, rope_theta) -> Tuple[jnp.ndarray, ...]:
    """Shared projections: q_nope [B,S,H,qk], q_rope [B,S,H,qr],
    c_kv [B,S,kvr], k_rope [B,S,qr]."""
    m = cfg.mla
    qk, qr = m.qk_nope_head_dim, m.qk_rope_head_dim
    q_lat = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q_lat = _rms(q_lat, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    q_rope = rope(q_rope, positions, rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_rope = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = _rms(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention_naive(
    p: Tree, x: jnp.ndarray, positions: jnp.ndarray, *, cfg: ArchConfig,
    rope_theta=10_000.0, chunk_q: int = 1024,
) -> jnp.ndarray:
    """Train/prefill path: materialize per-head K/V from the latent cache
    (standard DeepSeek practice), query-chunked for a bounded fp32 score
    working set."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = mla_project(p, x, positions, cfg,
                                               rope_theta)
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["wk_b"])
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["wv_b"])
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    pos_k = positions

    def attend(qn, qr, pq):
        scores = (jnp.einsum("bshk,bthk->bhst", qn, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshk,btk->bhst", qr, k_rope,
                               preferred_element_type=jnp.float32)) * scale
        scores = shard_hint(scores, ("batch", "heads", "seq", "kv_len"))
        mask = _mask(pq, pos_k, None, True)
        if mask.ndim == 2:
            mask = mask[None]
        scores = jnp.where(mask[:, None], scores,
                           jnp.finfo(scores.dtype).min)
        w = jax.nn.softmax(scores, -1).astype(v.dtype)
        return jnp.einsum("bhst,bthk->bshk", w, v)

    if s <= chunk_q or s % chunk_q != 0:
        out = attend(q_nope, q_rope, positions)
    else:
        nq = s // chunk_q
        qn = jnp.moveaxis(
            q_nope.reshape((b, nq, chunk_q) + q_nope.shape[2:]), 1, 0)
        qr = jnp.moveaxis(
            q_rope.reshape((b, nq, chunk_q) + q_rope.shape[2:]), 1, 0)
        pq = jnp.moveaxis(
            jnp.broadcast_to(positions, (b, s)).reshape(b, nq, chunk_q),
            1, 0)
        out = jax.lax.map(lambda a: attend(*a), (qn, qr, pq))
        out = jnp.moveaxis(out, 0, 1).reshape((b, s) + out.shape[3:])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_attention_absorbed(
    p: Tree, x: jnp.ndarray, positions: jnp.ndarray, *, cfg: ArchConfig,
    cache: Dict[str, jnp.ndarray], cache_offset, rope_theta=10_000.0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Decode path: scores/outputs computed against the compressed cache.

    q_c = q_nope @ wk_b  (absorb): [B,S,H,kvr]; scores = q_c . c_kv +
    q_rope . k_rope; out = (attn @ c_kv) @ wv_b.  The per-head K/V never
    materialize -- the whole point of MLA's compressed KV cache.
    """
    m = cfg.mla
    q_nope, q_rope, c_kv_new, k_rope_new = mla_project(
        p, x, positions, cfg, rope_theta)
    t = cache["c_kv"].shape[1]
    off = cache_offset
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, off, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, off, 0))
    new_cache = {"c_kv": c_kv, "k_rope": k_rope}

    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])   # absorbed query
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bshr,btr->bhst", q_c, c_kv.astype(q_c.dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bhst", q_rope,
                           k_rope.astype(q_rope.dtype),
                           preferred_element_type=jnp.float32)) * scale
    scores = shard_hint(scores, ("batch", "heads", "seq", "kv_len"))
    pos_k = jnp.arange(t)[None, :]
    s = x.shape[1]
    mask = _mask(positions, pos_k, None, True) & \
        (pos_k <= (off + s - 1))[:, None, :]
    scores = jnp.where(mask[:, None], scores, jnp.finfo(scores.dtype).min)
    w = jax.nn.softmax(scores, -1)
    ctx = jnp.einsum("bhst,btr->bshr", w.astype(c_kv.dtype), c_kv)
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["wv_b"])      # absorbed value
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, new_cache


def mla_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Tree:
    m = cfg.mla
    return {
        "c_kv": spec([batch, max_len, m.kv_lora_rank],
                     ["batch", "kv_len", "lora"], dtype, "zeros"),
        "k_rope": spec([batch, max_len, m.qk_rope_head_dim],
                       ["batch", "kv_len", "hdim"], dtype, "zeros"),
    }
