"""Parameter-spec trees: one model definition, three materializations.

A model is defined once as a pytree of ``ParamSpec`` leaves (shape, dtype,
*logical axes*, init law).  From that single tree we derive:

  * ``abstract(tree)``   -> jax.ShapeDtypeStruct tree   (dry-run lowering,
                            no host/device allocation)
  * ``shardings(tree, rules, mesh)`` -> NamedSharding tree (pjit in/out specs)
  * ``materialize(tree, key)`` -> concrete jnp arrays    (smoke tests, the
                            100M training example)

Logical axes name *semantic* dimensions ("embed", "heads", "ffn", "experts",
"vocab", "layers", "kv_len", ...); ``distributed/sharding.py`` maps them to
mesh axes per rule-set (train vs serve).  This is the MaxText-style logical/
physical split, kept dependency-free.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    axes: Tuple[Optional[str], ...] = ()
    init: str = "normal"          # normal | zeros | ones | embed | scaled
    init_scale: Optional[float] = None

    def __post_init__(self):
        if len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} must match shape {self.shape} rank")


def spec(shape: Sequence[int], axes: Sequence[Optional[str]],
         dtype=jnp.bfloat16, init: str = "normal",
         init_scale: Optional[float] = None) -> ParamSpec:
    return ParamSpec(tuple(int(s) for s in shape), dtype, tuple(axes),
                     init, init_scale)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree: Tree) -> Tree:
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def abstract(tree: Tree) -> Tree:
    """ShapeDtypeStruct stand-ins -- zero allocation (dry-run inputs)."""
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                          tree)


def param_bytes(tree: Tree) -> int:
    total = 0
    for s in jax.tree_util.tree_leaves(tree, is_leaf=is_spec):
        total += math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
    return total


def param_count(tree: Tree) -> int:
    return sum(math.prod(s.shape)
               for s in jax.tree_util.tree_leaves(tree, is_leaf=is_spec))


def _init_leaf(s: ParamSpec, key: jax.Array) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    # fan-in scaled normal by default; "embed" uses unit normal
    if s.init == "embed":
        scale = 1.0
    else:
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        scale = s.init_scale if s.init_scale is not None else 1.0 / math.sqrt(
            max(fan_in, 1))
    x = jax.random.normal(key, s.shape, jnp.float32) * scale
    return x.astype(s.dtype)


def materialize(tree: Tree, key: jax.Array) -> Tree:
    """Concrete random init.  Keys are derived from the leaf path so that
    adding/removing an unrelated parameter does not reshuffle others.
    The path hash is crc32, NOT Python hash() -- the builtin is salted
    per process (PYTHONHASHSEED), which would make multi-host / restarted
    inits diverge silently."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    paths = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)[0]
    out = []
    for (path, s) in paths:
        path_str = jax.tree_util.keystr(path)
        stable = zlib.crc32(path_str.encode()) & 0x7FFFFFFF
        k = jax.random.fold_in(key, stable)
        out.append(_init_leaf(s, k))
    return jax.tree_util.tree_unflatten(treedef, out)
