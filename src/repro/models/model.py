"""The composable LM stack: param-spec construction + train/prefill/decode.

Layer stacks run as lax.scan over *scan groups* (config.py): parameters are
stacked with a leading "layers" axis, per-layer metadata (window, rope
theta) rides as scanned arrays, and caches are scanned xs/ys.  This keeps
the HLO depth-independent -- essential for 512-device SPMD compiles on the
dry-run host (DESIGN.md section 5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard_hint
from repro.models import blocks as blk_lib
from repro.models.blocks import WINDOW_INF, apply_block, block_cache_specs, \
    block_param_specs
from repro.models.config import ArchConfig, BlockSpec, FFN, Mixer, ScanGroup
from repro.models.layers import embed, embed_specs, rmsnorm, rmsnorm_spec, \
    softmax_xent, unembed
from repro.models.params import ParamSpec, is_spec, spec, tree_map_specs

Tree = Any


@dataclasses.dataclass(frozen=True)
class RunFlags:
    """Per-step execution knobs (hillclimbed in EXPERIMENTS.md Perf)."""
    remat: str = "full"            # none | full | dots
    moe_impl: Optional[str] = None  # override cfg.moe.impl
    scan_unroll: int = 1
    attn_chunk: int = 1024         # query-chunked attention working set
    grad_accum: int = 1            # microbatch gradient accumulation
    moe_group: int = 0             # MoE dispatch group size (0 = one group)
    cache_dtype: str = "bf16"      # decode KV cache dtype: bf16 | int8


# ---------------------------------------------------------------------------
# parameter / cache / metadata construction
# ---------------------------------------------------------------------------

def _stack_specs(tree: Tree, repeats: int) -> Tree:
    return tree_map_specs(
        lambda s: ParamSpec((repeats,) + s.shape, s.dtype,
                            ("layers",) + s.axes, s.init, s.init_scale),
        tree)


def _encoder_cfg(cfg: ArchConfig) -> ArchConfig:
    """The encoder tower reuses the arch dims with full bidirectional attn."""
    enc_blk = BlockSpec(Mixer.ATTN, FFN.DENSE, rope_theta=cfg.rope_theta)
    return dataclasses.replace(
        cfg, groups=(ScanGroup("enc", cfg.encoder.n_layers, (enc_blk,)),),
        encoder=None)


def build_param_specs(cfg: ArchConfig) -> Tree:
    cfg.validate()
    p: Dict[str, Tree] = {"embed": embed_specs(cfg),
                          "final_norm": rmsnorm_spec(cfg.d_model)}
    p["groups"] = {}
    for g in cfg.groups:
        gp = {}
        for j, blk in enumerate(g.pattern):
            gp[f"pos{j}"] = _stack_specs(block_param_specs(cfg, blk),
                                         g.repeats)
        p["groups"][g.name] = gp
    if cfg.encoder is not None:
        ecfg = _encoder_cfg(cfg)
        enc = {"final_norm": rmsnorm_spec(cfg.d_model), "groups": {}}
        for g in ecfg.groups:
            gp = {}
            for j, blk in enumerate(g.pattern):
                gp[f"pos{j}"] = _stack_specs(block_param_specs(ecfg, blk),
                                             g.repeats)
            enc["groups"][g.name] = gp
        p["encoder"] = enc
    return p


def build_cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> Tree:
    src = cfg.encoder.source_len if cfg.encoder is not None else 0
    caches: Dict[str, Tree] = {}
    for g in cfg.groups:
        gc = {}
        for j, blk in enumerate(g.pattern):
            gc[f"pos{j}"] = _stack_specs(
                block_cache_specs(cfg, blk, batch, max_len,
                                  source_len=src, dtype=dtype), g.repeats)
        caches[g.name] = gc
    return caches


def build_meta(cfg: ArchConfig) -> Dict[str, Dict[str, Dict[str, jnp.ndarray]]]:
    """Per-group, per-pattern-position scanned metadata arrays [repeats]."""
    flat_windows = list(cfg.layer_windows) if cfg.layer_windows else None
    flat_thetas = list(cfg.layer_thetas) if cfg.layer_thetas else None
    metas: Dict[str, Dict[str, Dict[str, jnp.ndarray]]] = {}
    li = 0
    for g in cfg.groups:
        per_pos: Dict[str, Dict[str, List]] = {
            f"pos{j}": {"window": [], "theta": []}
            for j in range(len(g.pattern))}
        for r in range(g.repeats):
            for j, blk in enumerate(g.pattern):
                w = blk.window
                th = blk.rope_theta
                if flat_windows is not None:
                    w = flat_windows[li]
                if flat_thetas is not None:
                    th = flat_thetas[li]
                per_pos[f"pos{j}"]["window"].append(
                    WINDOW_INF if w is None else int(w))
                per_pos[f"pos{j}"]["theta"].append(float(th))
                li += 1
        metas[g.name] = {
            k: {"window": jnp.asarray(v["window"], jnp.int32),
                "theta": jnp.asarray(v["theta"], jnp.float32)}
            for k, v in per_pos.items()}
    return metas


# ---------------------------------------------------------------------------
# scan-group execution
# ---------------------------------------------------------------------------

def _run_groups(
    params: Tree,
    groups: Tuple[ScanGroup, ...],
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    metas: Tree,
    *,
    mode: str,
    caches: Optional[Tree] = None,
    cache_offset=None,
    enc_out: Optional[jnp.ndarray] = None,
    causal: bool = True,
    flags: RunFlags = RunFlags(),
) -> Tuple[jnp.ndarray, Optional[Tree], jnp.ndarray]:
    new_caches: Optional[Dict[str, Tree]] = {} if caches is not None else None
    aux_total = jnp.zeros((), jnp.float32)

    for g in groups:
        gp = params["groups"][g.name]
        gm = metas[g.name]
        gc = caches[g.name] if caches is not None else None

        def body(carry, per_layer):
            h, aux = carry
            p_i, m_i, c_i = per_layer
            nc_i = {}
            for j, blk in enumerate(g.pattern):
                key = f"pos{j}"
                h, nc, a = apply_block(
                    p_i[key], blk, cfg, h, positions, m_i[key],
                    mode=mode,
                    cache=c_i[key] if c_i is not None else None,
                    cache_offset=cache_offset, enc_out=enc_out,
                    causal=causal, moe_impl=flags.moe_impl,
                    moe_group=flags.moe_group or None,
                    attn_chunk=flags.attn_chunk)
                h = shard_hint(h, ("batch", "seq", None))
                nc_i[key] = nc if nc is not None else {}
                aux = aux + a
            return (h, aux), nc_i

        if mode == "train" and flags.remat != "none":
            policy = None
            if flags.remat == "dots":
                policy = jax.checkpoint_policies.checkpoint_dots
            body = jax.checkpoint(body, policy=policy)

        # unroll only when it divides the trip count (length-1 tail groups
        # stay rolled; the dry-run's two-point cost scaling relies on this)
        u = flags.scan_unroll if (g.repeats > 1 and
                                  g.repeats % flags.scan_unroll == 0) else 1
        if gc is None:
            def body_nc(carry, per_layer):
                p_i, m_i = per_layer
                return body(carry, (p_i, m_i, None))
            (x, aux_total), _ = jax.lax.scan(
                body_nc, (x, aux_total), (gp, gm), unroll=u)
        else:
            (x, aux_total), nc = jax.lax.scan(
                body, (x, aux_total), (gp, gm, gc), unroll=u)
            new_caches[g.name] = nc
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# model-level entry points
# ---------------------------------------------------------------------------

def _encode(params: Tree, cfg: ArchConfig, source_embeds: jnp.ndarray,
            flags: RunFlags) -> jnp.ndarray:
    """Run the bidirectional encoder tower (whisper-style)."""
    ecfg = _encoder_cfg(cfg)
    b, t, _ = source_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    metas = build_meta(ecfg)
    x, _, _ = _run_groups(params["encoder"], ecfg.groups, ecfg,
                          source_embeds.astype(cfg.compute_dtype), positions,
                          metas, mode="train", causal=False, flags=flags)
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _prepare_inputs(params: Tree, cfg: ArchConfig, batch: Dict[str, Any]
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Embed tokens, prepend VLM prefix embeddings if any.
    Returns (x, positions, n_prefix)."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, cfg).astype(cfg.compute_dtype)
    n_prefix = 0
    if cfg.n_prefix_embeddings > 0:
        pre = batch["prefix_embeds"].astype(cfg.compute_dtype)
        n_prefix = pre.shape[1]
        x = jnp.concatenate([pre, x], axis=1)
    x = shard_hint(x, ("batch", "seq", None))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return x, positions, n_prefix


def train_loss(params: Tree, batch: Dict[str, Any], cfg: ArchConfig,
               flags: RunFlags = RunFlags()) -> jnp.ndarray:
    """Mean next-token loss (+ MoE aux).  batch: tokens, labels,
    [source_embeds], [prefix_embeds], [loss_mask]."""
    x, positions, n_prefix = _prepare_inputs(params, cfg, batch)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encode(params, cfg, batch["source_embeds"], flags)
    x, _, aux = _run_groups(params, cfg.groups, cfg, x, positions,
                            build_meta(cfg), mode="train", enc_out=enc_out,
                            flags=flags)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if n_prefix > 0:
        x = x[:, n_prefix:, :]
    logits = unembed(params["embed"], x, cfg)
    logits = shard_hint(logits, ("batch", "seq", "vocab"))
    mask = batch.get("loss_mask")
    return softmax_xent(logits, batch["labels"], mask) + aux


def prefill(params: Tree, batch: Dict[str, Any], caches: Tree,
            cfg: ArchConfig, flags: RunFlags = RunFlags()
            ) -> Tuple[jnp.ndarray, Tree]:
    """Process the full prompt, returning (last-token logits [B,V],
    populated caches)."""
    x, positions, n_prefix = _prepare_inputs(params, cfg, batch)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encode(params, cfg, batch["source_embeds"], flags)
    x, new_caches, _ = _run_groups(
        params, cfg.groups, cfg, x, positions, build_meta(cfg),
        mode="prefill", caches=caches, cache_offset=0, enc_out=enc_out,
        flags=flags)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)[:, 0, :]
    return logits, new_caches


def decode_step(params: Tree, tokens: jnp.ndarray, caches: Tree,
                pos: jnp.ndarray, cfg: ArchConfig,
                flags: RunFlags = RunFlags()
                ) -> Tuple[jnp.ndarray, Tree]:
    """One decode step.  tokens [B,1]; pos: scalar int32 write offset.
    Returns (logits [B,V], updated caches)."""
    x = embed(params["embed"], tokens, cfg).astype(cfg.compute_dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(pos + jnp.arange(s)[None], (b, s))
    x, new_caches, _ = _run_groups(
        params, cfg.groups, cfg, x, positions, build_meta(cfg),
        mode="decode", caches=caches, cache_offset=pos, flags=flags)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)[:, -1, :]
    return logits, new_caches
