"""Single-layer block assembly: pre-norm mixer + pre-norm FFN residual.

One ``BlockSpec`` (config.py) describes a layer; ``block_param_specs``
builds its ParamSpec tree and ``apply_block`` runs it in one of three modes:

  * mode="train"    full sequence, no cache
  * mode="prefill"  full sequence, writes cache at offset 0
  * mode="decode"   short (usually 1-token) sequence against a cache

Per-layer *metadata* (window, rope theta) arrives as traced scalars so that
heterogeneous layers can share one lax.scan (see models/model.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import recurrent as rec
from repro.models.config import ArchConfig, BlockSpec, FFN, Mixer
from repro.models.layers import mlp, mlp_spec, rmsnorm, rmsnorm_spec

Tree = Any

WINDOW_INF = 2 ** 30     # "no window": larger than any position we trace


def block_param_specs(cfg: ArchConfig, blk: BlockSpec) -> Tree:
    d = cfg.d_model
    p: Dict[str, Tree] = {"norm_mixer": rmsnorm_spec(d)}
    if blk.mixer == Mixer.ATTN:
        p["attn"] = attn.gqa_specs(cfg)
    elif blk.mixer == Mixer.MLA:
        p["attn"] = attn.mla_specs(cfg)
    elif blk.mixer == Mixer.RGLRU:
        p["rglru"] = rec.rglru_specs(cfg)
    elif blk.mixer == Mixer.MLSTM:
        p["mlstm"] = rec.mlstm_specs(cfg)
    elif blk.mixer == Mixer.SLSTM:
        p["slstm"] = rec.slstm_specs(cfg)
    if blk.cross_attention:
        p["norm_cross"] = rmsnorm_spec(d)
        p["cross"] = attn.gqa_specs(cfg, cross=True)
    if blk.ffn == FFN.DENSE:
        p["norm_ffn"] = rmsnorm_spec(d)
        p["ffn"] = mlp_spec(cfg)
    elif blk.ffn == FFN.MOE:
        p["norm_ffn"] = rmsnorm_spec(d)
        p["ffn"] = moe_lib.moe_specs(cfg)
    return p


def block_cache_specs(cfg: ArchConfig, blk: BlockSpec, batch: int,
                      max_len: int, *, source_len: int = 0,
                      dtype=jnp.bfloat16) -> Tree:
    """Decode/prefill cache structure for one layer (None-free pytree)."""
    c: Dict[str, Tree] = {}
    if blk.mixer == Mixer.ATTN:
        c["attn"] = attn.gqa_cache_spec(cfg, batch, max_len, dtype)
    elif blk.mixer == Mixer.MLA:
        c["attn"] = attn.mla_cache_spec(cfg, batch, max_len, dtype)
    elif blk.mixer == Mixer.RGLRU:
        c["rglru"] = rec.rglru_state_spec(cfg, batch)
    elif blk.mixer == Mixer.MLSTM:
        c["mlstm"] = rec.mlstm_state_spec(cfg, batch)
    elif blk.mixer == Mixer.SLSTM:
        c["slstm"] = rec.slstm_state_spec(cfg, batch)
    if blk.cross_attention:
        from repro.models.params import spec as pspec
        hkv, hd = cfg.n_kv_heads, cfg.head_dim_
        c["cross"] = {
            "ek": pspec([batch, source_len, hkv, hd],
                        ["batch", "kv_len", "kv_heads", "hdim"], dtype,
                        "zeros"),
            "ev": pspec([batch, source_len, hkv, hd],
                        ["batch", "kv_len", "kv_heads", "hdim"], dtype,
                        "zeros"),
        }
    return c


def cross_kv(p: Tree, enc_out: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Encoder-side K/V for cross attention (computed once at prefill)."""
    ek = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
    ev = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
    return ek, ev


def apply_block(
    p: Tree,
    blk: BlockSpec,
    cfg: ArchConfig,
    x: jnp.ndarray,                     # [B,S,D]
    positions: jnp.ndarray,             # [B,S]
    meta: Dict[str, jnp.ndarray],       # window / theta traced scalars
    *,
    mode: str = "train",                # train | prefill | decode
    cache: Optional[Tree] = None,
    cache_offset=None,
    enc_out: Optional[jnp.ndarray] = None,   # encoder output (train/prefill)
    causal: bool = True,
    moe_impl: Optional[str] = None,
    moe_group: Optional[int] = None,
    attn_chunk: int = 1024,
) -> Tuple[jnp.ndarray, Optional[Tree], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Tree] = {} if cache is not None else None
    # when the config has no per-layer overrides, the BlockSpec's window /
    # theta are STATIC python values -- this is what lets the chunked
    # attention slice K/V to the window span (dynamic_slice needs a static
    # size) instead of masking a full-sequence score matrix
    if cfg.layer_windows is None and cfg.layer_thetas is None:
        window = blk.window
        theta = blk.rope_theta
    else:
        window = meta.get("window")
        theta = meta.get("theta", cfg.rope_theta)

    h = rmsnorm(p["norm_mixer"], x, cfg.norm_eps)
    if blk.mixer == Mixer.ATTN:
        sub = cache.get("attn") if cache else None
        y, nc = attn.gqa_attention(
            p["attn"], h, positions, cfg=cfg, window=window,
            rope_theta=theta, causal=causal, cache=sub,
            cache_offset=cache_offset, chunk_q=attn_chunk)
        if new_cache is not None:
            new_cache["attn"] = nc
    elif blk.mixer == Mixer.MLA:
        if mode == "decode":
            y, nc = attn.mla_attention_absorbed(
                p["attn"], h, positions, cfg=cfg, cache=cache["attn"],
                cache_offset=cache_offset, rope_theta=theta)
            new_cache["attn"] = nc
        else:
            y = attn.mla_attention_naive(p["attn"], h, positions, cfg=cfg,
                                         rope_theta=theta,
                                         chunk_q=attn_chunk)
            if cache is not None:
                # prefill: also populate the compressed cache for decode
                _, _, c_kv, k_rope = attn.mla_project(
                    p["attn"], h, positions, cfg, theta)
                import jax.lax as lax
                off = cache_offset if cache_offset is not None else 0
                ckv = lax.dynamic_update_slice(
                    cache["attn"]["c_kv"],
                    c_kv.astype(cache["attn"]["c_kv"].dtype), (0, off, 0))
                krp = lax.dynamic_update_slice(
                    cache["attn"]["k_rope"],
                    k_rope.astype(cache["attn"]["k_rope"].dtype), (0, off, 0))
                new_cache["attn"] = {"c_kv": ckv, "k_rope": krp}
    elif blk.mixer == Mixer.RGLRU:
        sub = cache.get("rglru") if cache else None
        y, nc = rec.rglru_block(p["rglru"], h, cfg=cfg, state=sub)
        if new_cache is not None:
            new_cache["rglru"] = nc
    elif blk.mixer == Mixer.MLSTM:
        if mode == "decode":
            y, nc = rec.mlstm_step(p["mlstm"], h, cache["mlstm"], cfg=cfg)
            new_cache["mlstm"] = nc
        else:
            y = rec.mlstm_parallel(p["mlstm"], h, cfg=cfg)
            if cache is not None:
                # prefill of a fresh sequence: rebuild state recurrently is
                # O(S); instead replay the parallel pass then fold the tail
                # state via a short scan.  For framework purposes we step.
                nc = _mlstm_state_from_sequence(p["mlstm"], h, cache["mlstm"],
                                                cfg)
                new_cache["mlstm"] = nc
    elif blk.mixer == Mixer.SLSTM:
        if mode == "decode":
            y, nc = rec.slstm_step(p["slstm"], h, cache["slstm"], cfg=cfg)
            new_cache["slstm"] = nc
        else:
            sub = cache.get("slstm") if cache else None
            y, nc = rec.slstm_sequence(p["slstm"], h, cfg=cfg, state=sub)
            if new_cache is not None:
                new_cache["slstm"] = nc
    else:
        raise ValueError(f"unknown mixer {blk.mixer}")
    x = x + y

    if blk.cross_attention:
        h = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        if mode == "decode":
            ek, ev = cache["cross"]["ek"], cache["cross"]["ev"]
        else:
            assert enc_out is not None, "cross-attention needs encoder output"
            ek, ev = cross_kv(p, enc_out)
        y, _ = attn.gqa_attention(
            p["cross"], h, positions, cfg=cfg, causal=False,
            kv_override=(ek.astype(h.dtype), ev.astype(h.dtype)))
        if new_cache is not None:
            new_cache["cross"] = {"ek": ek.astype(cache["cross"]["ek"].dtype)
                                  if cache else ek,
                                  "ev": ev.astype(cache["cross"]["ev"].dtype)
                                  if cache else ev}
        x = x + y

    if blk.ffn != FFN.NONE:
        h = rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
        if blk.ffn == FFN.DENSE:
            y = mlp(p["ffn"], h)
        else:
            y, aux = moe_lib.moe_ffn(p["ffn"], h, cfg, impl=moe_impl,
                                     group_size=moe_group)
        x = x + y
    return x, new_cache, aux


def _mlstm_state_from_sequence(p: Tree, h: jnp.ndarray, state0: Tree,
                               cfg: ArchConfig) -> Tree:
    """Fold a whole sequence into the mLSTM recurrent state (prefill)."""
    import jax

    def body(st, ht):
        _, st2 = rec.mlstm_step(p, ht[:, None, :], st, cfg=cfg)
        return st2, None

    st, _ = jax.lax.scan(body, state0, jnp.swapaxes(h, 0, 1))
    return st
