"""Mixture-of-Experts FFN: router + two dispatch strategies.

  * "onehot": GShard/Mesh-TF capacity-based one-hot dispatch einsums.  The
    TPU-classic formulation -- always GSPMD-shardable (experts on the
    "model"/EP axis), but pays dispatch/combine einsum FLOPs of
    2*B*S*E*C*D, which for narrow-expert archs (DeepSeek-V2: F=1536)
    rivals the expert compute itself.  This is the BASELINE; EXPERIMENTS.md
    section Perf hillclimbs it.
  * "dense": every expert computes every token, weighted by router prob.
    Exact (no capacity drops), used as the correctness oracle in tests and
    for tiny smoke configs.

Router: softmax -> top-k with load-balancing auxiliary loss (Switch/GShard
style), computed in fp32.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models.config import ArchConfig, MoEConfig
from repro.models.params import spec

Tree = Any


def moe_specs(cfg: ArchConfig) -> Tree:
    m = cfg.moe
    d = cfg.d_model
    dt = cfg.param_dtype
    p = {
        "router": spec([d, m.n_experts], ["embed", "experts"], jnp.float32),
        "wi_gate": spec([m.n_experts, d, m.d_ff_expert],
                        ["experts", "embed", "ffn"], dt),
        "wi_up": spec([m.n_experts, d, m.d_ff_expert],
                      ["experts", "embed", "ffn"], dt),
        "wo": spec([m.n_experts, m.d_ff_expert, d],
                   ["experts", "ffn", "embed"], dt),
    }
    if m.n_shared_experts > 0:
        f_sh = m.d_ff_shared or m.d_ff_expert * m.n_shared_experts
        p["shared"] = {
            "wi_gate": spec([d, f_sh], ["embed", "ffn"], dt),
            "wi_up": spec([d, f_sh], ["embed", "ffn"], dt),
            "wo": spec([f_sh, d], ["ffn", "embed"], dt),
        }
    return p


def _router(p: Tree, x: jnp.ndarray, m: MoEConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (gates [B,S,k] fp32, expert_idx [B,S,k] int32, aux_loss)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    # renormalize selected gates (DeepSeek/Mixtral convention)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balancing loss
    e = m.n_experts
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    prob_frac = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(dispatch_frac * prob_frac) * m.router_aux_loss
    return gates, idx, aux


def _expert_ffn(p: Tree, h: jnp.ndarray) -> jnp.ndarray:
    """h: [E, B, C, D] -> [E, B, C, D] via per-expert SwiGLU."""
    g = jnp.einsum("ebcd,edf->ebcf", h, p["wi_gate"])
    u = jnp.einsum("ebcd,edf->ebcf", h, p["wi_up"])
    return jnp.einsum("ebcf,efd->ebcd", jax.nn.silu(g) * u, p["wo"])


def moe_onehot(p: Tree, x: jnp.ndarray, m: MoEConfig, *,
               capacity_factor: Optional[float] = None,
               group_size: Optional[int] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based one-hot dispatch (GShard).  x: [B,S,D].

    ``group_size`` splits the sequence into independent dispatch groups
    (the GShard "G" dim): capacity C is per group, so the dispatch/combine
    einsum cost B*S*E*C*D becomes B*S*E*(g*k*cf/E)*D = B*S*g*k*cf*D --
    LINEAR in g instead of quadratic in S.  At S=32k / E=8 this is the
    difference between the dispatch einsums dominating the whole model
    (mixtral prefill baseline: 24x MODEL_FLOPS) and being a few percent.
    Groups also cap token imbalance blast radius (drops are per-group).
    """
    b, s, d = x.shape
    g = group_size or getattr(m, "group_size", None)
    if g and g < s and s % g == 0:
        ng = s // g
        xg = x.reshape(b * ng, g, d)
        y, aux = moe_onehot(p, xg, m, capacity_factor=capacity_factor,
                            group_size=None)
        return y.reshape(b, s, d), aux
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    cap = max(int(math.ceil(s * m.top_k * cf / m.n_experts)), 1)
    gates, idx, aux = _router(p, x, m)

    e = m.n_experts
    # position of each (token, slot) in its expert's queue, computed slot-
    # major so slot 0 assignments take priority (GShard convention)
    dispatch = jnp.zeros((b, s, e, cap), x.dtype)
    combine = jnp.zeros((b, s, e, cap), x.dtype)
    counts = jnp.zeros((b, e), jnp.int32)
    for slot in range(m.top_k):
        onehot_e = jax.nn.one_hot(idx[..., slot], e, dtype=jnp.int32)  # [B,S,E]
        pos = jnp.cumsum(onehot_e, axis=1) - 1 + counts[:, None, :]
        counts = counts + onehot_e.sum(axis=1)
        within = (pos < cap) & (onehot_e > 0)
        pos_oh = jax.nn.one_hot(jnp.where(within, pos, cap), cap + 1,
                                dtype=x.dtype)[..., :cap]         # drop ovfl
        contrib = onehot_e[..., None].astype(x.dtype) * pos_oh
        dispatch = dispatch + contrib
        combine = combine + contrib * gates[..., slot][..., None, None] \
            .astype(x.dtype)
    # shard the big [B,S,E,C] lookup tensors over (data, model): with the
    # expert dim on "model" the dispatch einsum computes each (expert,
    # batch) block locally and only the combine contraction all-reduces
    dispatch = shard_hint(dispatch, ("batch", None, "experts", None))
    combine = shard_hint(combine, ("batch", None, "experts", None))

    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
    expert_in = shard_hint(expert_in, ("experts", "batch", None, None))
    expert_out = _expert_ffn(p, expert_in)
    expert_out = shard_hint(expert_out, ("experts", "batch", None, None))
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), expert_out)
    return shard_hint(y, ("batch", "seq", None)), aux


def moe_dense(p: Tree, x: jnp.ndarray, m: MoEConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact dense fallback: all experts on all tokens (oracle/smoke)."""
    gates, idx, aux = _router(p, x, m)
    # full gate matrix [B,S,E]
    full = jnp.zeros(x.shape[:2] + (m.n_experts,), jnp.float32)
    for slot in range(m.top_k):
        full = full + jax.nn.one_hot(idx[..., slot], m.n_experts,
                                     dtype=jnp.float32) * \
            gates[..., slot][..., None]
    h = x[None]                                          # [1,B,S,D]
    g = jnp.einsum("bsd,edf->ebsf", x, p["wi_gate"])
    u = jnp.einsum("bsd,edf->ebsf", x, p["wi_up"])
    eo = jnp.einsum("ebsf,efd->ebsd", jax.nn.silu(g) * u, p["wo"])
    y = jnp.einsum("bse,ebsd->bsd", full.astype(x.dtype), eo)
    return y, aux


def shared_expert(p: Tree, x: jnp.ndarray) -> jnp.ndarray:
    sp = p["shared"]
    g = jnp.einsum("bsd,df->bsf", x, sp["wi_gate"])
    u = jnp.einsum("bsd,df->bsf", x, sp["wi_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, sp["wo"])


def moe_ffn(p: Tree, x: jnp.ndarray, cfg: ArchConfig, *,
            impl: Optional[str] = None,
            group_size: Optional[int] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full MoE FFN: routed experts (+ shared experts if configured)."""
    m = cfg.moe
    impl = impl or m.impl
    if impl == "dense":
        y, aux = moe_dense(p, x, m)
    elif impl == "onehot":
        y, aux = moe_onehot(p, x, m,
                            group_size=group_size or m.group_size or None)
    else:
        raise ValueError(f"unknown moe impl {impl!r}")
    if m.n_shared_experts > 0:
        y = y + shared_expert(p, x)
    return y, aux
