"""Architecture configuration: one dataclass drives model build, sharding,
cache layout, dry-run input specs and the roofline FLOP model.

A model is a frontend stub (optional) + embedding + a sequence of *scan
groups*.  Each group is (repeats x pattern) where the pattern is a short
list of structurally-identical-across-repeats blocks; lax.scan runs over
repeats (keeps HLO size depth-independent -- DESIGN.md section 5).  Per-layer
*metadata* (attention window, rope theta) rides along as scanned arrays so
heterogeneous-but-shape-identical layers (gemma3's 5:1 local:global) stay
in one scan.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp


class Mixer(str, enum.Enum):
    ATTN = "attn"            # GQA/MQA/MHA full or sliding-window attention
    MLA = "mla"              # multi-head latent attention (DeepSeek/MiniCPM)
    RGLRU = "rglru"          # RecurrentGemma RG-LRU block (conv1d + LRU)
    MLSTM = "mlstm"          # xLSTM matrix-memory block
    SLSTM = "slstm"          # xLSTM scalar-memory block


class FFN(str, enum.Enum):
    DENSE = "dense"          # SwiGLU MLP
    MOE = "moe"              # routed experts (+ optional shared experts)
    NONE = "none"            # block has no separate FFN (xLSTM)


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: Mixer
    ffn: FFN = FFN.DENSE
    # attention metadata (None window = full/global attention)
    window: Optional[int] = None
    rope_theta: float = 10_000.0
    cross_attention: bool = False    # decoder block attending to encoder


@dataclasses.dataclass(frozen=True)
class ScanGroup:
    name: str
    repeats: int
    pattern: Tuple[BlockSpec, ...]

    @property
    def n_layers(self) -> int:
        return self.repeats * len(self.pattern)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0                 # total shared width (0 = none)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    impl: str = "onehot"                 # onehot | dense  (see models/moe.py)
    group_size: int = 0                  # 0 = one group (see moe_onehot)


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    lru_width: int = 0                   # defaults to d_model when 0
    conv_width: int = 4
    expand: float = 1.0                  # rglru input expansion


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """For enc-dec archs (whisper): a separate bidirectional encoder."""
    n_layers: int
    source_len: int                      # e.g. 1500 audio frames
    frontend: str = "audio_stub"         # precomputed embeddings (stub)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                          # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # defaults to d_model // n_heads
    groups: Tuple[ScanGroup, ...] = ()
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    encoder: Optional[EncoderConfig] = None
    n_prefix_embeddings: int = 0         # VLM stub: image tokens prepended
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_logit_softcap: Optional[float] = None
    sub_quadratic: bool = False          # eligible for long_500k shape
    # optional flat per-layer overrides (length n_layers, group-major order)
    # for heterogeneous-in-metadata stacks (gemma3's 5:1 local:global)
    layer_windows: Optional[Tuple[Optional[int], ...]] = None
    layer_thetas: Optional[Tuple[float, ...]] = None
    param_dtype: object = jnp.bfloat16
    compute_dtype: object = jnp.bfloat16
    max_position: int = 131_072
    source: str = ""                     # provenance tag from the assignment

    # ---------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def total_layers(self) -> int:
        return sum(g.n_layers for g in self.groups)

    def validate(self) -> None:
        if self.total_layers != self.n_layers:
            raise ValueError(
                f"{self.name}: groups define {self.total_layers} layers, "
                f"config says {self.n_layers}")
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: heads not divisible by kv heads")
        for g in self.groups:
            for b in g.pattern:
                if b.mixer == Mixer.MLA and self.mla is None:
                    raise ValueError(f"{self.name}: MLA block without mla cfg")
                if b.ffn == FFN.MOE and self.moe is None:
                    raise ValueError(f"{self.name}: MoE block without moe cfg")
                if b.mixer == Mixer.RGLRU and self.recurrent is None:
                    raise ValueError(f"{self.name}: RGLRU without recurrent")

    # -- analytic parameter / FLOP model (roofline section) ----------
    def param_count(self) -> int:
        from repro.models.model import build_param_specs  # lazy, avoids cycle
        from repro.models.params import param_count
        return param_count(build_param_specs(self))

    def active_param_count(self) -> int:
        """Activated params per token (= dense count for non-MoE)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m, d = self.moe, self.d_model
        per_expert = 3 * d * m.d_ff_expert
        moe_layers = sum(
            g.repeats * sum(1 for b in g.pattern if b.ffn == FFN.MOE)
            for g in self.groups)
        inactive = per_expert * (m.n_experts - m.top_k) * moe_layers
        return total - inactive

    def model_flops_per_token(self, train: bool = True) -> float:
        """MODEL_FLOPS = 6 N_active per token (3 fwd+bwd passes x 2 MAC),
        or 2 N_active for inference forward-only."""
        mult = 6.0 if train else 2.0
        return mult * self.active_param_count()


def dense_lm(name: str, *, n_layers: int, d_model: int, n_heads: int,
             n_kv_heads: int, d_ff: int, vocab_size: int,
             head_dim: Optional[int] = None, window: Optional[int] = None,
             rope_theta: float = 10_000.0, family: str = "dense",
             source: str = "", **kw) -> ArchConfig:
    """Helper for the common single-scan-group decoder-only LM."""
    blk = BlockSpec(Mixer.ATTN, FFN.DENSE, window=window,
                    rope_theta=rope_theta)
    return ArchConfig(
        name=name, family=family, n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_kv_heads, d_ff=d_ff,
        vocab_size=vocab_size, head_dim=head_dim, rope_theta=rope_theta,
        groups=(ScanGroup("main", n_layers, (blk,)),), source=source, **kw)
