"""Shared layers: RMSNorm, SwiGLU MLP, embeddings, losses."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import spec

Tree = Any


# -- norms ------------------------------------------------------------------

def rmsnorm_spec(d: int) -> Tree:
    return {"scale": spec([d], ["embed"], jnp.float32, "ones")}


def rmsnorm(p: Tree, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * p["scale"]).astype(x.dtype)


# -- MLP --------------------------------------------------------------------

def mlp_spec(cfg: ArchConfig, d_ff: Optional[int] = None) -> Tree:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.param_dtype
    return {
        "wi_gate": spec([d, f], ["embed", "ffn"], dt),
        "wi_up": spec([d, f], ["embed", "ffn"], dt),
        "wo": spec([f, d], ["ffn", "embed"], dt),
    }


def mlp(p: Tree, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["wo"])


# -- embeddings / head ------------------------------------------------------

def embed_specs(cfg: ArchConfig) -> Tree:
    p = {"table": spec([cfg.vocab_size, cfg.d_model], ["vocab", "embed"],
                       cfg.param_dtype, "embed")}
    if not cfg.tie_embeddings:
        p["head"] = spec([cfg.d_model, cfg.vocab_size], ["embed", "vocab"],
                         cfg.param_dtype)
    return p


def embed(p: Tree, tokens: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    x = jnp.take(p["table"], tokens, axis=0)
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def unembed(p: Tree, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["table"])
    return jnp.einsum("bsd,dv->bsv", x, p["head"])


# -- loss -------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross-entropy.  logits [B,S,V] (any float dtype,
    reduced in fp32), labels [B,S] int32.

    The label log-prob is extracted with a one-hot contraction, NOT
    take_along_axis: a gather over the vocab dim -- which is sharded over
    the "model" axis -- would force GSPMD to all-gather the full fp32
    logits (69 GB/device for gemma3's 262k vocab at train_4k).  The
    one-hot product fuses into the reduction and keeps logits sharded.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.sum(shifted * onehot, axis=-1) + m[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
