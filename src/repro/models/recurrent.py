"""Recurrent mixers: RG-LRU (RecurrentGemma/Griffin) and xLSTM (mLSTM/sLSTM).

All three expose (train/prefill) a full-sequence form and (decode) a
single-step state update, with state pytrees sized independently of sequence
length -- this is what makes the `long_500k` shape feasible for the ssm/
hybrid architectures (DESIGN.md section 4).

  * RG-LRU uses an associative scan (log-depth) over the diagonal linear
    recurrence; the Pallas kernel in repro/kernels/rglru_scan.py implements
    the same contract with VMEM-blocked tiles.
  * mLSTM has a parallel (attention-like, stabilized exponential-gate)
    training form and an O(1)-state recurrent decode form.
  * sLSTM is genuinely sequential (memory mixing through block-diagonal
    recurrent weights), so training runs a lax.scan over time.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import spec

Tree = Any


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block: proj -> conv1d -> RG-LRU, gated)
# ---------------------------------------------------------------------------

def rglru_specs(cfg: ArchConfig) -> Tree:
    d = cfg.d_model
    r = cfg.recurrent
    w = r.lru_width or d
    dt = cfg.param_dtype
    return {
        "w_in": spec([d, w], ["embed", "ffn"], dt),      # recurrence branch
        "w_gate": spec([d, w], ["embed", "ffn"], dt),    # gelu gate branch
        "conv_w": spec([r.conv_width, w], ["conv", "ffn"], dt),
        "conv_b": spec([w], ["ffn"], dt, "zeros"),
        "lambda_param": spec([w], ["ffn"], jnp.float32, "ones"),
        "w_rec_gate": spec([w, w], ["ffn", "ffn2"], dt),   # r_t projection
        "b_rec_gate": spec([w], ["ffn"], dt, "zeros"),
        "w_in_gate": spec([w, w], ["ffn", "ffn2"], dt),    # i_t projection
        "b_in_gate": spec([w], ["ffn"], dt, "zeros"),
        "w_out": spec([w, d], ["ffn", "embed"], dt),
    }


_RGLRU_C = 8.0


def _rglru_gates(p: Tree, u: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a_t (decay) and b_t (input) of the diagonal recurrence, fp32."""
    uf = u.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", uf, p["w_rec_gate"].astype(jnp.float32))
        + p["b_rec_gate"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", uf, p["w_in_gate"].astype(jnp.float32))
        + p["b_in_gate"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lambda_param"]) * r_gate
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_gate * uf)
    return a, b


def _conv1d(p: Tree, u: jnp.ndarray,
            state: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal temporal conv.  u: [B,S,W].  state: [B,cw-1,W]
    carries the last cw-1 inputs for decode continuity."""
    cw = p["conv_w"].shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)        # [B, S+cw-1, W]
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(cw):
        out = out + ext[:, i:i + u.shape[1], :].astype(jnp.float32) * \
            p["conv_w"][i].astype(jnp.float32)
    out = out + p["conv_b"].astype(jnp.float32)
    new_state = ext[:, ext.shape[1] - (cw - 1):, :]
    return out.astype(u.dtype), new_state


def rglru_block(
    p: Tree, x: jnp.ndarray, *, cfg: ArchConfig,
    state: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full Griffin recurrent block.  x: [B,S,D].
    state = {"conv": [B,cw-1,W], "h": [B,W]} or None (fresh sequence)."""
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    gate = jnp.einsum("bsd,dw->bsw", x, p["w_gate"])
    conv_state = state["conv"] if state is not None else None
    u, new_conv = _conv1d(p, u, conv_state)
    a, b = _rglru_gates(p, u)                    # [B,S,W] fp32

    h0 = state["h"].astype(jnp.float32) if state is not None else None
    if h0 is not None:
        # fold the carried state into the first step's input term
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h.astype(x.dtype) * jax.nn.gelu(gate)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "h": h[:, -1, :].astype(state["h"].dtype)}
    return out, new_state


def rglru_state_spec(cfg: ArchConfig, batch: int) -> Tree:
    r = cfg.recurrent
    w = r.lru_width or cfg.d_model
    return {
        "conv": spec([batch, r.conv_width - 1, w],
                     ["batch", "conv", "ffn"], jnp.bfloat16, "zeros"),
        "h": spec([batch, w], ["batch", "ffn"], jnp.float32, "zeros"),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory)
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ArchConfig) -> Tree:
    d, h = cfg.d_model, cfg.n_heads
    k = d // h
    dt = cfg.param_dtype
    return {
        "wq": spec([d, h, k], ["embed", "heads", "hdim"], dt),
        "wk": spec([d, h, k], ["embed", "heads", "hdim"], dt),
        "wv": spec([d, h, k], ["embed", "heads", "hdim"], dt),
        "w_i": spec([d, h], ["embed", "heads"], dt),     # exp input gate
        "b_i": spec([h], ["heads"], dt, "zeros"),
        "w_f": spec([d, h], ["embed", "heads"], dt),     # forget gate
        "b_f": spec([h], ["heads"], dt, "zeros"),
        "w_o": spec([d, h, k], ["embed", "heads", "hdim"], dt),  # out gate
        "wo": spec([h, k, d], ["heads", "hdim", "embed"], dt),
    }


def mlstm_parallel(p: Tree, x: jnp.ndarray, *, cfg: ArchConfig) -> jnp.ndarray:
    """Stabilized parallel form (xLSTM paper eqs. 24-27).  O(S^2) like
    attention; used for training/prefill."""
    b, s, d = x.shape
    h = cfg.n_heads
    k = d // h
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]) / math.sqrt(k)
    kk = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    log_i = (jnp.einsum("bsd,dh->bsh", x, p["w_i"]) + p["b_i"]) \
        .astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (jnp.einsum("bsd,dh->bsh", x, p["w_f"]) + p["b_f"]).astype(jnp.float32))

    # F[t,s] = sum_{j=s+1..t} log_f_j ; D[t,s] = F[t,s] + log_i_s  (s<=t)
    cum = jnp.cumsum(log_f, axis=1)                       # [B,S,H]
    fmat = cum[:, :, None, :] - cum[:, None, :, :]        # [B,t,s,H]
    dmat = fmat + log_i[:, None, :, :]
    tidx = jnp.arange(s)
    causal = (tidx[None, :, None] >= tidx[None, None, :])[..., None]
    dmat = jnp.where(causal, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)              # stabilizer [B,t,1,H]
    w = jnp.exp(dmat - m)                                 # [B,t,s,H]
    scores = jnp.einsum("bthk,bshk->btsh", q, kk,
                        preferred_element_type=jnp.float32) * w
    denom = jnp.maximum(jnp.abs(scores.sum(axis=2)),
                        jnp.exp(-m[:, :, 0, :]))          # [B,t,H]
    out = jnp.einsum("btsh,bshk->bthk", scores, v.astype(jnp.float32))
    out = out / denom[..., None]
    o = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, p["w_o"])
                       .astype(jnp.float32))
    out = (out * o).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mlstm_step(p: Tree, x: jnp.ndarray, state: Dict[str, jnp.ndarray], *,
               cfg: ArchConfig
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Recurrent decode step.  x: [B,1,D].
    state: C [B,H,K,K], n [B,H,K], m [B,H]."""
    b, s, d = x.shape
    assert s == 1
    h = cfg.n_heads
    k = d // h
    xt = x[:, 0]
    q = jnp.einsum("bd,dhk->bhk", xt, p["wq"]) / math.sqrt(k)
    kk = jnp.einsum("bd,dhk->bhk", xt, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", xt, p["wv"])
    log_i = (xt @ p["w_i"] + p["b_i"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid((xt @ p["w_f"] + p["b_f"]).astype(jnp.float32))

    m_prev = state["m"]
    m_new = jnp.maximum(log_f + m_prev, log_i)
    f_sc = jnp.exp(log_f + m_prev - m_new)[..., None]
    i_sc = jnp.exp(log_i - m_new)[..., None]
    kf, vf = kk.astype(jnp.float32), v.astype(jnp.float32)
    c_new = state["C"] * f_sc[..., None] + \
        i_sc[..., None] * kf[..., :, None] * vf[..., None, :]
    n_new = state["n"] * f_sc + i_sc * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n_new)),
                      jnp.exp(-m_new))
    out = num / den[..., None]
    o = jax.nn.sigmoid(jnp.einsum("bd,dhk->bhk", xt, p["w_o"])
                       .astype(jnp.float32))
    out = (out * o).astype(x.dtype)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None, :]
    return y, {"C": c_new, "n": n_new, "m": m_new}


def mlstm_state_spec(cfg: ArchConfig, batch: int) -> Tree:
    h = cfg.n_heads
    k = cfg.d_model // h
    return {
        "C": spec([batch, h, k, k], ["batch", "heads", "hdim", "hdim2"],
                  jnp.float32, "zeros"),
        "n": spec([batch, h, k], ["batch", "heads", "hdim"], jnp.float32,
                  "zeros"),
        "m": spec([batch, h], ["batch", "heads"], jnp.float32, "zeros"),
    }


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory with block-diagonal recurrence)
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ArchConfig) -> Tree:
    d, h = cfg.d_model, cfg.n_heads
    k = d // h
    dt = cfg.param_dtype
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w_{g}"] = spec([d, h, k], ["embed", "heads", "hdim"], dt)
        gates[f"r_{g}"] = spec([h, k, k], ["heads", "hdim", "hdim2"], dt)
        gates[f"b_{g}"] = spec([h, k], ["heads", "hdim"], dt, "zeros")
    gates["wo"] = spec([h, k, d], ["heads", "hdim", "embed"], dt)
    return gates


def _slstm_cell(p: Tree, xt: jnp.ndarray, st: Dict[str, jnp.ndarray]
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One sLSTM timestep.  xt: [B,D]; state h,c,n,m: [B,H,K] fp32."""
    hp = st["h"]

    def gate(g):
        wx = jnp.einsum("bd,dhk->bhk", xt, p[f"w_{g}"]).astype(jnp.float32)
        rh = jnp.einsum("bhj,hjk->bhk", hp, p[f"r_{g}"].astype(jnp.float32))
        return wx + rh + p[f"b_{g}"].astype(jnp.float32)

    z = jnp.tanh(gate("z"))
    log_i = gate("i")                      # exponential input gate
    log_f = jax.nn.log_sigmoid(gate("f"))
    o = jax.nn.sigmoid(gate("o"))
    m_new = jnp.maximum(log_f + st["m"], log_i)
    i_sc = jnp.exp(log_i - m_new)
    f_sc = jnp.exp(log_f + st["m"] - m_new)
    c_new = f_sc * st["c"] + i_sc * z
    n_new = jnp.maximum(f_sc * st["n"] + i_sc, 1e-6)
    h_new = o * c_new / n_new
    return h_new, {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_sequence(p: Tree, x: jnp.ndarray, *, cfg: ArchConfig,
                   state: Optional[Dict[str, jnp.ndarray]] = None
                   ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Sequential scan over time (training + prefill).  x: [B,S,D]."""
    b, s, d = x.shape
    h, k = cfg.n_heads, cfg.d_model // cfg.n_heads
    st0 = state
    if st0 is None:
        z = jnp.zeros((b, h, k), jnp.float32)
        st0 = {"h": z, "c": z, "n": z + 1e-6, "m": z}
    st0 = {kk: v.astype(jnp.float32) for kk, v in st0.items()}

    def body(st, xt):
        h_new, st_new = _slstm_cell(p, xt, st)
        return st_new, h_new

    st_fin, hs = jax.lax.scan(body, st0, jnp.swapaxes(x, 0, 1))
    hs = jnp.swapaxes(hs, 0, 1).astype(x.dtype)          # [B,S,H,K]
    y = jnp.einsum("bshk,hkd->bsd", hs, p["wo"])
    new_state = None
    if state is not None:
        new_state = {kk: v.astype(state[kk].dtype) for kk, v in st_fin.items()}
    return y, new_state


def slstm_step(p: Tree, x: jnp.ndarray, state: Dict[str, jnp.ndarray], *,
               cfg: ArchConfig
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    st = {kk: v.astype(jnp.float32) for kk, v in state.items()}
    h_new, st_new = _slstm_cell(p, x[:, 0], st)
    y = jnp.einsum("bhk,hkd->bd", h_new.astype(x.dtype), p["wo"])
    return y[:, None, :], {kk: v.astype(state[kk].dtype)
                           for kk, v in st_new.items()}


def slstm_state_spec(cfg: ArchConfig, batch: int) -> Tree:
    h, k = cfg.n_heads, cfg.d_model // cfg.n_heads
    mk = lambda init: spec([batch, h, k], ["batch", "heads", "hdim"],
                           jnp.float32, init)
    return {"h": mk("zeros"), "c": mk("zeros"), "n": mk("ones"),
            "m": mk("zeros")}
