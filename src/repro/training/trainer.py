"""Training driver: data -> jitted train_step -> async checkpoints.

Composes the substrate: synthetic pipeline (repro.data), AdamW train step
with optional microbatch accumulation and int8 gradient compression
(repro.training), sharded init, and fault-tolerant resume
(repro.checkpoint).  The same ``make_train_step`` that the 512-device
dry-run lowers is what runs here on the host mesh -- one code path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_pytree
from repro.data import DataCursor, SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.models.config import ArchConfig
from repro.models.model import RunFlags, build_param_specs
from repro.models.params import materialize
from repro.training.optimizer import AdamWConfig, adamw_init_specs
from repro.models.params import tree_map_specs, ParamSpec

Tree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    batch_size: int = 8
    seq_len: int = 256
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 10
    grad_compression: bool = False
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    flags: RunFlags = dataclasses.field(default_factory=RunFlags)


def init_state(cfg: ArchConfig, seed: int = 0, *,
               compression: bool = False) -> Tree:
    specs = build_param_specs(cfg)
    params = materialize(specs, jax.random.PRNGKey(seed))
    mu_s, nu_s = adamw_init_specs(specs)
    zeros = lambda t: jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), t,
        is_leaf=lambda x: isinstance(x, ParamSpec))
    state = {"params": params, "mu": zeros(mu_s), "nu": zeros(nu_s),
             "step": jnp.zeros((), jnp.int32)}
    if compression:
        state["ef"] = zeros(mu_s)
    return state


def train(cfg: ArchConfig, tc: TrainConfig,
          log_fn: Callable[[str], None] = print) -> Dict[str, List[float]]:
    """Run the loop; returns the metric history (losses must descend --
    asserted by tests/test_training.py and the 100M example)."""
    step_fn = jax.jit(make_train_step(cfg, tc.opt, tc.flags,
                                      compression=tc.grad_compression),
                      donate_argnums=(0,))
    state = init_state(cfg, tc.seed, compression=tc.grad_compression)
    cursor = DataCursor()

    mgr = None
    if tc.checkpoint_dir:
        mgr = CheckpointManager(tc.checkpoint_dir)
        last = latest_step(tc.checkpoint_dir)
        if last is not None:
            ckpt_tmpl = {"state": state,
                         "cursor": jnp.zeros((), jnp.int32)}
            restored = restore_pytree(ckpt_tmpl, tc.checkpoint_dir, last)
            state = restored["state"]
            cursor.batch_index = int(restored["cursor"])
            log_fn(f"[trainer] resumed from step {last} "
                   f"(batch cursor {cursor.batch_index})")

    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
                            batch_size=tc.batch_size, seed=tc.seed)
    history: Dict[str, List[float]] = {"loss": [], "grad_norm": [],
                                       "step_time_s": []}
    it = ds.iterate(cursor)
    start_step = int(state["step"])
    err_state = None
    for i in range(start_step, tc.steps):
        batch_np = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        history["loss"].append(loss)
        history["grad_norm"].append(float(metrics["grad_norm"]))
        history["step_time_s"].append(dt)
        if i % tc.log_every == 0 or i == tc.steps - 1:
            log_fn(f"[trainer] step {i:5d} loss {loss:8.4f} "
                   f"gnorm {float(metrics['grad_norm']):8.3f} "
                   f"{dt*1e3:7.1f} ms")
        if mgr and tc.checkpoint_every and (i + 1) % tc.checkpoint_every == 0:
            mgr.save_async({"state": state,
                            "cursor": jnp.asarray(cursor.batch_index,
                                                  jnp.int32)}, i + 1)
    if mgr:
        mgr.save_async({"state": state,
                        "cursor": jnp.asarray(cursor.batch_index,
                                              jnp.int32)}, tc.steps)
        mgr.close()
    return history
