"""GPipe-style pipeline parallelism over the "pod" axis (optional feature;
DESIGN.md section 5).

The production multi-pod mesh is (pod=2, data=16, model=16).  The default
regime treats "pod" as pure data parallelism (gradient all-reduce over
DCN).  This module offers the alternative: the layer stack is split into
`n_stages = pod` contiguous stages; microbatches stream through stages
with activations handed across pods via ``jax.lax.ppermute`` on a GPipe
schedule (fill, steady state, drain).  Because ppermute is differentiable
(its transpose is the reverse permutation), ``jax.grad`` through the
pipelined forward yields the correct pipelined backward -- no manual
schedule for the bwd pass.

Scope: decoder-only dense stacks with a single scan group (the
pipeline-stage split must be a clean layer partition).  The dry-run proof
(`python -m repro.launch.dryrun_pipeline`) lowers + compiles the
pipelined train step on the (2,16,16) mesh; `tests/test_pipeline.py`
checks numerical equivalence against the plain stack on a degenerate
1-stage mesh and the schedule logic on a simulated 2-stage run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import embed, rmsnorm, softmax_xent, unembed
from repro.models.model import RunFlags, build_meta, _run_groups

Tree = Any


def split_stage_params(params: Tree, cfg: ArchConfig, n_stages: int) -> Tree:
    """Reshape the single scan group's stacked params [L, ...] into
    [n_stages, L/n_stages, ...] so stage s owns slice s."""
    if len(cfg.groups) != 1 or len(cfg.groups[0].pattern) != 1:
        raise ValueError("pipeline supports single-group single-pattern "
                         "stacks (dense decoder-only)")
    L = cfg.groups[0].repeats
    if L % n_stages:
        raise ValueError(f"{L} layers not divisible by {n_stages} stages")

    def reshape(leaf):
        return leaf.reshape((n_stages, L // n_stages) + leaf.shape[1:])

    gname = cfg.groups[0].name
    out = dict(params)
    out["groups"] = {gname: {"pos0": jax.tree_util.tree_map(
        reshape, params["groups"][gname]["pos0"])}}
    return out


def make_pipelined_train_loss(cfg: ArchConfig, mesh: Mesh, *,
                              n_microbatches: int,
                              axis: str = "pod",
                              flags: RunFlags = RunFlags()):
    """Returns loss_fn(params_staged, batch) running a GPipe schedule via
    shard_map over `axis`.  params_staged: stage dim leading (sharded over
    `axis`); batch: tokens/labels [B, S] with B % n_microbatches == 0."""
    n_stages = mesh.shape[axis]
    gname = cfg.groups[0].name
    L_per = cfg.groups[0].repeats // n_stages
    stage_group = dataclasses.replace(cfg.groups[0], repeats=L_per)
    stage_cfg = dataclasses.replace(cfg, groups=(stage_group,),
                                    n_layers=L_per * len(
                                        stage_group.pattern))
    metas = build_meta(stage_cfg)

    def stage_fn(p_stage: Tree, h: jnp.ndarray,
                 positions: jnp.ndarray) -> jnp.ndarray:
        """Run this device's L/n_stages layers."""
        params = {"groups": {gname: {"pos0": p_stage}}}
        out, _, _ = _run_groups(params, stage_cfg.groups, stage_cfg, h,
                                positions, metas, mode="train", flags=flags)
        return out

    def pipeline_body(p_stage, emb_mb, positions):
        """Inside shard_map: emb_mb [M, mb, S, D] microbatched embeddings
        (replicated across stages); returns final-stage activations."""
        # shard_map leaves a leading size-1 stage dim on the local slice
        p_stage = jax.tree_util.tree_map(lambda x: x[0], p_stage)
        stage = jax.lax.axis_index(axis)
        M = emb_mb.shape[0]
        mb_shape = emb_mb.shape[1:]
        steps = M + n_stages - 1
        buf = jnp.zeros_like(emb_mb)          # finished microbatches
        carry = jnp.zeros(mb_shape, emb_mb.dtype)

        def step(t, state):
            buf, carry = state
            # stage 0 ingests microbatch t (when in range)
            mb_in = emb_mb[jnp.clip(t, 0, M - 1)]
            h_in = jnp.where(stage == 0, mb_in, carry)
            h_out = stage_fn(p_stage, h_in, positions)
            # hand activations downstream (last stage wraps to 0, masked)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(h_out, axis, perm)
            # last stage stores microbatch (t - (n_stages-1)) when valid
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            valid = (t >= n_stages - 1) & (stage == n_stages - 1)
            upd = jnp.where(valid, h_out,
                            buf[out_idx])
            buf = jax.lax.dynamic_update_index_in_dim(buf, upd, out_idx, 0)
            return buf, nxt

        buf, _ = jax.lax.fori_loop(0, steps, step, (buf, carry))
        # broadcast final activations from the last stage to all stages
        # (each stage computes loss on identical data; psum averages)
        src = n_stages - 1
        perm = [(src, i) for i in range(n_stages)]
        buf = jax.lax.ppermute(buf, axis, [(src, (src + 1) % n_stages)]) \
            if n_stages > 1 else buf
        return buf

    from jax.experimental.shard_map import shard_map
    in_specs = (P(axis), P(), P())
    out_specs = P(axis)  # stage-local copies; stage (0) holds real output

    smapped = shard_map(pipeline_body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)

    def loss_fn(params_staged: Tree, batch: Dict[str, jnp.ndarray]
                ) -> jnp.ndarray:
        tokens, labels = batch["tokens"], batch["labels"]
        b, s = tokens.shape
        M = n_microbatches
        x = embed(params_staged["embed"], tokens, cfg) \
            .astype(cfg.compute_dtype)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b // M, s))
        emb_mb = x.reshape(M, b // M, s, x.shape[-1])
        p_stage = params_staged["groups"][gname]["pos0"]
        outs = smapped(p_stage, emb_mb, positions)
        # out_specs P(axis) concatenates stage-local [M, mb, S, D] buffers
        # along dim 0 -> [n_stages*M, ...]; stage 0's block holds the
        # pipeline output (ppermuted back from the last stage)
        h = outs[:M].reshape(b, s, -1)
        h = rmsnorm(params_staged["final_norm"], h, cfg.norm_eps)
        logits = unembed(params_staged["embed"], h, cfg)
        return softmax_xent(logits, labels)

    return loss_fn
