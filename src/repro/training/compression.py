"""int8 gradient compression with error feedback (distributed-optimization
trick for DCN-crossing gradient reduction; DESIGN.md section 5).

Quantize per-tensor symmetric int8 before the cross-pod all-reduce, keep
the quantization residual locally and add it back into the next step's
gradient ("error feedback" / EF-SGD), which provably preserves
convergence for smooth objectives.  8x less DCN traffic per step.

The compression is exposed as a pair (compress, decompress) applied
around the gradient reduction plus an error-feedback state threaded
through the train step; `tests/test_training.py` checks convergence
parity on a small problem.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_state(grads: Tree) -> Tree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_grads(grads: Tree, error: Tree) -> Tuple[Tree, Tree]:
    """Apply error feedback + int8 round-trip.  Returns (grads', error').

    In a real multi-host launch the int8 payload is what crosses DCN (the
    all-reduce runs on the quantized tensors); this in-graph round-trip
    has identical numerics and is what the convergence test exercises.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        deq = _dequantize(q, scale)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))
