from repro.training.optimizer import (AdamWConfig, adamw_init_specs,
                                      adamw_update)

__all__ = ["AdamWConfig", "adamw_init_specs", "adamw_update"]
