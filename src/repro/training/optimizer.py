"""AdamW in pure JAX (no optax in this container).

Moments are fp32 regardless of parameter dtype and shard exactly like
their parameters (the ParamSpec trees share logical axes), which under
TRAIN_RULES gives ZeRO-style distributed optimizer state for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec, is_spec, tree_map_specs

Tree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init_specs(param_specs: Tree) -> Tuple[Tree, Tree]:
    """(mu_specs, nu_specs): fp32 zeros with the params' logical axes."""
    def f32(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, jnp.float32, s.axes, "zeros")
    return tree_map_specs(f32, param_specs), tree_map_specs(f32, param_specs)


def lr_at(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree: Tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def adamw_update(
    params: Tree, grads: Tree, mu: Tree, nu: Tree, step: jnp.ndarray,
    cfg: AdamWConfig,
) -> Tuple[Tree, Tree, Tree, jnp.ndarray]:
    """One AdamW step.  Returns (params, mu, nu, grad_norm)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = lr_at(step, cfg)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1.0 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1.0 - cfg.b2) * gf * gf
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(mu)
    flat_v = jax.tree_util.tree_leaves(nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, new_m, new_v, gnorm
