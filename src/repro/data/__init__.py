from repro.data.pipeline import SyntheticLMDataset, DataCursor

__all__ = ["SyntheticLMDataset", "DataCursor"]
