"""Data pipeline: deterministic synthetic LM batches with a resumable
cursor and background prefetch.

Synthetic corpus: a mixture of Zipf-distributed unigrams and short
repeated motifs, so a language model has real (low-entropy) structure to
learn -- the 100M-example's loss curve must actually descend, not just
jitter (a uniform-random stream has no learnable signal).

``DataCursor`` (just the batch index) rides inside the training
checkpoint, making restarts bit-exact: batch i is a pure function of
(seed, i).  Prefetch runs one batch ahead on a thread -- the host-side
analogue of overlapping input copy with compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataCursor:
    batch_index: int = 0


class SyntheticLMDataset:
    def __init__(self, *, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, motif_len: int = 16, n_motifs: int = 64):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed
        rng = np.random.default_rng(seed)
        # motif bank: repeated phrases give the model learnable structure
        self._motifs = rng.integers(
            0, vocab_size, size=(n_motifs, motif_len), dtype=np.int32)
        # Zipf unigram distribution
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._unigram = p / p.sum()

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        """Batch `index` as a pure function of (seed, index)."""
        rng = np.random.default_rng((self.seed, index))
        b, s = self.batch_size, self.seq_len
        toks = rng.choice(self.vocab_size, size=(b, s + 1),
                          p=self._unigram).astype(np.int32)
        # overwrite random spans with motifs (about half the stream)
        n_spans = max((s // self._motifs.shape[1]) // 2, 1)
        for i in range(b):
            for _ in range(n_spans):
                m = self._motifs[rng.integers(len(self._motifs))]
                start = rng.integers(0, s + 1 - len(m))
                toks[i, start:start + len(m)] = m
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, cursor: Optional[DataCursor] = None, *,
                prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
        """Resumable background-prefetched stream."""
        cursor = cursor or DataCursor()
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            i = cursor.batch_index
            while not stop.is_set():
                q.put((i, self.batch(i)))
                i += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                i, b = q.get()
                cursor.batch_index = i + 1
                yield b
        finally:
            stop.set()
            try:
                q.get_nowait()
            except queue.Empty:
                pass
