"""Four-objective Pareto fleet planner: sweep plans, keep the frontier.

The simulator meters one configuration at a time; the planner turns it
into a capacity-planning tool.  ``plan_fleet`` sweeps a grid of plans --
fleet composition / purchase-tier specs, routing policies, spot
preemption rates -- runs each through the cheapest engine that can
replay it (the compiled ``run_mega`` backends for warm-first
zero-service plans, the event loop for everything else), and reduces
the sweep to the set of plans no other plan beats on ALL of

    (cost_usd, energy_wh, carbon_kg, p99_added_latency_s)

-- the non-dominated frontier (same Pareto-over-plans shape as the
dgx-cloud planner the ROADMAP names, generalized to four objectives).

The frontier's single summary number is its **hypervolume** against the
all-on-demand reference plan: objectives are normalized so the
reference sits at (1, 1, 1, 1), values beating the reference land in
[0, 1), values worse than it clip to 1 (no credit), and the reported
volume is the fraction of the unit box the frontier dominates.  0 means
nothing in the sweep beats always-on-demand anywhere; the volume grows
as plans push the corners in.  Exact recursive slicing -- frontiers are
tens of points, not thousands.

Execution comes in two modes.  ``batched=False`` evaluates every grid
point as its own simulation (the legacy shape).  ``batched=True`` (the
default) runs at compiled-sweep speed: points are GROUPED by structural
shape -- ``(fleet, router, rate, spot-device-set)`` -- because purchase
tiers never steer the dynamics (they only re-price the metered
timeline, and the preemption draw depends on the tier map only through
which devices are spot).  One simulation per group replays hot on the
``run_mega_sweep`` shared-compile machinery; tier variants re-price the
group's metered reports through ``pricing.price_fleet``, bit-identical
to a fresh run.  Points outside mega scope (stateful routers, actual
fault draws) dispatch concurrently on a worker pool.  See docs/SCALE.md
"Batched planning".
"""
from __future__ import annotations

import concurrent.futures
import copy
import dataclasses
import json
import math
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.fleet.catalog import build_fleet
from repro.fleet.fleetsim import (DAY, FleetModel, FleetScenario,
                                  mixed_fleet_scenario, run_fleet)
from repro.fleet.pricing import PreemptionModel, price_fleet
from repro.fleet.router import get_router

OBJECTIVES = ("cost_usd", "energy_wh", "carbon_kg", "p99_s")

# The pinned 3-zone day (PR 8's follow-the-sun fleet) and its spot-tier
# variants: the canonical sweep the planner acceptance test, the
# fleet24h.pareto.* bench family, and examples/fleet_planner.py all
# share, so a future spec change cannot de-sync them.
ZONES3_FLEET = "2xh100@DEU+2xa100@USA+2xl40s@IND"
SPOT_H100_FLEET = "2xh100@DEU:spot+2xa100@USA+2xl40s@IND"
SPOT_ALL_FLEET = "2xh100@DEU:spot+2xa100@USA:spot+2xl40s@IND:spot"


@dataclasses.dataclass(frozen=True)
class PlanAxes:
    """The sweep grid: every combination of these axes is one plan.

    ``fleets`` are fleet spec strings and may embed per-part zones and
    tiers (``"2xh100@DEU:spot+2xa100"``); ``price_tiers`` sweeps the
    scenario DEFAULT tier that tier-less parts inherit.  A nonzero
    preemption rate attaches a seeded ``PreemptionModel`` (spot-tier
    devices only), so on-demand plans are identical across rates and
    the planner dedupes them by skipping rate > 0 for plans with no
    spot device.
    """
    fleets: Tuple[str, ...]
    routers: Tuple[str, ...] = ("warm-first",)
    price_tiers: Tuple[str, ...] = ("on_demand",)
    preemption_rates: Tuple[float, ...] = (0.0,)
    preemption_warning_s: float = 120.0
    preemption_outage_s: float = 4 * 3600.0
    preemption_seed: int = 0


@dataclasses.dataclass(frozen=True)
class PlanPoint:
    """One evaluated plan: its coordinates on the sweep grid plus the
    four objective values (all minimized) and run provenance."""
    fleet: str
    router: str
    price_tier: str
    preemption_rate: float
    cost_usd: float
    energy_wh: float
    carbon_kg: float
    p99_s: float
    engine: str = ""                  # "mega-jax" | "mega-numpy" | "fleet"
    gpu_hours_usd: float = 0.0
    energy_usd: float = 0.0
    preemptions: int = 0
    requests: int = 0
    # wall seconds this point's simulation took (informational, never
    # compared): 0.0 for batched tier variants, which re-price their
    # group's simulation instead of running one; mega-sweep primaries
    # carry an equal share of the batch wall-clock
    eval_s: float = 0.0

    def objectives(self) -> Tuple[float, float, float, float]:
        return (self.cost_usd, self.energy_wh, self.carbon_kg, self.p99_s)

    def label(self) -> str:
        pre = (f" pre={self.preemption_rate:g}/dev-day"
               if self.preemption_rate else "")
        return f"{self.fleet} [{self.router}, {self.price_tier}{pre}]"


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """Minimization dominance: a is no worse everywhere, better
    somewhere."""
    return (all(x <= y for x, y in zip(a, b))
            and any(x < y for x, y in zip(a, b)))


def pareto_front(points: Sequence[PlanPoint]) -> List[PlanPoint]:
    """The mutually non-dominated subset, sorted by cost then the other
    objectives (deterministic presentation order).  Exact-duplicate
    objective vectors keep only their first point (a plan tied on every
    objective adds no frontier information)."""
    out: List[PlanPoint] = []
    seen = set()
    for p in points:
        obj = p.objectives()
        if obj in seen:
            continue
        if any(dominates(q.objectives(), obj) for q in points):
            continue
        seen.add(obj)
        out.append(p)
    return sorted(out, key=lambda p: p.objectives())


def _slice_hv(pts: List[Tuple[float, ...]]) -> float:
    """Exact hypervolume of the region of [0, 1]^d dominated by ``pts``
    (minimization; the reference corner is (1, ..., 1)).  Recursive
    slicing on the first objective: sweep its sorted values, and weight
    each slab's width by the (d-1)-dimensional volume the points alive
    in that slab dominate."""
    if not pts:
        return 0.0
    d = len(pts[0])
    if d == 1:
        return 1.0 - min(p[0] for p in pts)
    pts = sorted(pts)
    vol = 0.0
    for i, p in enumerate(pts):
        x1 = pts[i + 1][0] if i + 1 < len(pts) else 1.0
        width = x1 - p[0]
        if width > 0.0:
            vol += width * _slice_hv([q[1:] for q in pts[:i + 1]])
    return vol


def hypervolume(points: Sequence[PlanPoint],
                reference: Sequence[float]) -> float:
    """Normalized 4-objective hypervolume of ``points`` against a
    reference objective vector (e.g. the all-on-demand plan's).

    Each objective is divided by its reference value (a zero reference
    component, e.g. a p99 of exactly 0 s, cannot be beaten: values at
    or under it map to 0, everything else clips to 1) and clipped to
    [0, 1], so the result is the fraction of the unit box between the
    frontier and the reference that the frontier dominates -- 0 when
    nothing beats the reference anywhere, approaching 1 as plans push
    all four corners toward zero.
    """
    norm: List[Tuple[float, ...]] = []
    for p in points:
        q = []
        for o, r in zip(p.objectives(), reference):
            if r > 0.0:
                q.append(min(max(o / r, 0.0), 1.0))
            else:
                q.append(0.0 if o <= r else 1.0)
        norm.append(tuple(q))
    return _slice_hv(norm)


@dataclasses.dataclass
class PlanResult:
    """A finished sweep: every evaluated plan, its non-dominated
    frontier, the all-on-demand reference plan, and the frontier's
    normalized hypervolume against it."""
    points: List[PlanPoint]
    frontier: List[PlanPoint]
    reference: Optional[PlanPoint]
    hypervolume: float
    # execution provenance: {"mode", "wall_s", "sims", "points",
    # "compiles"} -- sims counts actual simulations run (batched mode
    # shares one sim across a group's tier variants) and compiles is
    # the jit-cache growth the sweep paid (jaxback bulk programs)
    stats: Dict[str, object] = dataclasses.field(default_factory=dict)

    def best(self, objective: str) -> PlanPoint:
        """The frontier's corner point for one objective (ties broken
        by the full objective tuple, so the answer is deterministic).
        Single-objective optima of the sweep are always on the frontier
        -- nothing can dominate a point that is minimal somewhere."""
        if objective not in OBJECTIVES:
            raise KeyError(f"unknown objective {objective!r}; have "
                           f"{OBJECTIVES}")
        return min(self.frontier,
                   key=lambda p: (getattr(p, objective), p.objectives()))

    def to_json(self) -> str:
        """The frontier (plus reference and hypervolume) as a JSON
        document -- what the nightly CI lane uploads as an artifact."""
        return json.dumps({
            "objectives": list(OBJECTIVES),
            "hypervolume_vs_on_demand": self.hypervolume,
            "reference": (dataclasses.asdict(self.reference)
                          if self.reference else None),
            "frontier": [dataclasses.asdict(p) for p in self.frontier],
            "n_evaluated": len(self.points),
            "stats": dict(self.stats),
        }, indent=2)


def _scenario_for(base: FleetScenario, fleet: str, router: str,
                  tier: str, rate: float, axes: PlanAxes) -> FleetScenario:
    """One grid point's scenario: the base workload re-fleeted.  Models
    keep their traces; prewarm homes re-assign round-robin over the new
    device list (the same assignment rule as ``mixed_fleet_scenario``,
    so the base scenario itself is reproduced exactly when its own
    coordinates come up)."""
    devices = build_fleet(fleet)
    models = []
    for i, fm in enumerate(base.models):
        home = (devices[i % len(devices)].instance_id
                if fm.spec.home is not None else None)
        models.append(FleetModel(dataclasses.replace(fm.spec, home=home),
                                 fm.arrivals_s))
    pre = None
    if rate > 0.0:
        pre = PreemptionModel(rate_per_device_day=rate,
                              warning_s=axes.preemption_warning_s,
                              outage_s=axes.preemption_outage_s,
                              seed=axes.preemption_seed)
    return dataclasses.replace(base, devices=devices, models=models,
                               router=router, price_tier=tier,
                               preemptions=pre)


def _evaluate(sc: FleetScenario, backend: str) -> Tuple[object, str]:
    """Run one plan through the cheapest capable engine: the compiled
    mega backend when the plan fits its scope, the event loop when it
    does not (stateful routing, service time, consolidation,
    autoscaling, or actual preemption faults)."""
    from repro.fleet.mega.megasim import MegaUnsupportedError, run_mega
    try:
        return (run_mega(sc, compute_bound=False, backend=backend),
                f"mega-{backend}")
    except MegaUnsupportedError:
        return run_fleet(sc), "fleet"


def _has_spot(sc: FleetScenario) -> bool:
    return "spot" in sc.device_tiers().values()


def _grid(base: FleetScenario, axes: PlanAxes
          ) -> List[Tuple[str, str, str, float, FleetScenario]]:
    """The sweep grid in canonical (serial) order, with construction
    hoisted: each fleet's device list and re-homed models are built
    ONCE and shared by every (router, tier, rate) variant -- so all
    variants replay the IDENTICAL arrival arrays (keeping the mega
    backends' biggap caches, keyed by array identity, hot across the
    whole sweep) -- and each nonzero rate shares one PreemptionModel
    (its draw is pure).  Plans with no spot-tier device skip nonzero
    rates (the draw would be empty; the plan is the rate-0 plan)."""
    parts: Dict[str, Tuple[list, list]] = {}
    pres: Dict[float, PreemptionModel] = {}
    out: List[Tuple[str, str, str, float, FleetScenario]] = []
    for fleet in axes.fleets:
        if fleet not in parts:
            devices = build_fleet(fleet)
            models = []
            for i, fm in enumerate(base.models):
                home = (devices[i % len(devices)].instance_id
                        if fm.spec.home is not None else None)
                models.append(FleetModel(
                    dataclasses.replace(fm.spec, home=home),
                    fm.arrivals_s))
            parts[fleet] = (devices, models)
        devices, models = parts[fleet]
        for router in axes.routers:
            for tier in axes.price_tiers:
                for rate in axes.preemption_rates:
                    pre = None
                    if rate > 0.0:
                        pre = pres.get(rate)
                        if pre is None:
                            pre = pres[rate] = PreemptionModel(
                                rate_per_device_day=rate,
                                warning_s=axes.preemption_warning_s,
                                outage_s=axes.preemption_outage_s,
                                seed=axes.preemption_seed)
                    sc = dataclasses.replace(
                        base, devices=devices, models=models,
                        router=router, price_tier=tier, preemptions=pre)
                    if rate > 0.0 and not _has_spot(sc):
                        continue        # no revocable device: same plan
                    out.append((fleet, router, tier, rate, sc))
    return out


def _point(res, engine: str, fleet: str, router: str, tier: str,
           rate: float, eval_s: float, *,
           cost=None) -> PlanPoint:
    """A PlanPoint from a finished run; ``cost`` re-prices a tier
    variant from the group simulation's reports (CostBreakdown)."""
    return PlanPoint(
        fleet=fleet, router=router, price_tier=tier,
        preemption_rate=rate,
        cost_usd=cost.cost_usd if cost is not None else res.cost_usd,
        energy_wh=res.energy_wh,
        carbon_kg=res.carbon_kg, p99_s=res.p99_added_latency_s,
        engine=engine,
        gpu_hours_usd=(cost.gpu_hours_usd if cost is not None
                       else res.gpu_hours_usd),
        energy_usd=res.energy_usd, preemptions=res.preemptions,
        requests=res.requests, eval_s=eval_s)


def _serial_points(grid, backend: str) -> Tuple[List[PlanPoint], int]:
    points = []
    for fleet, router, tier, rate, sc in grid:
        t0 = time.perf_counter()
        res, engine = _evaluate(sc, backend)
        points.append(_point(res, engine, fleet, router, tier, rate,
                             time.perf_counter() - t0))
    return points, len(points)


def _batched_points(grid, backend: str,
                    max_workers: Optional[int]
                    ) -> Tuple[List[PlanPoint], int]:
    """One simulation per structural group, replayed hot.

    Group key ``(fleet, router, rate, spot-device-set)``: members
    differ only in the default purchase tier, which never steers the
    dynamics -- it re-prices the metered timeline, and the preemption
    draw sees the tier map only through which devices resolve to spot
    (pinned in the key).  The group primary (first member in grid
    order) simulates -- mega-scope primaries in one
    ``run_mega_sweep(on_unsupported="skip")`` batch sharing every
    compiled program, the rest concurrently on a thread pool running
    ``run_fleet(compute_bound=False, detail=False)`` -- and each tier
    variant re-prices the primary's device reports, bit-identical to
    its own run.  Engine attribution per point matches the serial
    dispatch because scope eligibility is group-uniform.
    """
    from repro.fleet.mega import megasim
    groups: Dict[tuple, List[int]] = {}
    for i, (fleet, router, tier, rate, sc) in enumerate(grid):
        spotset = (frozenset(d for d, t in sc.device_tiers().items()
                             if t == "spot") if rate > 0.0 else None)
        groups.setdefault((fleet, router, rate, spotset), []).append(i)
    primaries = [g[0] for g in groups.values()]

    # phase 1: every primary attempts the mega engine (the guards are
    # cheap); unsupported points come back as None
    results: Dict[int, Tuple[object, str, float]] = {}
    t0 = time.perf_counter()
    if backend == "jax":
        from repro.fleet.mega import jaxback
        sweep = jaxback.run_mega_sweep(
            scenarios=[grid[i][4] for i in primaries],
            compute_bound=False, on_unsupported="skip")
    else:
        sweep = []
        for i in primaries:
            try:
                sweep.append(megasim.run_mega(grid[i][4],
                                              compute_bound=False,
                                              backend=backend))
            except megasim.MegaUnsupportedError:
                sweep.append(None)
    mega_wall = time.perf_counter() - t0
    n_mega = sum(1 for r in sweep if r is not None)
    share = mega_wall / n_mega if n_mega else 0.0
    for i, r in zip(primaries, sweep):
        if r is not None:
            results[i] = (r, f"mega-{backend}", share)

    # phase 2: event-loop groups on the worker pool.  Each submission
    # gets a PRIVATE router instance (get_router returns shared
    # stateless singletons; run_fleet re-binds the carbon trace on
    # them, which concurrent runs must not race on).
    ev_idx = [i for i, r in zip(primaries, sweep) if r is None]
    if ev_idx:
        def run_ev(i):
            _f, _r, _t, _rt, sc = grid[i]
            if isinstance(sc.router, str):
                sc = dataclasses.replace(
                    sc, router=copy.copy(get_router(sc.router)))
            t1 = time.perf_counter()
            res = run_fleet(sc, compute_bound=False, detail=False)
            return res, "fleet", time.perf_counter() - t1

        workers = max_workers or min(8, os.cpu_count() or 1)
        with concurrent.futures.ThreadPoolExecutor(
                max_workers=workers) as ex:
            for i, out in zip(ev_idx, ex.map(run_ev, ev_idx)):
                results[i] = out

    # assemble in grid order; tier variants re-price the group run
    points: List[Optional[PlanPoint]] = [None] * len(grid)
    for idxs in groups.values():
        res, engine, eval_s = results[idxs[0]]
        for j in idxs:
            fleet, router, tier, rate, sc = grid[j]
            if j == idxs[0]:
                points[j] = _point(res, engine, fleet, router, tier,
                                   rate, eval_s)
            else:
                cost = price_fleet(sc.devices, res.devices,
                                   default_tier=tier,
                                   energy_usd=res.energy_usd)
                points[j] = _point(res, engine, fleet, router, tier,
                                   rate, 0.0, cost=cost)
    return points, len(primaries)


def _compile_count() -> int:
    try:
        from repro.fleet.mega import jaxback
        return jaxback.compiled_program_count()
    except Exception:
        return 0


def plan_fleet(base_scenario: FleetScenario, axes: PlanAxes, *,
               backend: str = "jax", batched: bool = True,
               max_workers: Optional[int] = None) -> PlanResult:
    """Sweep every plan on the grid and reduce to the 4-objective
    frontier.

    ``base_scenario`` supplies the workload (models, traces, horizon,
    zone, carbon trace); each grid point re-fleets it.  ``backend``
    picks the mega bulk-scan engine for plans inside mega scope.
    ``batched`` selects grouped shared-compile execution (see the
    module docstring; the frontier is point-for-point identical to
    ``batched=False``, property-tested); ``max_workers`` caps the
    event-loop worker pool.

    The reference plan for the hypervolume is the sweep's all-on-demand
    singleton: the first fleet x first router at the ``on_demand``
    default tier with no preemption -- evaluated even when those
    coordinates are not on the grid, so the reported volume always has
    the same meaning.  Plans with no spot-tier device skip nonzero
    preemption rates (the draw would be empty; the plan is the rate-0
    plan, and evaluating it again would only duplicate points).
    """
    c0 = _compile_count()
    t_start = time.perf_counter()
    grid = _grid(base_scenario, axes)
    if batched:
        points, sims = _batched_points(grid, backend, max_workers)
    else:
        points, sims = _serial_points(grid, backend)
    reference: Optional[PlanPoint] = None
    for p in points:
        if (p.price_tier == "on_demand" and p.preemption_rate == 0.0
                and p.fleet == axes.fleets[0]
                and p.router == axes.routers[0]
                and ":" not in p.fleet):
            reference = p
            break
    if reference is None:
        # the grid skipped the all-on-demand corner: evaluate it anyway
        # so the hypervolume keeps its fixed meaning (strip per-part
        # tier pins from the first fleet spec)
        bare = "+".join(part.split(":")[0]
                        for part in axes.fleets[0].split("+"))
        sc = _scenario_for(base_scenario, bare, axes.routers[0],
                           "on_demand", 0.0, axes)
        t0 = time.perf_counter()
        res, engine = _evaluate(sc, backend)
        reference = _point(res, engine, bare, axes.routers[0],
                           "on_demand", 0.0,
                           time.perf_counter() - t0)
        sims += 1
    frontier = pareto_front(points)
    hv = hypervolume(frontier, reference.objectives())
    stats = {"mode": "batched" if batched else "serial",
             "wall_s": time.perf_counter() - t_start,
             "sims": sims, "points": len(points),
             "compiles": _compile_count() - c0}
    return PlanResult(points=points, frontier=frontier,
                      reference=reference, hypervolume=hv, stats=stats)


# ---------------------------------------------------------------------------
# The pinned sweep (acceptance anchor, bench family, example).
# ---------------------------------------------------------------------------

def pinned_day_base(*, horizon_s: float = DAY,
                    seed: int = 100) -> FleetScenario:
    """The 3-zone seed-100 day (10 models, diurnal zone traces) as the
    planner's base workload -- the same scenario shape the zone anchors
    pin, with the zone-preset carbon trace so carbon is a live axis."""
    from repro.core.scheduler import Breakeven
    return mixed_fleet_scenario(Breakeven, "warm-first", fleet=ZONES3_FLEET,
                                seed=seed, horizon_s=horizon_s,
                                carbon_trace="zone")


def pinned_day_axes(*, routers: Tuple[str, ...] = ("warm-first",
                                                   "slo-aware"),
                    preemption_rate: float = 2.0) -> PlanAxes:
    """The canonical sweep grid over the pinned day: three fleet/tier
    mixes (all on-demand, spot H100s, all spot) x routers x default
    tiers x {no faults, ``preemption_rate``/device-day with 4 h
    outages}.  With the default two routers this is a 20-plan sweep
    whose frontier holds >=3 mutually non-dominated plans (pinned in
    tests/test_pricing.py)."""
    return PlanAxes(fleets=(ZONES3_FLEET, SPOT_H100_FLEET, SPOT_ALL_FLEET),
                    routers=routers,
                    price_tiers=("on_demand", "reserved"),
                    preemption_rates=(0.0, preemption_rate))
