"""Replica auto-scaling: scale hot routes out, retire over-provisioned
replicas when their parking tax exceeds the reload they would save.

The paper's breakeven model says the energy-optimal treatment of a
parked model is set by its arrival rate and loading latency, not its
size (Eqs. 12-13).  Lifted to the replica-set level the same ski rental
answers BOTH autoscaling questions:

  * scale OUT when a route's live demand -- busy decode slots plus
    queued requests, from the fleet event loop's published occupancy --
    presses against the warm capacity of its replica set, AND the
    per-replica arrival gap after scaling stays inside the target
    device's breakeven window (a replica that would immediately sit
    past T* would just re-evict: loading it is pure waste).  Placement
    picks the cheapest feasible device by ``catalog.scaleout_cost_j``:
    above-bare load energy + marginal parking power (zero on a device
    whose context is already up) held for the expected demand window.

  * scale IN when the idlest replica's parking tax outruns its reload:
    its observed per-replica arrival gap (``Cluster.rep_rates``) exceeds
    the breakeven window implied by its marginal parking power, and the
    remaining replicas can absorb the route's live load with slack.  A
    replica whose device hosts other live contexts parks at ZERO
    marginal watts and is never retired for energy reasons -- capacity
    pressure (``make_room``) handles VRAM, not the autoscaler.

The controller runs inside the fleetsim event loop as periodic
``autoscale`` ticks (like the Consolidator): ``plan`` returns actions,
the event loop applies them through the device loader channels -- so a
scale-out load serializes behind in-flight loads and overlaps decode
exactly like any other load, and every joule it costs is metered.

Safety invariants (property-tested in tests/test_fleet_properties.py):
``max_replicas=1`` plans nothing, a single-device fleet plans nothing
(the 1-device x 1-model equivalence anchor to core/simulator.py
survives with the autoscaler enabled), and scale-in never drops a
route's last replica, a pinned replica, or one with work in flight.
Retired replicas leave their devices to the Consolidator's packing
pass, which can then drain the freed context windows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Union

from repro.core.power_states import PowerState
from repro.fleet.carbon import CarbonTrace, _J_PER_KWH
from repro.fleet.catalog import (above_base_load_j, marginal_park_w,
                                 scaleout_cost_j, wake_cost_j,
                                 wake_cost_kg)
from repro.fleet.cluster import Cluster


@dataclasses.dataclass(frozen=True)
class ScaleOut:
    """Plan action: load one more warm replica of ``model_id`` on
    device ``dst`` (applied through the device's loader channel)."""
    model_id: str
    dst: str


@dataclasses.dataclass(frozen=True)
class ScaleIn:
    """Plan action: retire the warm replica of ``model_id`` on device
    ``src`` (applied via ``Cluster.scale_in``, which re-checks safety)."""
    model_id: str
    src: str


Action = Union[ScaleOut, ScaleIn]


class ReplicaAutoscaler:
    """Periodic scale-out/in controller over per-route replica sets.

    Knobs:
      tick_s        controller period (seconds of sim time).
      max_replicas  hard cap per route; 1 disables the controller
                    entirely (trace-identical to no autoscaler).
      pressure_hi   scale out when live demand (busy slots + waiters)
                    reaches this fraction of the set's decode capacity.
      pressure_lo   scale in only when demand fits under this fraction
                    of the SHRUNK set's capacity (hysteresis band).
      margin        both breakeven tests require benefit >= margin *
                    cost; >1 biases toward fewer scale events.
      cooldown_s    per-route minimum gap between actions (damps
                    oscillation on bursty traffic).
      patience_s    scale-in additionally waits for at least this much
                    replica idle time.  The raw breakeven hold is tens
                    of seconds for derived loaders, which would retire a
                    held replica the moment a burst ends and put the
                    NEXT burst back on a cold start -- patience keeps
                    the latency half of the trade from thrashing.
      carbon_aware  price the breakeven tests in kgCO2e against the
                    run's grid-intensity trace (bound by ``run_fleet``
                    via ``set_carbon_trace``) instead of joules: the
                    breakeven hold SHRINKS when the coming window is
                    dirtier than the daily mean (standing warmth is
                    carbon-expensive now; retire sooner, reload in a
                    cleaner hour) and STRETCHES through clean windows;
                    scale-out placement prices its load burst at the
                    current intensity, so prewarm-style capacity buys
                    drift into low-intensity windows.  Flat traces
                    reproduce the energy decisions exactly.
    """

    def __init__(self, *, tick_s: float = 60.0, max_replicas: int = 3,
                 pressure_hi: float = 0.5, pressure_lo: float = 0.25,
                 margin: float = 1.0, cooldown_s: float = 300.0,
                 patience_s: float = 1800.0, carbon_aware: bool = False):
        if tick_s <= 0:
            raise ValueError("tick period must be positive")
        if max_replicas < 1:
            raise ValueError("need at least one replica per route")
        if not 0.0 < pressure_lo <= pressure_hi:
            raise ValueError("need 0 < pressure_lo <= pressure_hi")
        self.tick_s = tick_s
        self.max_replicas = max_replicas
        self.pressure_hi = pressure_hi
        self.pressure_lo = pressure_lo
        self.margin = margin
        self.cooldown_s = cooldown_s
        self.patience_s = patience_s
        self.carbon_aware = carbon_aware
        self.carbon_trace: Optional[CarbonTrace] = None
        self._last_action: Dict[str, float] = {}
        self.scale_outs = 0
        self.scale_ins = 0

    def reset(self) -> None:
        """Clear per-run state (cooldowns, action counters); run_fleet
        calls this so one controller instance can drive many runs."""
        self._last_action.clear()
        self.scale_outs = 0
        self.scale_ins = 0

    def set_carbon_trace(self, trace: CarbonTrace) -> None:
        """Bind the run's intensity trace (called by ``run_fleet``);
        only consulted when ``carbon_aware`` is set."""
        self.carbon_trace = trace

    def _trace(self) -> Optional[CarbonTrace]:
        """The active trace, or None when carbon pricing is off (not
        carbon_aware, no trace bound, or a flat trace -- all three are
        energy-identical, so one code path serves them)."""
        t = self.carbon_trace if self.carbon_aware else None
        return None if (t is None or t.is_flat) else t

    # -- per-route signals --------------------------------------------------
    @staticmethod
    def route_demand(cluster: Cluster, model_id: str) -> int:
        """Live demand: busy decode slots + queued requests, fleet-wide
        (waiters can sit on a device whose replica is still loading)."""
        return sum(cluster.busy_slots(did, model_id)
                   + cluster.waiting_requests(did, model_id)
                   for did in cluster.devices)

    @staticmethod
    def _replica_idle_s(cluster: Cluster, device_id: str, model_id: str,
                        now_s: float) -> float:
        """How idle this replica is: the larger of its EWMA inter-arrival
        gap and the time since its LAST arrival.  The elapsed term
        matters -- the EWMA only updates on arrivals, so a replica whose
        traffic stopped would otherwise keep its burst-time (small) gap
        forever and never look idle.  inf when never routed a request
        (the prime scale-in victim)."""
        est = cluster.rep_rates.get((device_id, model_id))
        if est is None or est.last_arrival is None:
            return math.inf
        elapsed = max(now_s - est.last_arrival, 0.0)
        if est.gap_s is None:
            return elapsed
        return max(est.expected_gap_s(), elapsed)

    def _breakeven_hold_s(self, cluster: Cluster, device_id: str,
                          model_id: str, now_s: float = 0.0) -> float:
        """Replica-level T*: how long this replica may park before its
        marginal tax buys a reload.  Infinite at zero marginal watts.

        Uses the paper's Eq.-12 convention (FULL loading power), like
        the default Breakeven eviction policy: the derived per-arch
        loaders spend most of their window near bare idle, so the
        energy-exact convention would price reloads at almost nothing
        and never let a replica stand.

        Carbon mode reprices the same ski rental in kgCO2e with a
        first-order intensity correction: parking over the coming
        window is weighed at the window's mean intensity, the eventual
        reload at the daily mean (its phase is unknown), so

            hold_c = hold * i_daily / i(now .. now+hold)

        -- shorter through dirty hours, longer through clean ones.

        Args:
          now_s: current sim time (anchors the carbon window; unused
                 in energy mode).
        Returns: hold in seconds (may be ``inf``)."""
        dev = cluster.devices[device_id]
        others_on = any(
            (m.resident or m.loading) and m.model_id != model_id
            for m in cluster.managers[device_id].models.values())
        park_w = marginal_park_w(dev, others_on)
        if park_w <= 0.0:
            return math.inf
        hold = cluster.loader_for(model_id, device_id).load_energy_j / park_w
        trace = self._trace()
        if trace is not None:
            window = trace.mean(now_s, now_s + hold)
            if window > 0.0:
                hold *= trace.daily_mean_kg_per_kwh / window
        return hold

    # -- planning -----------------------------------------------------------
    def plan(self, cluster: Cluster, now_s: float) -> List[Action]:
        """One controller pass; pure decision (the event loop applies,
        and counts only the actions that actually land).

        A single-device fleet can never scale (the replica set IS the
        device), and max_replicas=1 disables the controller outright --
        both keep the single-simulator equivalence anchor exact.
        Scale-outs emitted in the SAME pass reserve their slot/VRAM in a
        ledger, so two hot routes cannot both claim the last fit on one
        device before either load is applied.
        """
        if self.max_replicas <= 1 or len(cluster.devices) <= 1:
            return []
        actions: List[Action] = []
        reserved: Dict[str, List[float]] = {}    # dst -> [slots, vram_gb]
        for mid in sorted(cluster.specs):
            last = self._last_action.get(mid)
            if last is not None and now_s - last < self.cooldown_s:
                continue
            act = self._plan_route(cluster, mid, now_s, reserved)
            if act is not None:
                actions.append(act)
                self._last_action[mid] = now_s
                if isinstance(act, ScaleOut):
                    r = reserved.setdefault(act.dst, [0, 0.0])
                    r[0] += 1
                    r[1] += cluster.specs[mid].vram_gb
        return actions

    def _plan_route(self, cluster: Cluster, mid: str, now_s: float,
                    reserved: Dict[str, List[float]]) -> Optional[Action]:
        resident = cluster.locations(mid, include_loading=False)
        pending = cluster.pending_scaleouts(mid)
        members = sorted(set(resident) | set(pending))
        n = len(members)
        if n == 0:
            return None           # cold route: first load is routing's job
        capacity = sum(cluster.decode_slots(d) for d in members)
        demand = self.route_demand(cluster, mid)

        if (n < self.max_replicas and capacity > 0
                and demand >= self.pressure_hi * capacity):
            waiting = sum(cluster.waiting_requests(d, mid)
                          for d in cluster.devices)
            return self._plan_scale_out(cluster, mid, members, n, now_s,
                                        reserved,
                                        forced=waiting >= capacity)

        if n > 1 and not pending and resident:
            return self._plan_scale_in(cluster, mid, resident, demand,
                                       now_s)
        return None

    @staticmethod
    def _fits_reserving(cluster: Cluster, device_id: str, model_id: str,
                        reserved: Dict[str, List[float]]) -> bool:
        """fits() plus what same-pass actions reserved AND what earlier
        ticks left queued on the loader channel (queued-not-started
        loads are invisible to occupancy, but will claim their VRAM when
        they pump -- ignoring them would overcommit the device and
        make_room would then cannibalize a freshly landed replica)."""
        slots, vram = reserved.get(device_id, (0, 0.0))
        q_slots, q_vram = cluster.queued_load_demand(device_id)
        return (cluster.free_slots(device_id) - slots - q_slots >= 1
                and cluster.free_vram_gb(device_id) - vram - q_vram
                >= cluster.specs[model_id].vram_gb)

    def _plan_scale_out(self, cluster: Cluster, mid: str, members: List[str],
                        n: int, now_s: float,
                        reserved: Dict[str, List[float]], *,
                        forced: bool = False) -> Optional[ScaleOut]:
        """Demand said scale; pick WHERE by expected joules.

        Per candidate the Eq.-13 worthwhile test asks whether the new
        replica's traffic share (expected gap x grown set size) would
        re-arrive inside the device's breakeven hold -- a replica that
        would park past T* is pure tax, so it is only bought when the
        route is FORCED (queued demand exceeds a full batch round: the
        SLO is already paying in seconds, so we pay in joules instead).
        Cost per candidate: above-bare load energy + marginal parking
        power over the expected demand window (capped at the breakeven
        hold, the most a standing replica can owe before scale-in
        retires it); loader-channel backlog breaks ties so the new
        capacity lands soonest."""
        gap = cluster.rates[mid].expected_gap_s()
        cands = [d for d in sorted(cluster.devices)
                 if d not in members
                 and d not in cluster.revoked   # spot warning/outage
                 and self._fits_reserving(cluster, d, mid, reserved)]
        best, best_key = None, None
        trace = self._trace()
        for d in cands:
            dev = cluster.devices[d]
            ld = cluster.loader_for(mid, d)
            hold = self._breakeven_hold_s(cluster, d, mid, now_s)
            if not forced and gap * (n + 1) > self.margin * hold:
                continue
            window = min(gap * (n + 1), hold)
            ctx_on = cluster.context_on(d)
            # a gated candidate pays its wake on top: ramp energy above
            # sleep + the bare-minus-sleep delta over the demand window
            # (in carbon mode, at the current window's intensity)
            wake_j = wake_cost_j(dev, window) \
                if cluster.power_state(d) is PowerState.SLEEP else 0.0
            if trace is None:
                cost = scaleout_cost_j(dev, ld, window, context_on=ctx_on) \
                    + wake_j
            else:
                # kgCO2e analogue of scaleout_cost_j: the load burst at
                # the CURRENT intensity (this is what drags prewarm-style
                # capacity buys into clean windows), the marginal parking
                # over the expected demand window
                t_warm = now_s + ld.t_load_s
                load_kg = above_base_load_j(dev, ld) \
                    * trace.mean(now_s, t_warm) / _J_PER_KWH
                park_kg = marginal_park_w(dev, ctx_on) \
                    * trace.integral(t_warm, t_warm + max(window, 0.0)) \
                    / _J_PER_KWH
                wake_kg = wake_cost_kg(dev, trace, now_s, t_warm,
                                       window) if wake_j > 0.0 else 0.0
                cost = load_kg + park_kg + wake_kg
            lag_s = cluster.load_backlog_s(d, now_s) \
                + (dev.profile.wake_latency_s if wake_j > 0.0 else 0.0)
            key = (cost, lag_s, d)
            if best_key is None or key < best_key:
                best, best_key = d, key
        return ScaleOut(mid, best) if best is not None else None

    def _plan_scale_in(self, cluster: Cluster, mid: str,
                       resident: List[str], demand: int, now_s: float
                       ) -> Optional[ScaleIn]:
        # victims: safe to retire now, idlest first
        victims = [
            d for d in resident
            if cluster.busy_slots(d, mid) == 0
            and cluster.waiting_requests(d, mid) == 0
            and cluster.managers[d].models[mid].pins == 0]
        victims.sort(key=lambda d: (-self._replica_idle_s(cluster, d, mid,
                                                          now_s), d))
        for d in victims:
            shrunk_cap = sum(cluster.decode_slots(x) for x in resident
                             if x != d)
            if demand > self.pressure_lo * shrunk_cap:
                return None       # remaining set would run hot
            idle = self._replica_idle_s(cluster, d, mid, now_s)
            bar = max(self.margin * self._breakeven_hold_s(cluster, d, mid,
                                                           now_s),
                      self.patience_s)
            if idle >= bar:
                return ScaleIn(mid, d)
            # this one still earns its keep at ITS device's breakeven
            # hold; a less idle replica on a cheaper-loading device may
            # not -- keep looking
        return None
