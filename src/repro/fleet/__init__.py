"""Fleet orchestration: cluster-scale parking-tax simulation, placement,
routing, replica autoscaling, and carbon-intensity-aware scheduling
across heterogeneous GPUs (see DESIGN in each module; docs/ARCHITECTURE.md
maps the layers)."""
from repro.fleet.autoscaler import (ReplicaAutoscaler, ScaleIn, ScaleOut)
from repro.fleet.carbon import (CarbonBreakeven, CarbonTrace, TRACE_SHAPES,
                                carbon_timeline_kg, carbon_timeline_multi_kg,
                                flat_trace, make_trace, resolve_zone_trace,
                                solar_duck, trace_for_zone, wind_night)
from repro.fleet.catalog import (CATALOG, MIXES, PRICE_TIERS, DeviceInstance,
                                 ElectricityMix, GPUSku, above_base_load_j,
                                 build_fleet, carbon_kg, energy_cost_usd,
                                 fleet_price_usd, get_mix, get_sku,
                                 marginal_park_w, normalize_tier,
                                 scaleout_cost_j, transfer_cost_j,
                                 transfer_latency_s, wake_cost_j, zone_hops)
from repro.fleet.cluster import (Cluster, FleetModelSpec, RateEstimator)
from repro.fleet.router import (BreakevenRouter, CarbonAwareRouter,
                                Consolidator, EnergyGreedyRouter,
                                LeastLoadedRouter, Move, ROUTERS, Router,
                                SLOAwareRouter, WarmFirstRouter, get_router)
from repro.fleet.fleetsim import (DeviceReport, FleetModel, FleetResult,
                                  FleetScenario, clairvoyant_bound,
                                  mixed_fleet_scenario, run_fleet,
                                  single_device_scenario, zone_decomposition)
from repro.fleet.mega import (FleetTrace, GENERATORS, MegaUnsupportedError,
                              RouteTrace, flash_crowd, product_launch,
                              regional_outage, run_mega, trace_from_records)
from repro.fleet.planner import (OBJECTIVES, PlanAxes, PlanPoint, PlanResult,
                                 dominates, hypervolume, pareto_front,
                                 plan_fleet)
from repro.fleet.pricing import (UNBILLED_STATES, CostBreakdown,
                                 PreemptionModel, Revocation, billed_seconds,
                                 device_gpu_usd, device_tier_map, price_fleet)

__all__ = [
    "CATALOG", "MIXES", "DeviceInstance", "ElectricityMix", "GPUSku",
    "build_fleet", "carbon_kg", "energy_cost_usd", "fleet_price_usd",
    "get_mix", "get_sku", "above_base_load_j", "marginal_park_w",
    "scaleout_cost_j", "transfer_cost_j", "transfer_latency_s",
    "wake_cost_j", "zone_hops",
    "CarbonBreakeven", "CarbonTrace", "TRACE_SHAPES", "carbon_timeline_kg",
    "carbon_timeline_multi_kg", "flat_trace", "make_trace",
    "resolve_zone_trace", "solar_duck", "trace_for_zone", "wind_night",
    "ReplicaAutoscaler", "ScaleOut", "ScaleIn",
    "Cluster", "FleetModelSpec", "RateEstimator",
    "Router", "ROUTERS", "WarmFirstRouter", "LeastLoadedRouter",
    "EnergyGreedyRouter", "BreakevenRouter", "SLOAwareRouter",
    "CarbonAwareRouter", "Consolidator", "Move", "get_router",
    "FleetModel", "FleetScenario", "FleetResult", "DeviceReport",
    "run_fleet", "single_device_scenario", "mixed_fleet_scenario",
    "clairvoyant_bound", "zone_decomposition",
    "MegaUnsupportedError", "run_mega", "run_mega_sweep", "GENERATORS",
    "FleetTrace", "RouteTrace", "flash_crowd", "product_launch",
    "regional_outage", "trace_from_records",
    "PRICE_TIERS", "normalize_tier", "UNBILLED_STATES", "CostBreakdown",
    "PreemptionModel", "Revocation", "billed_seconds", "device_gpu_usd",
    "device_tier_map", "price_fleet",
    "OBJECTIVES", "PlanAxes", "PlanPoint", "PlanResult", "dominates",
    "hypervolume", "pareto_front", "plan_fleet",
]


def __getattr__(name):
    # jax-backed sweep entry point, resolved lazily so the fleet package
    # (and run_mega's numpy path) stays importable without jax
    if name == "run_mega_sweep":
        from repro.fleet.mega import jaxback
        return jaxback.run_mega_sweep
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
