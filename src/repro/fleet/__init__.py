"""Fleet orchestration: cluster-scale parking-tax simulation, placement,
and routing across heterogeneous GPUs (see DESIGN in each module)."""
from repro.fleet.autoscaler import (ReplicaAutoscaler, ScaleIn, ScaleOut)
from repro.fleet.catalog import (CATALOG, MIXES, DeviceInstance,
                                 ElectricityMix, GPUSku, above_base_load_j,
                                 build_fleet, carbon_kg, energy_cost_usd,
                                 fleet_price_usd, get_mix, get_sku,
                                 marginal_park_w, scaleout_cost_j)
from repro.fleet.cluster import (Cluster, FleetModelSpec, RateEstimator)
from repro.fleet.router import (BreakevenRouter, Consolidator,
                                EnergyGreedyRouter, LeastLoadedRouter,
                                Move, ROUTERS, Router, SLOAwareRouter,
                                WarmFirstRouter, get_router)
from repro.fleet.fleetsim import (DeviceReport, FleetModel, FleetResult,
                                  FleetScenario, clairvoyant_bound,
                                  mixed_fleet_scenario, run_fleet,
                                  single_device_scenario)

__all__ = [
    "CATALOG", "MIXES", "DeviceInstance", "ElectricityMix", "GPUSku",
    "build_fleet", "carbon_kg", "energy_cost_usd", "fleet_price_usd",
    "get_mix", "get_sku", "above_base_load_j", "marginal_park_w",
    "scaleout_cost_j",
    "ReplicaAutoscaler", "ScaleOut", "ScaleIn",
    "Cluster", "FleetModelSpec", "RateEstimator",
    "Router", "ROUTERS", "WarmFirstRouter", "LeastLoadedRouter",
    "EnergyGreedyRouter", "BreakevenRouter", "SLOAwareRouter",
    "Consolidator", "Move", "get_router",
    "FleetModel", "FleetScenario", "FleetResult", "DeviceReport",
    "run_fleet", "single_device_scenario", "mixed_fleet_scenario",
    "clairvoyant_bound",
]
