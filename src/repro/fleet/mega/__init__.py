"""Vectorized mega-fleet simulation + production trace replay.

`megasim.run_mega` is an array-program re-expression of
`fleet.fleetsim.run_fleet` for the warm-first / no-controller scope,
anchored against the event loop on the pinned 10-model x 6-GPU day and
fast enough for 500+-device multi-million-request days.  `traces`
supplies the telemetry-shaped ingestion schema (`FleetTrace`) and the
synthetic production-day generators that feed it.  See docs/SCALE.md.
"""
from repro.fleet.mega.megasim import MegaUnsupportedError, run_mega
from repro.fleet.mega.traces import (
    GENERATORS,
    FleetTrace,
    RouteTrace,
    flash_crowd,
    product_launch,
    regional_outage,
    trace_from_records,
)

__all__ = [
    "MegaUnsupportedError",
    "run_mega",
    "run_mega_sweep",
    "sweep_traces",
    "GENERATORS",
    "FleetTrace",
    "RouteTrace",
    "flash_crowd",
    "product_launch",
    "regional_outage",
    "trace_from_records",
]

_LAZY = {"run_mega_sweep", "sweep_traces"}


def __getattr__(name):
    # the sweep entry points live in jaxback, which imports jax -- keep
    # the package importable (and run_mega's numpy path usable) without
    # it by resolving these lazily (PEP 562)
    if name in _LAZY:
        from repro.fleet.mega import jaxback
        return getattr(jaxback, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
