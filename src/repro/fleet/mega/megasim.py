"""Vectorized mega-fleet simulator: the event loop's exact dynamics in
array-program form, for 500-5000-device multi-million-request days.

``fleetsim.run_fleet`` walks one heap event per request; at mega scale
(millions of arrivals) the per-event Python overhead dominates.
``run_mega`` keeps a heap, but only for STRUCTURAL events -- load
completions and armed idle-timeout evictions -- and retires the
per-request work in bulk:

  * Device state lives in numpy vectors (occupied slots, VRAM, power
    state, watts) so least-loaded placement is one masked ``lexsort``
    instead of a min() over Python objects.
  * A model whose stream is in the common steady state -- exactly one
    warm replica, no load in flight or queued -- enters a WARM RUN: the
    maximal prefix of its remaining arrivals whose inter-arrival gaps
    are all <= the replica's idle timeout T is claimed in O(log n) via a
    precomputed big-gap index (``np.flatnonzero(np.diff(arr) > T)``),
    one eviction event is armed at ``arr[last] + T``, and the requests
    are committed lazily when the run ends.  Interruptions (capacity
    evictions from another model's load) commit the served prefix by
    ``searchsorted`` -- never by iterating requests.
  * A load in flight with no other replica absorbs every arrival before
    its completion straight into the wait queue (one slice), exactly the
    event loop's route-to-loading-device behaviour.
  * Energy is integrated per device as (state-interval dt) x (watts)
    only at actual power CHANGES, which is precisely what the event
    loop's ``EnergyMeter`` coalesces its timeline down to -- so the
    metered power segments come out float-identical and per-state Wh
    agrees to float-summation order.

Correctness spine (the repo's equivalence-anchor discipline,
docs/ARCHITECTURE.md): on the pinned 10-model x 6-GPU seed-100 day,
``run_mega`` reproduces ``run_fleet``'s request count and cold starts
EXACTLY and total/per-state Wh to float-summation precision (pinned in
``tests/test_mega.py`` far inside the issue's 1e-3 relative budget).

Scope: the fast path covers the paper's evaluation convention --
warm-first routing, zero service time, no consolidator/autoscaler, and
constant-timeout eviction policies (AlwaysOn / FixedTTL / Breakeven /
CarbonBreakeven on a flat trace...).  Anything else raises
``MegaUnsupportedError`` so callers fall back to ``run_fleet`` instead
of silently diverging; the probe is behavioural (timeout sampled at
several instants, arrival hook checked for statefulness), not a class
allowlist.
"""
from __future__ import annotations

import heapq
import itertools
import math
import time
import weakref
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.coldstart import loader_from_checkpoint
from repro.core.power_states import PowerState, state_power_w
from repro.core.scheduler import Policy
from repro.fleet.carbon import carbon_timeline_kg, carbon_timeline_multi_kg
from repro.fleet.catalog import (carbon_kg, energy_cost_usd,
                                 fleet_price_usd, get_mix)
from repro.fleet.cluster import _make_policy
from repro.fleet.fleetsim import (DeviceReport, FleetResult, FleetScenario,
                                  clairvoyant_bound, zone_decomposition)
from repro.fleet.pricing import (device_tier_map, price_fleet,
                                 tier_billed_seconds)
from repro.fleet.router import WarmFirstRouter
from repro.serving.service_model import ConstantServiceTime

# compact power-state codes for the three states a non-gated zero-service
# run can occupy; indices double as wire names via _STATE_KEYS
_BARE, _PARKED, _LOADING = 0, 1, 2
_STATE_KEYS = (PowerState.BARE.value, PowerState.CTX_IDLE.value,
               PowerState.LOADING.value)

# heap phases at equal timestamps, matching run_fleet's ordering
# (completions < arrivals) plus evictions AFTER everything -- the event
# loop's advance_to fires a deadline strictly BEFORE the next event's
# time, so a deadline equal to an event time must lose to that event
_P_DONE, _P_ARR, _P_EVICT = 0, 3, 4

_PROBE_TIMES = (0.0, 12345.678, 67801.25)


class MegaUnsupportedError(ValueError):
    """The scenario needs dynamics outside run_mega's vectorized scope
    (stateful policies, service time, consolidation, autoscaling, or a
    non-warm-first router).  Fall back to ``fleetsim.run_fleet``."""


def _probe_constant_timeout(policy) -> float:
    """Behavioural check that a policy is a constant idle timeout.

    Samples ``idle_timeout_s`` at several instants, and -- when the
    policy overrides the base no-op ``observe_arrival`` (duck-typed
    policies like CarbonBreakeven define their own) -- feeds it probe
    arrivals and re-samples, so stateful estimators (AdaptiveBreakeven)
    and time-varying stopping rules (CarbonBreakeven on a shaped trace)
    are rejected rather than mis-simulated."""
    try:
        ts = [policy.idle_timeout_s(t) for t in _PROBE_TIMES]
    except Exception as exc:
        raise MegaUnsupportedError(
            f"policy {getattr(policy, 'name', policy)!r} needs per-gap "
            f"context ({exc}); run_mega supports constant timeouts only"
        ) from exc
    base_hook = getattr(type(policy), "observe_arrival", None) \
        is Policy.observe_arrival
    if not base_hook:
        policy.observe_arrival(_PROBE_TIMES[0])
        policy.observe_arrival(_PROBE_TIMES[1])
        if [policy.idle_timeout_s(t) for t in _PROBE_TIMES] != ts:
            raise MegaUnsupportedError(
                f"policy {getattr(policy, 'name', policy)!r} adapts to "
                f"arrivals; run_mega supports constant timeouts only")
    if any(t != ts[0] for t in ts):
        raise MegaUnsupportedError(
            f"policy {getattr(policy, 'name', policy)!r} varies its "
            f"timeout over the day; run_mega supports constant timeouts")
    if not (ts[0] == math.inf or ts[0] > 0.0):
        raise MegaUnsupportedError(
            f"policy {getattr(policy, 'name', policy)!r} returned "
            f"non-positive timeout {ts[0]!r}")
    return float(ts[0])


class _Rep:
    """One (device, model) replica: the ManagedModel fields the mega
    dynamics need."""
    __slots__ = ("resident", "loading", "evict_at", "gen", "vram", "pos")

    def __init__(self, vram: float, pos: int):
        self.resident = False
        self.loading = False
        self.evict_at = math.inf
        self.gen = 0            # bumped on every (re)arm/evict: stale
        self.vram = vram        # eviction events carry the gen they saw
        self.pos = pos          # registration index on its device

    def __repr__(self):  # pragma: no cover - debug aid
        return (f"_Rep(res={self.resident}, load={self.loading}, "
                f"evict_at={self.evict_at:g})")


class _BigGapCache:
    """Bounded LRU of derived per-stream arrays, shared across
    ``run_mega`` calls on the same ``FleetTrace``.

    Keyed by ``(id(arrivals), horizon)`` of the raw ``arrivals_s``
    object each ``FleetModel`` carries: a ``FleetTrace`` hands every
    ``to_scenario`` the SAME per-route arrays, so repeat runs (sweeps)
    hit.  An entry holds the sorted/horizon-filtered arrival array plus
    the stream's ``T -> big-gap index`` dict, so neither is rebuilt per
    run; a weakref to the source guards against ``id()`` reuse after
    gc.  Sources that cannot be weakly referenced (plain lists) are
    derived fresh each run -- the pre-cache behaviour.
    """

    def __init__(self, maxsize: int = 256, max_timeouts: int = 16):
        if maxsize < 1 or max_timeouts < 1:
            raise ValueError("cache bounds must be positive")
        self.maxsize = maxsize             # streams kept (LRU evicted)
        self.max_timeouts = max_timeouts   # per-stream biggap dict cap
        self.hits = 0
        self.misses = 0
        self._d: Dict[Tuple[int, float], tuple] = {}   # insertion = LRU

    def __len__(self) -> int:
        return len(self._d)

    def clear(self) -> None:
        self._d.clear()
        self.hits = self.misses = 0

    def stream_arrays(self, source, horizon: float
                      ) -> Tuple[np.ndarray, Dict[float, np.ndarray]]:
        """The (derived arrival array, shared biggap dict) for a raw
        ``arrivals_s`` object at a horizon, cached."""
        key = (id(source), float(horizon))
        ent = self._d.get(key)
        if ent is not None and ent[0]() is source:
            self.hits += 1
            self._d.pop(key)               # LRU bump
            self._d[key] = ent
            return ent[1], ent[2]
        self.misses += 1
        arr = np.sort(np.asarray(source, dtype=np.float64))
        arr = arr[(arr >= 0.0) & (arr < horizon)]
        biggap: Dict[float, np.ndarray] = {}
        try:
            ref = weakref.ref(source)
        except TypeError:
            return arr, biggap             # not weakly referenceable
        if ent is not None:
            self._d.pop(key, None)         # stale entry from id() reuse
        while len(self._d) >= self.maxsize:
            self._d.pop(next(iter(self._d)))
        self._d[key] = (ref, arr, biggap)
        return arr, biggap


biggap_cache = _BigGapCache()


class _Stream:
    """One model's arrival stream + replica-set bookkeeping."""
    __slots__ = ("mid", "arr", "n", "ptr", "ev", "res", "loading", "queued",
                 "waiters", "run_active", "run_dev", "run_last", "run_E0",
                 "suspended", "biggap")

    def __init__(self, mid: str, arr: np.ndarray,
                 biggap: Optional[Dict[float, np.ndarray]] = None):
        self.mid = mid
        self.arr = arr                   # sorted, within [0, horizon)
        self.n = int(arr.size)
        self.ptr = 0                     # next unconsumed arrival index
        self.ev = 0                      # arrival-event version (staleness)
        self.res: set = set()            # device indices with warm replica
        self.loading: set = set()        # device indices mid-load
        self.queued: set = set()         # queued-not-started loads
        self.waiters: Dict[int, list] = {}
        self.run_active = False
        self.run_dev = -1
        self.run_last = -1
        self.run_E0 = math.inf
        self.suspended = False           # arrivals pre-absorbed into a load
        # T -> big-gap indices; shared through biggap_cache so repeat
        # runs on the same FleetTrace reuse the scans
        self.biggap: Dict[float, np.ndarray] = \
            {} if biggap is None else biggap

    def biggaps(self, T: float) -> np.ndarray:
        """Indices i with arr[i+1] - arr[i] > T (a warm run starting at
        or before i ends at i).  Cached per distinct timeout (timeouts
        differ per SKU, not per device, so this stays tiny), bounded at
        ``biggap_cache.max_timeouts`` oldest-out."""
        got = self.biggap.get(T)
        if got is None:
            if math.isinf(T):
                got = np.empty(0, dtype=np.int64)
            else:
                got = np.flatnonzero(np.diff(self.arr) > T)
            if len(self.biggap) >= biggap_cache.max_timeouts:
                self.biggap.pop(next(iter(self.biggap)))
            self.biggap[T] = got
        return got


class _Fin:
    """What a bulk backend hands back at finalize time."""
    __slots__ = ("energy_j", "dur_s", "waits", "carbon_dev",
                 "carbon_timeline", "timings", "tier_billed_s")

    def __init__(self, energy_j, dur_s, waits, carbon_dev, carbon_timeline,
                 timings, tier_billed_s=None):
        self.energy_j = energy_j           # [N][3] joules per state
        self.dur_s = dur_s                 # [N][3] seconds per state
        self.waits = waits                 # per-request waits, any order
        self.carbon_dev = carbon_dev       # [N] kgCO2e
        self.carbon_timeline = carbon_timeline
        self.timings = timings             # phase -> wall seconds
        # tier -> billed seconds when the backend fused it into the
        # metering pass; None -> run_mega re-derives it from reports
        self.tier_billed_s = tier_billed_s


class _NumpyBulk:
    """The reference bulk backend: the exact inline numpy/Python paths
    the simulator shipped with (the bit-exact anchor vs ``run_fleet``),
    instrumented with per-phase wall-clock so the compiled backend's
    bulk-scan speedup is measured like-for-like.

    The seam: the event loop owns all STRUCTURAL state (heap, replica
    sets, pointers) and calls the backend for every bulk operation --
    energy charging, waiter billing, big-gap run claiming, and the
    finalize pass (carbon integration, waits assembly).  Both backends
    see identical calls in identical order, so every control-flow
    decision (routing tie-breaks, run extents) is backend-invariant by
    construction; only the arithmetic engine differs.
    """

    name = "numpy"
    wants_tables = False

    def __init__(self, n_dev: int):
        self.energy_j = [[0.0, 0.0, 0.0] for _ in range(n_dev)]
        self.dur_s = [[0.0, 0.0, 0.0] for _ in range(n_dev)]
        self.waits: List[float] = []
        self.t = {"biggap_s": 0.0, "billing_s": 0.0, "energy_s": 0.0,
                  "carbon_s": 0.0}

    def prepare(self, streams, stream_Ts) -> None:
        pass

    def charge(self, d: int, s: int, dt: float, p: float,
               a: float = 0.0, b: float = 0.0) -> None:
        # a/b (the absolute interval) only feed the jax backend's fused
        # metering pass; the numpy buckets need just dt
        self.energy_j[d][s] += dt * p
        self.dur_s[d][s] += dt

    def last_of_run(self, ms: _Stream, T: float) -> int:
        t0 = time.perf_counter()
        big = ms.biggaps(T)
        j = int(np.searchsorted(big, ms.ptr))
        last = int(big[j]) if j < big.size else ms.n - 1
        self.t["biggap_s"] += time.perf_counter() - t0
        return last

    def absorb(self, ms: _Stream, d: int, lo: int, hi: int,
               t_done: float) -> None:
        t0 = time.perf_counter()
        ms.waiters.setdefault(d, []).extend(ms.arr[lo:hi].tolist())
        self.t["billing_s"] += time.perf_counter() - t0

    def wait_one(self, ms: _Stream, d: int, t: float) -> None:
        ms.waiters.setdefault(d, []).append(t)

    def waiter_count(self, ms: _Stream, d: int) -> int:
        return len(ms.waiters.get(d, ()))

    def drain(self, ms: _Stream, d: int, t: float) -> int:
        w = ms.waiters.pop(d, None)
        if not w:
            return 0
        t0 = time.perf_counter()
        self.waits.extend(t - a for a in w)
        self.t["billing_s"] += time.perf_counter() - t0
        return len(w)

    def finalize(self, segs, fleet_segments, trace, horizon: float,
                 dev_traces=None, tiers=None) -> _Fin:
        t0 = time.perf_counter()
        waits = np.asarray(self.waits, dtype=np.float64)
        self.t["billing_s"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        if dev_traces is not None and any(tr is not trace
                                          for tr in dev_traces):
            # multi-zone fleet: each device integrates against its own
            # zone's trace; the fleet timeline folds the per-device
            # segments in the exact order fleet_segments concatenates
            carbon_dev = [tr.carbon_for_segments(s)
                          for tr, s in zip(dev_traces, segs)]
            timeline = carbon_timeline_multi_kg(
                [(tr, sg) for tr, s in zip(dev_traces, segs) for sg in s],
                end_s=horizon)
        else:
            carbon_dev = [trace.carbon_for_segments(s) for s in segs]
            timeline = carbon_timeline_kg(trace, fleet_segments,
                                          end_s=horizon)
        self.t["carbon_s"] += time.perf_counter() - t0
        self.t["bulk_scan_s"] = sum(self.t.values())
        return _Fin(self.energy_j, self.dur_s, waits, carbon_dev, timeline,
                    dict(self.t))


def run_mega(scenario: FleetScenario, *,
             compute_bound: bool = True,
             backend: str = "numpy") -> FleetResult:
    """Vectorized replacement for ``run_fleet`` on its supported scope
    (see module docstring); raises ``MegaUnsupportedError`` otherwise.

    ``compute_bound=False`` skips the O(requests) clairvoyant-bound pass
    (reported as 0.0) -- the bound is a per-gap Python loop and would
    dominate wall-clock on multi-million-request days.

    ``backend`` selects the bulk-scan engine: ``"numpy"`` (default) is
    the bit-exact anchor vs ``run_fleet``; ``"jax"`` retires the bulk
    phases -- big-gap scans, deferred waiter billing, per-state energy
    segment-sums, and the carbon trapezoid integral -- as jit-compiled
    array programs (``fleet/mega/jaxback.py``, docs/SCALE.md).  Both
    backends drive the identical structural event loop, so request
    counts and cold starts are equal and float totals agree to <=1e-9
    relative (pinned in tests).  ``FleetResult.phase_timings`` reports
    per-phase wall seconds for either backend.
    """
    sc = scenario
    if backend == "numpy":
        _Bulk = _NumpyBulk
    elif backend == "jax":
        try:
            from repro.fleet.mega import jaxback
        except ImportError as exc:
            raise RuntimeError(
                "run_mega(backend='jax') needs jax, which is not "
                "importable in this environment; install jax or use "
                "backend='numpy'") from exc
        _Bulk = jaxback._JaxBulk
    else:
        raise ValueError(
            f"unknown backend {backend!r}: expected 'numpy' or 'jax'")
    # ---- scope guard ------------------------------------------------------
    if not (sc.router == "warm-first"
            or isinstance(sc.router, WarmFirstRouter)):
        raise MegaUnsupportedError(
            f"run_mega supports warm-first routing only, got {sc.router!r}")
    if sc.consolidator is not None:
        raise MegaUnsupportedError("run_mega does not support consolidation")
    if sc.autoscaler is not None:
        raise MegaUnsupportedError("run_mega does not support autoscaling")
    svc = sc.resolved_service_model()
    if not (isinstance(svc, ConstantServiceTime) and svc.service_s == 0.0):
        raise MegaUnsupportedError(
            "run_mega supports the zero-service-time convention only "
            f"(got {getattr(svc, 'name', svc)!r})")
    if sc.preemptions is not None and sc.preemptions.draw(
            sc.devices, sc.device_tiers(), sc.horizon_s):
        # guard on the DRAW, not the model: an all-on-demand plan under
        # a preemption model has no revocable devices and replays
        # exactly -- only actual fault events exceed the mega scope
        raise MegaUnsupportedError(
            "run_mega does not support spot preemption faults; "
            "fall back to run_fleet")
    if not sc.devices:
        raise ValueError("empty fleet")

    trace = sc.resolved_carbon_trace()
    horizon = float(sc.horizon_s)
    # per-device zone bindings (tentpole): accounting-only at mega scope
    # -- policies keep the SCENARIO trace (so the per-(model, SKU)
    # loader/timeout cache stays valid) and warm-first routing is
    # zone-blind, but every device's joules integrate against its own
    # zone's intensity.  Single-zone fleets bind the same trace object
    # everywhere, keeping the bit-exact anchor vs run_fleet.
    zones = sc.device_zones()
    dev_traces_by_id = sc.device_carbon_traces(trace)
    multi_zone = len(set(zones.values())) > 1

    # ---- device vectors (index = rank in sorted(instance_id), so integer
    # comparisons reproduce every instance-id string tie-break) ------------
    by_id = {d.instance_id: d for d in sc.devices}
    if len(by_id) != len(sc.devices):
        raise ValueError("duplicate instance_id in fleet")
    dids = sorted(by_id)
    devs = [by_id[i] for i in dids]
    N = len(devs)
    vcap = np.array([d.sku.vram_gb for d in devs], dtype=np.float64)
    scap = np.array([d.sku.slots for d in devs], dtype=np.int64)
    occ = np.zeros(N, dtype=np.int64)
    vused = np.zeros(N, dtype=np.float64)
    p_bare = [state_power_w(d.profile, PowerState.BARE) for d in devs]
    p_park = [state_power_w(d.profile, PowerState.CTX_IDLE) for d in devs]
    state = [_BARE] * N
    watts = [p_bare[d] for d in range(N)]
    since = [0.0] * N
    bulk = _Bulk(N)
    touched = [[False, False, False] for _ in range(N)]
    key_order: List[List[int]] = [[] for _ in range(N)]
    segs: List[List[Tuple[float, float, float]]] = [[] for _ in range(N)]
    res_count = [0] * N
    d_cold = [0] * N
    d_reqs = [0] * N
    dev_models: List[List[str]] = [[] for _ in range(N)]   # registration order
    act: List[set] = [set() for _ in range(N)]   # currently resident|loading

    def _touch(d: int, s: int) -> None:
        if not touched[d][s]:
            touched[d][s] = True
            key_order[d].append(s)

    def _trans(d: int, t: float, ns: int, w: float) -> None:
        """Charge the open interval into the current state's bucket and
        enter (ns, w) -- the EnergyMeter transition, minus the dt=0
        flushes the event loop performs (which change no joules and
        coalesce away in its timeline)."""
        s = state[d]
        t0 = since[d]
        dt = t - t0
        p = watts[d]
        bulk.charge(d, s, dt, p, t0, t)
        _touch(d, s)
        if dt > 0.0:
            sg = segs[d]
            if sg and sg[-1][1] == t0 and sg[-1][2] == p:
                sg[-1] = (sg[-1][0], t, p)
            else:
                sg.append((t0, t, p))
        state[d] = ns
        watts[d] = w
        since[d] = t

    def recompute_vused(d: int) -> None:
        """Fresh registration-order sum, so capacity comparisons see the
        exact float the event loop's ``vram_used_gb`` computes (an
        incremental add/subtract could drift in the last bits and flip a
        boundary ``fits`` decision).  Walks only the currently-contributing
        replicas (``act``), sorted back into registration order -- NOT all
        models ever registered on the device, which grows toward M over a
        long day and made this O(M * events)."""
        s = 0.0
        for m in sorted(act[d], key=lambda m: reps[(d, m)].pos):
            s += reps[(d, m)].vram
        vused[d] = s

    # ---- per-(model, SKU) constants: loader + probed constant timeout ----
    specs = {}
    for fm in sc.models:
        if fm.spec.model_id in specs:
            raise MegaUnsupportedError(
                f"duplicate model_id {fm.spec.model_id!r}: run_fleet would "
                f"merge their specs; run_mega refuses")
        specs[fm.spec.model_id] = fm.spec
    sku_of = [d.sku.key for d in devs]
    _per_sku: Dict[Tuple[str, str], Tuple[object, float]] = {}

    def _loader_T(mid: str, d: int):
        key = (mid, sku_of[d])
        got = _per_sku.get(key)
        if got is None:
            spec = specs[mid]
            if spec.loader is not None:
                loader = spec.loader
            else:
                loader = loader_from_checkpoint(
                    mid, spec.checkpoint_bytes, devs[d].profile)
            policy = _make_policy(spec.policy_factory, loader,
                                  devs[d].profile, trace)
            got = (loader, _probe_constant_timeout(policy))
            _per_sku[key] = got
        return got

    # ---- streams, replicas, heap -----------------------------------------
    streams: Dict[str, _Stream] = {}
    for fm in sc.models:
        a, shared_biggap = biggap_cache.stream_arrays(fm.arrivals_s,
                                                      horizon)
        streams[fm.spec.model_id] = _Stream(fm.spec.model_id, a,
                                            shared_biggap)
    if bulk.wants_tables:
        # candidate constant timeouts per stream: one probe per (model,
        # SKU present).  A probe failure is skipped, NOT raised -- the
        # numpy path probes lazily on first routing, so scope rejection
        # must surface at the same instant on either backend.
        rep_dev: Dict[str, int] = {}
        for i, k in enumerate(sku_of):
            rep_dev.setdefault(k, i)
        stream_Ts: Dict[str, List[float]] = {}
        for mid in streams:
            Ts: List[float] = []
            for d0 in rep_dev.values():
                try:
                    T = _loader_T(mid, d0)[1]
                except MegaUnsupportedError:
                    continue
                if T not in Ts:
                    Ts.append(T)
            stream_Ts[mid] = Ts
        bulk.prepare(streams, stream_Ts)

    reps: Dict[Tuple[int, str], _Rep] = {}

    def get_rep(d: int, mid: str) -> _Rep:
        rep = reps.get((d, mid))
        if rep is None:
            rep = _Rep(specs[mid].vram_gb, len(dev_models[d]))
            reps[(d, mid)] = rep
            dev_models[d].append(mid)
        return rep

    heap: list = []
    seq = itertools.count()
    n_live = 0                  # pending arrival + load_done heap entries
    n_zero = 0                  # warm-served requests (zero added latency)
    replica_log: Dict[str, List[Tuple[float, int]]] = {}
    inflight: List[Optional[str]] = [None] * N     # loader channel
    dq = [deque() for _ in range(N)]               # queued loads (FIFO)
    dq_set: List[set] = [set() for _ in range(N)]

    def push(t: float, phase: int, payload: tuple) -> None:
        heapq.heappush(heap, (t, phase, next(seq), payload))

    def push_arr(ms: _Stream) -> None:
        nonlocal n_live
        ms.ev += 1              # at most ONE valid arrival event per stream
        push(float(ms.arr[ms.ptr]), _P_ARR, (ms.mid, ms.ptr, ms.ev))
        n_live += 1

    def log_replicas(ms: _Stream, t: float) -> None:
        log = replica_log[ms.mid]
        n = len(ms.res)
        if not log or log[-1][1] != n:
            log.append((t, n))

    def arm(d: int, mid: str, t: float) -> None:
        rep = reps[(d, mid)]
        rep.gen += 1
        T = _loader_T(mid, d)[1]
        if math.isinf(T):
            rep.evict_at = math.inf
        else:
            rep.evict_at = t + T
            push(rep.evict_at, _P_EVICT, (d, mid, rep.gen))

    def cur_evict_at(d: int, mid: str, t: float) -> float:
        """The deadline the event loop would see at instant t -- for a
        replica mid-run, that is the last run arrival before t plus its
        timeout (each warm hit re-arms), reconstructed lazily."""
        ms = streams[mid]
        if ms.run_active and ms.run_dev == d:
            k = int(np.searchsorted(ms.arr, t, "left"))
            k = min(k, ms.run_last + 1)
            if k <= ms.ptr:
                return ms.run_E0
            return float(ms.arr[k - 1]) + _loader_T(mid, d)[1]
        return reps[(d, mid)].evict_at

    def evict_replica(d: int, mid: str, t: float) -> None:
        """Unload now (idle timeout fired, or make_room pressure).  A
        replica mid-run first commits its served prefix (arrivals
        strictly before t were warm hits)."""
        nonlocal n_zero
        rep = reps[(d, mid)]
        ms = streams[mid]
        if ms.run_active and ms.run_dev == d:
            k = int(np.searchsorted(ms.arr, t, "left"))
            k = min(max(k, ms.ptr), ms.run_last + 1)
            served = k - ms.ptr
            d_reqs[d] += served
            n_zero += served
            ms.ptr = k
            ms.run_active = False
        rep.resident = False
        rep.evict_at = math.inf
        rep.gen += 1
        act[d].discard(mid)
        ms.res.discard(d)
        occ[d] -= 1
        res_count[d] -= 1
        recompute_vused(d)
        log_replicas(ms, t)
        if res_count[d] == 0 and state[d] == _PARKED:
            _trans(d, t, _BARE, p_bare[d])
        if ms.ptr < ms.n and not ms.suspended:
            push_arr(ms)        # stream continues cold (or on other replicas)

    def make_room(d: int, mid_new: str, t: float) -> None:
        need = specs[mid_new].vram_gb

        def over() -> bool:
            return (vused[d] + need > vcap[d] or occ[d] + 1 > scap[d])

        if not over():
            return
        # the event loop scans its models dict (registration order) and
        # stable-sorts by deadline -- reproduce that from the small active
        # set: registration order first, then a stable deadline sort
        victims = sorted((m for m in act[d]
                          if m != mid_new and reps[(d, m)].resident),
                         key=lambda m: reps[(d, m)].pos)
        victims.sort(key=lambda m: cur_evict_at(d, m, t))
        for m in victims:
            if not over():
                break
            evict_replica(d, m, t)

    def least_loaded(mid: str) -> int:
        # lexicographic argmin of (occ, -free_vram, index) without a full
        # sort: staged boolean masks, O(N) per call on the cold-route path
        need = specs[mid].vram_gb
        free_v = vcap - vused
        cand = np.flatnonzero((scap - occ >= 1) & (free_v >= need))
        if cand.size == 0:
            cand = np.arange(N)
        o = occ[cand]
        cand = cand[o == o.min()]
        f = free_v[cand]
        return int(cand[f == f.max()][0])

    def start_load(d: int, ms: _Stream, t: float) -> None:
        nonlocal n_live
        rep = get_rep(d, ms.mid)
        make_room(d, ms.mid, t)
        rep.loading = True
        act[d].add(ms.mid)
        ms.loading.add(d)
        occ[d] += 1
        recompute_vused(d)
        loader = _loader_T(ms.mid, d)[0]
        _trans(d, t, _LOADING, loader.p_load_w)
        t_done = t + loader.t_load_s
        push(t_done, _P_DONE, (d, ms.mid))
        n_live += 1
        # the only replica coming up: every arrival before t_done routes
        # warm-first to this loading replica and waits -- absorb them in
        # one slice instead of one heap event each
        if (not ms.res and ms.loading == {d} and not ms.queued
                and ms.ptr < ms.n):
            k = int(np.searchsorted(ms.arr, t_done, "left"))
            if k > ms.ptr:
                bulk.absorb(ms, d, ms.ptr, k, t_done)
                ms.ptr = k
            ms.suspended = True

    def pump(d: int, t: float) -> None:
        """Start the next queued load if the serialized channel is free
        (run_fleet's pump_loader, minus migrations/wakes)."""
        if inflight[d] is not None:
            return
        q = dq[d]
        while q:
            mid = q.popleft()
            dq_set[d].discard(mid)
            ms = streams[mid]
            ms.queued.discard(d)
            rep = reps.get((d, mid))
            if rep is not None and (rep.resident or rep.loading):
                continue        # a racing load landed it meanwhile
            inflight[d] = mid
            start_load(d, ms, t)
            return

    def continue_stream(ms: _Stream) -> None:
        """Re-plan a stream after its replica set settled: enter a bulk
        warm run when the steady single-replica state holds, otherwise
        fall back to one heap event for the next arrival."""
        ms.suspended = False
        if ms.ptr >= ms.n:
            return
        if len(ms.res) == 1 and not ms.loading and not ms.queued:
            d = next(iter(ms.res))
            rep = reps[(d, ms.mid)]
            if float(ms.arr[ms.ptr]) > rep.evict_at:
                return          # idle gap: the armed eviction restarts us
            T = _loader_T(ms.mid, d)[1]
            last = bulk.last_of_run(ms, T)
            ms.run_active = True
            ms.run_dev = d
            ms.run_last = last
            ms.run_E0 = rep.evict_at
            arm(d, ms.mid, float(ms.arr[last]))
        else:
            push_arr(ms)

    def drain_waiters(d: int, ms: _Stream, t: float) -> None:
        d_reqs[d] += bulk.drain(ms, d, t)

    def on_load_done(t: float, d: int, mid: str) -> None:
        inflight[d] = None
        ms = streams[mid]
        rep = reps[(d, mid)]
        rep.loading = False
        rep.resident = True
        ms.loading.discard(d)
        ms.res.add(d)
        res_count[d] += 1
        recompute_vused(d)
        d_cold[d] += 1
        _trans(d, t, _PARKED, p_park[d])
        if ms.run_active:       # defensive: a run elsewhere cannot coexist
            nonlocal n_zero     # with a load in mega scope, but commit it
            k = int(np.searchsorted(ms.arr, t, "left"))
            k = min(max(k, ms.ptr), ms.run_last + 1)
            d_reqs[ms.run_dev] += k - ms.ptr
            n_zero += k - ms.ptr
            ms.ptr = k
            ms.run_active = False
        arm(d, mid, t)
        drain_waiters(d, ms, t)
        log_replicas(ms, t)
        pump(d, t)
        continue_stream(ms)

    def on_arrival(t: float, mid: str, idx: int, ev: int) -> None:
        nonlocal n_zero
        ms = streams[mid]
        if ev != ms.ev or idx != ms.ptr:
            return              # superseded by an absorb / run / re-push
        ms.ptr += 1
        locs = ms.res | ms.loading
        if locs:
            # warm-first: least-pressure warm replica; a mid-load replica
            # counts as a full pool so residency wins ties
            d = min(locs, key=lambda x: (bulk.waiter_count(ms, x),
                                         0 if x in ms.res else 1, x))
            if d in ms.res:
                d_reqs[d] += 1
                n_zero += 1
                if state[d] == _LOADING:
                    # run_fleet's settle-then-recompose flush creates the
                    # parked bucket (0 Wh) on a device serving a warm hit
                    # mid-another-model's-load; mirror the touched keys
                    _touch(d, _LOADING)
                    _touch(d, _PARKED)
                arm(d, mid, t)
                continue_stream(ms)
            else:
                bulk.wait_one(ms, d, t)
                if ms.ptr < ms.n and not ms.suspended:
                    push_arr(ms)
            return
        # cold: least-loaded placement, queue the load on that device's
        # serialized channel (dedup while queued or in flight)
        d = least_loaded(mid)
        rep = get_rep(d, mid)
        bulk.wait_one(ms, d, t)
        if not rep.loading and mid not in dq_set[d]:
            dq_set[d].add(mid)
            dq[d].append(mid)
            ms.queued.add(d)
            pump(d, t)
        if ms.ptr < ms.n and not ms.suspended:
            push_arr(ms)

    # ---- prewarm (run_fleet's Table-6 warm-start convention) --------------
    idx_of = {did: i for i, did in enumerate(dids)}
    for fm in sc.models:
        mid = fm.spec.model_id
        replica_log.setdefault(mid, [])
        if fm.spec.home is None:
            continue
        d = idx_of[fm.spec.home]
        need = fm.spec.vram_gb
        if not (scap[d] - occ[d] >= 1 and vcap[d] - vused[d] >= need):
            fitting = np.flatnonzero((scap - occ >= 1)
                                     & (vcap - vused >= need))
            if fitting.size == 0:
                continue        # starts cold
            free_v = vcap[fitting] - vused[fitting]
            order = np.lexsort((fitting, -free_v, occ[fitting]))
            d = int(fitting[order[0]])
        rep = get_rep(d, mid)
        rep.resident = True
        act[d].add(mid)
        occ[d] += 1
        res_count[d] += 1
        recompute_vused(d)
        d_cold[d] += 1
        streams[mid].res.add(d)
        _trans(d, 0.0, _PARKED, p_park[d])
        arm(d, mid, 0.0)
    for fm in sc.models:        # timeline origin, including zero-replica
        ms = streams[fm.spec.model_id]
        replica_log[ms.mid].append((0.0, len(ms.res)))
    for fm in sc.models:        # kick every stream
        ms = streams[fm.spec.model_id]
        if ms.n == 0:
            continue
        if ms.res:
            continue_stream(ms)
        else:
            push_arr(ms)

    # ---- main loop: structural events only --------------------------------
    last_done_t = 0.0
    deferred: List[Tuple[float, int, str, int]] = []
    while heap:
        t, phase, _s, payload = heapq.heappop(heap)
        if phase == _P_EVICT:
            d, mid, gen = payload
            rep = reps.get((d, mid))
            if rep is None or not rep.resident or rep.gen != gen:
                continue
            if t < horizon or n_live > 0:
                # some later event (all remaining real events are strictly
                # later) or the final advance-to-horizon will cross this
                # deadline, so the event loop fires it at exactly t
                evict_replica(d, mid, t)
            else:
                # past the horizon with nothing left in flight: fires only
                # if the final clock (a load may overshoot) passes it
                deferred.append((t, d, mid, gen))
            continue
        n_live -= 1
        if phase == _P_ARR:
            mid, idx, ev = payload
            on_arrival(t, mid, idx, ev)
        else:
            d, mid = payload
            last_done_t = max(last_done_t, t)
            on_load_done(t, d, mid)

    # arrivals all land before the horizon; only a load can overshoot it
    final_clock = max(horizon, last_done_t)
    for t, d, mid, gen in deferred:
        rep = reps.get((d, mid))
        if (rep is not None and rep.resident and rep.gen == gen
                and t < final_clock):
            evict_replica(d, mid, t)

    # commit runs still warm at the end (their eviction deadline lies at
    # or beyond the final clock, so every claimed arrival was served)
    for ms in streams.values():
        if ms.run_active:
            served = ms.run_last + 1 - ms.ptr
            d_reqs[ms.run_dev] += served
            n_zero += served
            ms.ptr = ms.run_last + 1
            ms.run_active = False
        if ms.ptr != ms.n or ms.waiters:
            raise RuntimeError(
                f"mega invariant violated: stream {ms.mid!r} left "
                f"{ms.n - ms.ptr} arrivals unserved")
    for d in range(N):
        _trans(d, final_clock, state[d], watts[d])   # totals() flush

    # ---- bulk finalize: billing, energy buckets, carbon integration ------
    fleet_segments: List[Tuple[float, float, float]] = []
    for d in range(N):
        fleet_segments.extend(segs[d])
    dev_trace_list = [dev_traces_by_id[did] for did in dids]
    tiers_map = device_tier_map(sc.devices, sc.price_tier)
    fin = bulk.finalize(segs, fleet_segments, trace, horizon,
                        dev_trace_list,
                        tiers=[tiers_map[did] for did in dids])
    energy_j = fin.energy_j
    dur_s = fin.dur_s

    # ---- reports (same construction as run_fleet) -------------------------
    reports = []
    for d in range(N):
        e_wh = {_STATE_KEYS[s]: energy_j[d][s] / 3600.0
                for s in key_order[d]}
        e_wh["total"] = sum(e_wh.values())
        durations = {_STATE_KEYS[s]: dur_s[d][s] for s in key_order[d]}
        reports.append(DeviceReport(
            instance_id=dids[d], sku=devs[d].sku.key,
            energy_wh=e_wh,
            parking_tax_wh=(dur_s[d][_PARKED]
                            * devs[d].profile.dvfs_step_w / 3600.0),
            cold_starts=d_cold[d], requests=d_reqs[d],
            resident=[m for m in dev_models[d] if reps[(d, m)].resident],
            meter_state=_STATE_KEYS[state[d]],
            carbon_kg=fin.carbon_dev[d],
            zone=zones[dids[d]],
            durations_s=durations))

    if compute_bound:
        lb_nongated, cv_sum = clairvoyant_bound(sc)
    else:
        lb_nongated = cv_sum = 0.0
    energy = sum(r.total_wh for r in reports)
    mix = get_mix(sc.zone)
    state_wh: Dict[str, float] = {}
    state_s: Dict[str, float] = {}
    for r in reports:
        for k, v in r.energy_wh.items():
            if k != "total":
                state_wh[k] = state_wh.get(k, 0.0) + v
        for k, v in r.durations_s.items():
            state_s[k] = state_s.get(k, 0.0) + v
    zone_wh, zone_kg = zone_decomposition(reports)
    if multi_zone:
        # same per-zone pricing as run_fleet's multi-zone branch
        energy_usd = math.fsum(
            energy_cost_usd(wh, get_mix(z)) for z, wh in zone_wh.items())
        kg_flat = math.fsum(
            carbon_kg(wh, get_mix(z)) for z, wh in zone_wh.items())
    else:
        energy_usd = energy_cost_usd(energy, mix)
        kg_flat = carbon_kg(energy, mix)
    cost = price_fleet(sc.devices, reports, default_tier=sc.price_tier,
                       energy_usd=energy_usd)
    tier_billed = (fin.tier_billed_s if fin.tier_billed_s is not None
                   else tier_billed_seconds(sc.devices, reports,
                                            sc.price_tier))
    all_lat = np.concatenate([np.zeros(n_zero), fin.waits])
    return FleetResult(
        router="warm-first", horizon_s=horizon, devices=reports,
        energy_wh=energy,
        parking_tax_wh=sum(r.parking_tax_wh for r in reports),
        cold_starts=sum(d_cold), requests=sum(d_reqs),
        added_latency_s_total=math.fsum(fin.waits),
        migrations=0,
        lb_nongated_wh=lb_nongated, cv_per_model_wh=cv_sum,
        infra_usd=fleet_price_usd(sc.devices, horizon, sc.price_tier),
        energy_usd=energy_usd,
        carbon_kg=math.fsum(r.carbon_kg for r in reports),
        carbon_kg_flat=kg_flat,
        carbon_trace_name=trace.name,
        carbon_timeline=fin.carbon_timeline,
        power_timeline=fleet_segments,
        zone_energy_wh=zone_wh, zone_carbon_kg=zone_kg,
        latencies_s=np.sort(all_lat),
        replica_timeline={mid: list(log)
                          for mid, log in replica_log.items()},
        state_energy_wh=state_wh, state_durations_s=state_s,
        phase_timings=fin.timings,
        cost_usd=cost.cost_usd, gpu_hours_usd=cost.gpu_hours_usd,
        device_gpu_usd=cost.device_gpu_usd,
        device_cost_usd=cost.device_cost_usd,
        zone_cost_usd=cost.zone_cost_usd, device_tiers=cost.device_tiers,
        tier_billed_s=tier_billed)
