"""JAX-compiled bulk-scan backend for the mega-simulator, plus vmapped
fleet sweeps (``run_mega(..., backend="jax")`` / ``run_mega_sweep``).

``megasim.run_mega`` splits into a STRUCTURAL event loop (heap events:
load completions, armed evictions -- inherently sequential, stays
Python) and BULK phases that touch every request or metered segment.
This module re-expresses the bulk phases as jit-compiled array
programs behind the ``_NumpyBulk`` seam:

  * **big-gap scans** -- instead of per-(stream, timeout)
    ``np.flatnonzero(np.diff(arr) > T)`` + a ``searchsorted`` per run,
    ``prepare`` stacks streams into padded static-shape matrices
    (arrival lengths bucketed to powers of two so jit compiles once
    per bucket, not once per stream) and one ``lax.cummin`` reverse
    scan yields a ``nextbig`` table per (stream, T): the run ending at
    pointer ``p`` is the O(1) lookup ``nextbig[p]``.
  * **lazy-commit billing** -- waiter slices absorbed into mid-load
    replicas are recorded as (stream, lo, hi, drain-time) references,
    never materialized per element; ``finalize`` expands every record
    in one ragged gather (``searchsorted`` over the record-start
    prefix sums, indexed into the stacked stream arrays) and the wait
    of each request is one vectorized subtract.
  * **energy accounting** -- each power-state transition appends
    ``(device*3 + state, dt, watts)``; per-(device, state) joules and
    seconds are two ``jax.ops.segment_sum`` calls at finalize.
  * **carbon integration** -- the power-timeline x ``CarbonTrace``
    trapezoid integral runs through the ``kernels/segment_trapz``
    Pallas kernel (jnp reference under interpret mode, see
    ``kernels/ops.py``), with per-device attribution one segment-sum
    away; the hourly cumulative timeline is the same prefix-integral
    evaluated at bin boundaries under ``lax.map``.

Everything is float64 (the fleet accounting convention) via the
``jax.experimental.enable_x64`` scope, which is thread-local and does
not disturb the f32 kernel tests elsewhere in the repo.  All array
programs pad to power-of-two sizes with masked/zero-weight tails, so a
sweep over many same-shaped days reuses every compiled program.

Both backends drive the identical event loop and see identical calls,
so requests/cold starts are equal and float totals (energy, carbon)
agree to <=1e-9 relative -- pinned in ``tests/test_mega.py``.
"""
from __future__ import annotations

import array
import functools
import itertools
import math
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.fleet.carbon import CarbonTrace
from repro.fleet.fleetsim import DAY, FleetResult
from repro.fleet.mega import megasim
from repro.fleet.mega.traces import FleetTrace, RouteTrace, _route_plan
from repro.kernels import ops

_J_PER_KWH = 3.6e6

# Fused metering (kernels/ops.fused_meter): energy segment-sums, carbon
# integrals, and per-tier billed seconds in ONE pass over the charge
# log instead of three.  Module-level so tests can monkeypatch it; each
# _JaxBulk snapshots the flag at construction.
FUSED = os.environ.get("REPRO_MEGA_FUSED", "1") != "0"


def _pow2(n: int, lo: int = 256) -> int:
    """Smallest power of two >= max(n, 1), floored at ``lo`` -- the
    padding quantum that keeps jit recompiles bounded (one compile per
    bucket, reused across streams, runs, and sweep points)."""
    return max(lo, 1 << max(int(n) - 1, 0).bit_length())


def _pad(a: np.ndarray, n: int, value=0.0) -> np.ndarray:
    if a.size >= n:
        return a
    return np.concatenate([a, np.full(n - a.size, value, dtype=a.dtype)])


# ---------------------------------------------------------------------------
# Compiled bulk programs (shapes pre-padded by the callers below).
# ---------------------------------------------------------------------------

@jax.jit
def _nextbig_rows(mat: jnp.ndarray, Ts: jnp.ndarray) -> jnp.ndarray:
    """Per-row ``nextbig`` tables: ``out[r, p]`` = the smallest i >= p
    with ``mat[r, i+1] - mat[r, i] > Ts[r]``, or a sentinel >= L when
    no such gap remains.  Rows are arrival streams padded by repeating
    their last arrival (gap 0: never "big"), so padding cannot end a
    run early."""
    gaps = mat[:, 1:] - mat[:, :-1]
    L1 = gaps.shape[1]
    idx = jnp.where(gaps > Ts[:, None],
                    jnp.arange(L1, dtype=jnp.int32)[None, :],
                    jnp.int32(L1))
    return jax.lax.cummin(idx, axis=1, reverse=True)


@functools.partial(jax.jit, static_argnames=("total_pad",))
def _bill_gather(flat: jnp.ndarray, off: jnp.ndarray, sid: jnp.ndarray,
                 lo: jnp.ndarray, hi: jnp.ndarray, t: jnp.ndarray, *,
                 total_pad: int) -> jnp.ndarray:
    """Expand ragged billing records into per-request waits.

    Record r says: arrivals ``arr_sid[lo:hi]`` of stream ``sid`` were
    served at drain time ``t`` (their wait is ``t - arrival``).  The
    expansion is the classic ragged gather: output slot k belongs to
    the record whose cumulative-count prefix contains k
    (``searchsorted`` side='right' also steps over zero-length pad
    records), and its arrival index is the offset within that record.
    Slots past the real total hit pad records; callers slice them off.
    """
    cnt = hi - lo
    starts = jnp.cumsum(cnt) - cnt
    k = jnp.arange(total_pad, dtype=jnp.int32)
    r = jnp.searchsorted(starts, k, side="right") - 1
    r = jnp.clip(r, 0, sid.shape[0] - 1)
    pos = off[sid[r]] + lo[r] + (k - starts[r])
    pos = jnp.clip(pos, 0, flat.shape[0] - 1)
    return t[r] - flat[pos]


@functools.partial(jax.jit, static_argnames=("num",))
def _energy_segsum(keys: jnp.ndarray, dt: jnp.ndarray, pw: jnp.ndarray, *,
                   num: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(device, state) joules and seconds from the transition log
    (keys = device*3 + state; pad rows carry dt = 0)."""
    return (jax.ops.segment_sum(dt * pw, keys, num_segments=num),
            jax.ops.segment_sum(dt, keys, num_segments=num))


def _prefix_fn(kt: jnp.ndarray, kv: jnp.ndarray, cum: jnp.ndarray,
               period: float) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """F(t) = integral of the periodic piecewise-linear intensity over
    [0, t] from the extended knot tables (``CarbonTrace`` internals) --
    the same closed form as ``kernels/ref.segment_trapz_ref``."""
    total = cum[kt.shape[0] - 1]

    def F(t):
        k = jnp.floor(t / period)
        p = t - k * period
        j = jnp.clip(jnp.searchsorted(kt, p, side="right") - 1,
                     0, kt.shape[0] - 2)
        span = kt[j + 1] - kt[j]
        dt = p - kt[j]
        v_p = kv[j] + (kv[j + 1] - kv[j]) * dt / jnp.where(span > 0, span,
                                                           1.0)
        return k * total + cum[j] + dt * (kv[j] + v_p) * 0.5

    return F


@functools.partial(jax.jit, static_argnames=("period", "n_dev", "nb"))
def _carbon_fused(a, b, w, dev, bucket, pseg, pk, pw, kt, kv, cum, tbr, *,
                  period: float, n_dev: int, nb: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """kgCO2e per device AND the cumulative hourly timeline in one pass.

    Per device: the segment_trapz kernel over every metered power
    segment, attributed by one segment-sum (pad rows carry w = 0).

    Timeline: the cumulative emission at boundary t is
    ``sum_i w_i * (F(min(b_i, t)) - F(min(a_i, t)))`` -- but evaluating
    F at every (segment, boundary) pair is an [nb, N] traversal.
    Instead, split by how a segment meets a boundary: segments ENDING
    at or before t contribute their whole (already-computed) integral
    -- a segment-sum into the bin of ``b`` plus a tiny cumsum over
    bins -- and only segments STRADDLING t (``a < t < b``; at most one
    per device per boundary, precomputed host-side as (pseg, pk)
    pairs) need a partial ``w * (F(t) - F(a))``.  Exact, and the pair
    set is ~devices x boundaries, thousands of terms instead of
    boundaries x segments millions."""
    per_seg = ops.segment_trapz(a, b, w, kt, kv, cum, period=period)
    per_dev = jax.ops.segment_sum(per_seg, dev,
                                  num_segments=n_dev) / _J_PER_KWH
    full = jnp.cumsum(jax.ops.segment_sum(per_seg, bucket,
                                          num_segments=nb))
    if nb > 1:
        F = _prefix_fn(kt, kv, cum, period)
        corr = jax.ops.segment_sum(pw * (F(tbr)[pk] - F(a)[pseg]), pk,
                                   num_segments=nb - 1)
        full = full.at[:nb - 1].add(corr)
    return per_dev, full / _J_PER_KWH


def _prefix_rows(kt: jnp.ndarray, kv: jnp.ndarray, cum: jnp.ndarray,
                 per: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """``F_g(t)`` for stacked trace tables: kt/kv/cum [G, K] (rows
    padded by repeating the last knot), per [G], t [T] -> [G, T].  The
    row-wise twin of ``_prefix_fn`` (same closed form, compare-and-sum
    lookup instead of a shared searchsorted)."""
    total = cum[:, -1:]
    k = jnp.floor(t[None, :] / per[:, None])
    p = t[None, :] - k * per[:, None]
    j = jnp.sum((kt[:, None, :] <= p[:, :, None]).astype(jnp.int32),
                axis=2) - 1
    j = jnp.clip(j, 0, kt.shape[1] - 2)
    take = jnp.take_along_axis
    kt_j = take(kt, j, axis=1)
    kv_j = take(kv, j, axis=1)
    span = take(kt, j + 1, axis=1) - kt_j
    dt = p - kt_j
    v_p = kv_j + (take(kv, j + 1, axis=1) - kv_j) * dt \
        / jnp.where(span > 0, span, 1.0)
    return k * total + take(cum, j, axis=1) + dt * (kv_j + v_p) * 0.5


@functools.partial(jax.jit, static_argnames=("n_dev", "nb", "n_tier"))
def _meter_fused(keys, a, b, dt, pw, g, bucket, tdev, pseg, pk, pwp,
                 kts, kvs, cums, pers, tbr, *,
                 n_dev: int, nb: int, n_tier: int):
    """The whole metering reduction in one compiled program fed by ONE
    fused kernel pass (``ops.fused_meter``) over the raw charge log:

      * per-(device, state) joules/seconds -- same ``segment_sum`` of
        the same ``w * dt`` products as ``_energy_segsum``, so the
        energy/billing numbers (and the 0.0-USD engine anchors built
        on them) are bit-identical to the unfused path;
      * per-device carbon + the hourly cumulative timeline -- same
        end-bin + straddle-correction decomposition as
        ``_carbon_fused``, but over raw log entries (uncoalesced) and
        with every zone's trace in one stacked-table launch instead of
        one compiled call per zone group;
      * per-tier billed seconds -- a third segment-sum of the SAME
        kernel output, free at this point (in mega scope every metered
        state is powered-on, so raw seconds == billed seconds).
    """
    e, s, c, fa = ops.fused_meter(a, b, dt, pw, g, kts, kvs, cums, pers)
    ej = jax.ops.segment_sum(e, keys, num_segments=n_dev * 3)
    ds = jax.ops.segment_sum(s, keys, num_segments=n_dev * 3)
    dev = keys // 3
    per_dev = jax.ops.segment_sum(c, dev, num_segments=n_dev) / _J_PER_KWH
    tier_s = jax.ops.segment_sum(s, tdev[dev], num_segments=n_tier)
    full = jnp.cumsum(jax.ops.segment_sum(c, bucket, num_segments=nb))
    if nb > 1:
        Fb = _prefix_rows(kts, kvs, cums, pers, tbr)      # [G, nb-1]
        pg = g[pseg]
        corr = jax.ops.segment_sum(pwp * (Fb[pg, pk] - fa[pseg]), pk,
                                   num_segments=nb - 1)
        full = full.at[:nb - 1].add(corr)
    return ej, ds, per_dev, tier_s, full / _J_PER_KWH


# ---------------------------------------------------------------------------
# The backend object megasim drives.
# ---------------------------------------------------------------------------

class _JaxBulk:
    """Drop-in for ``megasim._NumpyBulk`` that records the bulk work
    during the event loop and retires it compiled at finalize.  See the
    module docstring for the four phases; ``self.t`` carries the same
    phase-timing keys the numpy backend reports, so the bench's
    speedup rows compare like-for-like."""

    name = "jax"
    wants_tables = True

    def __init__(self, n_dev: int):
        self.n_dev = n_dev
        self.t = {"biggap_s": 0.0, "billing_s": 0.0, "energy_s": 0.0,
                  "carbon_s": 0.0}
        # transition log (energy) and billing records, appended by the
        # event loop, reduced at finalize (array.array: appends like a
        # list, converts to ndarray as a buffer view instead of a
        # million-element Python float walk)
        self._ekey = array.array("i")
        self._edt = array.array("d")
        self._epw = array.array("d")
        # absolute segment bounds, only consumed by the fused pass
        # (the unfused carbon path reads the coalesced `segs` lists)
        self._ea = array.array("d")
        self._eb = array.array("d")
        self.fused = FUSED
        self._bill: List[Tuple[int, int, int, float]] = []
        self._scalar_waits: List[float] = []
        self._sid: Dict[str, int] = {}
        self._flat = np.empty(0, dtype=np.float64)
        self._off = np.empty(0, dtype=np.int32)
        self._nextbig: Dict[Tuple[str, float], np.ndarray] = {}

    # -- prepare: stacked stream matrices + nextbig tables -------------------
    def prepare(self, streams: Dict[str, "megasim._Stream"],
                stream_Ts: Dict[str, Sequence[float]]) -> None:
        t0 = time.perf_counter()
        mids = list(streams)
        self._sid = {mid: i for i, mid in enumerate(mids)}
        arrs = [streams[mid].arr for mid in mids]
        lens = np.array([a.size for a in arrs], dtype=np.int64)
        off = np.zeros(len(arrs) + 1, dtype=np.int64)
        np.cumsum(lens, out=off[1:])
        self._off = off[:-1].astype(np.int32)
        self._flat = (np.concatenate(arrs) if arrs
                      else np.empty(0, dtype=np.float64))
        # one nextbig row per (stream, candidate timeout), bucketed by
        # padded length so each bucket is a single static-shape compile;
        # computed rows are parked in the stream's shared biggap dict
        # (under ("nb", T) keys the numpy float-keyed lookups never see)
        # so repeat runs on the same FleetTrace skip the scan entirely
        buckets: Dict[int, List[Tuple[str, float, np.ndarray]]] = {}
        for mid in mids:
            ms = streams[mid]
            if ms.n < 2:
                continue
            for T in dict.fromkeys(stream_Ts.get(mid, ())):
                if math.isinf(T) or (mid, T) in self._nextbig:
                    continue
                row = ms.biggap.get(("nb", T))
                if row is not None:
                    self._nextbig[(mid, T)] = row
                    continue
                L = _pow2(ms.n)
                buckets.setdefault(L, []).append((mid, float(T), ms.arr))
        with enable_x64():
            for L, grp in buckets.items():
                rows = _pow2(len(grp), lo=8)
                mat = np.zeros((rows, L), dtype=np.float64)
                Ts = np.full(rows, np.inf)
                for r, (_mid, T, arr) in enumerate(grp):
                    mat[r, :arr.size] = arr
                    mat[r, arr.size:] = arr[-1]
                    Ts[r] = T
                nb = np.asarray(_nextbig_rows(jnp.asarray(mat),
                                              jnp.asarray(Ts)))
                for r, (mid, T, _arr) in enumerate(grp):
                    self._nextbig[(mid, T)] = nb[r]
                    ms = streams[mid]
                    if len(ms.biggap) >= megasim.biggap_cache.max_timeouts:
                        ms.biggap.pop(next(iter(ms.biggap)))
                    ms.biggap[("nb", T)] = nb[r]
        self.t["biggap_s"] += time.perf_counter() - t0

    # -- event-loop hooks ----------------------------------------------------
    def charge(self, d: int, s: int, dt: float, p: float,
               a: float = 0.0, b: float = 0.0) -> None:
        self._ekey.append(d * 3 + s)
        self._edt.append(dt)
        self._epw.append(p)
        self._ea.append(a)
        self._eb.append(b)

    def last_of_run(self, ms, T: float) -> int:
        t0 = time.perf_counter()
        if ms.ptr >= ms.n - 1:
            last = ms.n - 1
        else:
            row = self._nextbig.get((ms.mid, T))
            if row is None:
                # timeout the eager probe skipped (or an infinite one):
                # the numpy scan path is the fallback, same answer
                big = ms.biggaps(T)
                j = int(np.searchsorted(big, ms.ptr))
                last = int(big[j]) if j < big.size else ms.n - 1
            else:
                v = int(row[ms.ptr])
                last = v if v <= ms.n - 2 else ms.n - 1
        self.t["biggap_s"] += time.perf_counter() - t0
        return last

    def absorb(self, ms, d: int, lo: int, hi: int, t_done: float) -> None:
        ent = ms.waiters.get(d)
        if ent is None:
            ent = ms.waiters[d] = [0, []]
        ent[0] += hi - lo
        ent[1].append((lo, hi))

    def wait_one(self, ms, d: int, t: float) -> None:
        ent = ms.waiters.get(d)
        if ent is None:
            ent = ms.waiters[d] = [0, []]
        ent[0] += 1
        ent[1].append(t)

    def waiter_count(self, ms, d: int) -> int:
        ent = ms.waiters.get(d)
        return ent[0] if ent is not None else 0

    def drain(self, ms, d: int, t: float) -> int:
        ent = ms.waiters.pop(d, None)
        if ent is None:
            return 0
        sid = self._sid[ms.mid]
        for item in ent[1]:
            if type(item) is tuple:
                self._bill.append((sid, item[0], item[1], t))
            else:
                self._scalar_waits.append(t - item)
        return ent[0]

    # -- finalize: the compiled bulk reductions ------------------------------
    def finalize(self, segs, fleet_segments, trace: CarbonTrace,
                 horizon: float, dev_traces=None,
                 tiers=None) -> "megasim._Fin":
        with enable_x64():
            if self.fused:
                (energy_j, dur_s, carbon_dev, timeline,
                 tier_billed) = self._finalize_fused(trace, horizon,
                                                     dev_traces, tiers)
                waits = self._finalize_billing()
            else:
                energy_j, dur_s = self._finalize_energy()
                waits = self._finalize_billing()
                carbon_dev, timeline = self._finalize_carbon(
                    segs, fleet_segments, trace, horizon, dev_traces)
                tier_billed = None
        self.t["bulk_scan_s"] = sum(self.t.values())
        return megasim._Fin(energy_j, dur_s, waits, carbon_dev, timeline,
                            dict(self.t), tier_billed)

    def _finalize_energy(self):
        t0 = time.perf_counter()
        n = len(self._ekey)
        m = _pow2(n)
        keys = _pad(np.asarray(self._ekey, dtype=np.int32), m, 0)
        dt = _pad(np.asarray(self._edt, dtype=np.float64), m)
        pw = _pad(np.asarray(self._epw, dtype=np.float64), m)
        ej, ds = _energy_segsum(jnp.asarray(keys), jnp.asarray(dt),
                                jnp.asarray(pw), num=self.n_dev * 3)
        energy_j = np.asarray(ej).reshape(self.n_dev, 3)
        dur_s = np.asarray(ds).reshape(self.n_dev, 3)
        self.t["energy_s"] += time.perf_counter() - t0
        return energy_j, dur_s

    def _finalize_billing(self) -> np.ndarray:
        t0 = time.perf_counter()
        scalar = np.asarray(self._scalar_waits, dtype=np.float64)
        if not self._bill:
            self.t["billing_s"] += time.perf_counter() - t0
            return scalar
        rec = np.asarray(self._bill, dtype=np.float64)
        m = _pow2(rec.shape[0])
        sid = _pad(rec[:, 0].astype(np.int32), m, 0)
        lo = _pad(rec[:, 1].astype(np.int32), m, 0)
        hi = _pad(rec[:, 2].astype(np.int32), m, 0)
        tt = _pad(rec[:, 3], m)
        total = int((hi - lo).sum())
        w = _bill_gather(jnp.asarray(self._flat), jnp.asarray(self._off),
                         jnp.asarray(sid), jnp.asarray(lo),
                         jnp.asarray(hi), jnp.asarray(tt),
                         total_pad=_pow2(total))
        waits = np.concatenate([np.asarray(w)[:total], scalar])
        self.t["billing_s"] += time.perf_counter() - t0
        return waits

    def _finalize_carbon(self, segs, fleet_segments, trace: CarbonTrace,
                         horizon: float, dev_traces=None):
        t0 = time.perf_counter()
        n = len(fleet_segments)
        if n == 0:
            self.t["carbon_s"] += time.perf_counter() - t0
            return [0.0] * self.n_dev, []
        # hourly timeline, numpy-semantics bins: they cover
        # max(horizon, last segment end), the last bin absorbing any
        # overshoot.  Bin geometry is GLOBAL (all zones share the sim
        # clock) even when devices integrate against different traces.
        bin_s = 3600.0
        end = max(horizon, max(s[-1][1] for s in segs if s))
        nb = max(int(math.ceil(end / bin_s - 1e-12)), 1)
        tbr = bin_s * np.arange(1, nb)               # interior boundaries
        # partition devices by their zone's trace object: one fused
        # call per distinct trace, device ids group-local, timelines
        # summed elementwise.  A single-zone fleet is one group over
        # every device -- the exact pre-zone call.
        if dev_traces is None or all(tr is trace for tr in dev_traces):
            groups = [(trace, list(range(self.n_dev)))]
        else:
            by_trace: Dict[int, Tuple[CarbonTrace, List[int]]] = {}
            for d, tr in enumerate(dev_traces):
                by_trace.setdefault(id(tr), (tr, []))[1].append(d)
            groups = list(by_trace.values())
        per_dev_out = np.zeros(self.n_dev, dtype=np.float64)
        cums_total = np.zeros(nb, dtype=np.float64)
        for gtrace, gdevs in groups:
            gsegs = [segs[d] for d in gdevs]
            gn = sum(len(s) for s in gsegs)
            if gn == 0:
                continue
            # fromiter over a flattened chain beats np.asarray on a
            # millions-long list of 3-tuples by ~2.5x
            seg = np.fromiter(
                itertools.chain.from_iterable(
                    itertools.chain.from_iterable(gsegs)),
                dtype=np.float64, count=3 * gn).reshape(gn, 3)
            a_np, b_np, w_np = seg[:, 0], seg[:, 1], seg[:, 2]
            dev = np.repeat(np.arange(len(gdevs), dtype=np.int32),
                            [len(s) for s in gsegs])
            # host-side prep for _carbon_fused: each segment's full
            # integral lands in the bin of its END (``bucket``), and
            # the (segment, boundary) STRADDLE pairs -- bounded by
            # devices x boundaries, since a device's power segments
            # are disjoint in time -- are expanded with one
            # repeat/cumsum.
            k_lo = np.searchsorted(tbr, a_np, side="right")
            bucket = np.searchsorted(tbr, b_np,
                                     side="left").astype(np.int32)
            cnt = np.maximum(bucket - k_lo, 0)
            total = int(cnt.sum())
            pcap = _pow2(total, lo=1024)
            pseg = np.zeros(pcap, dtype=np.int32)
            pk = np.zeros(pcap, dtype=np.int32)
            pw = np.zeros(pcap, dtype=np.float64)    # pad pairs weigh 0
            if total:
                ps = np.repeat(np.arange(gn, dtype=np.int32), cnt)
                starts = np.cumsum(cnt) - cnt
                pseg[:total] = ps
                pk[:total] = (np.arange(total) - starts[ps] + k_lo[ps])
                pw[:total] = w_np[ps]
            m = _pow2(gn)
            per_dev, cums = _carbon_fused(
                jnp.asarray(_pad(a_np, m)), jnp.asarray(_pad(b_np, m)),
                jnp.asarray(_pad(w_np, m)),          # pad weight 0
                jnp.asarray(_pad(dev, m, 0)),
                jnp.asarray(_pad(bucket, m, 0)),
                jnp.asarray(pseg), jnp.asarray(pk), jnp.asarray(pw),
                jnp.asarray(np.asarray(gtrace._kt)),
                jnp.asarray(np.asarray(gtrace._kv)),
                jnp.asarray(np.asarray(gtrace._cum)), jnp.asarray(tbr),
                period=float(gtrace.period_s), n_dev=len(gdevs), nb=nb)
            per_dev_out[gdevs] = np.asarray(per_dev)
            cums_total += np.asarray(cums)
        timeline = [(min((j + 1) * bin_s, end), float(cums_total[j]))
                    for j in range(nb)]
        self.t["carbon_s"] += time.perf_counter() - t0
        return list(per_dev_out), timeline

    def _finalize_fused(self, trace: CarbonTrace, horizon: float,
                        dev_traces=None, tiers=None):
        """Energy, durations, carbon, timeline, and per-tier billed
        seconds from ONE ``_meter_fused`` launch over the raw charge
        log.  Host-side prep (table stacking, bin/straddle geometry) is
        booked under ``carbon_s`` and the compiled call under
        ``energy_s`` so the phase-timing keys the bench and tests pin
        keep their meaning: time spent preparing/running the carbon
        vs energy reductions."""
        t0 = time.perf_counter()
        n = len(self._ekey)
        tier_names = sorted(set(tiers)) if tiers else ["on_demand"]
        if n == 0:
            z = np.zeros((self.n_dev, 3))
            self.t["energy_s"] += time.perf_counter() - t0
            return (z, z.copy(), [0.0] * self.n_dev, [],
                    {t: 0.0 for t in tier_names})
        keys_np = np.asarray(self._ekey, dtype=np.int32)
        a_np = np.asarray(self._ea, dtype=np.float64)
        b_np = np.asarray(self._eb, dtype=np.float64)
        dt_np = np.asarray(self._edt, dtype=np.float64)
        pw_np = np.asarray(self._epw, dtype=np.float64)
        # stacked knot tables: one row per distinct zone trace, K
        # padded by repeating the final knot (in-period offsets are
        # strictly below the period, so pad knots never match), G
        # padded with row-0 copies (never gathered)
        if dev_traces is None:
            dev_traces = [trace] * self.n_dev
        gid: Dict[int, int] = {}
        gidx_dev = np.zeros(self.n_dev, dtype=np.int32)
        tabs: List[CarbonTrace] = []
        for d, tr in enumerate(dev_traces):
            gi = gid.get(id(tr))
            if gi is None:
                gi = gid[id(tr)] = len(tabs)
                tabs.append(tr)
            gidx_dev[d] = gi
        kmax = _pow2(max(np.asarray(t._kt).size for t in tabs), lo=8)
        gpad = _pow2(len(tabs), lo=1)
        kts = np.zeros((gpad, kmax), dtype=np.float64)
        kvs = np.zeros((gpad, kmax), dtype=np.float64)
        cums = np.zeros((gpad, kmax), dtype=np.float64)
        pers = np.ones(gpad, dtype=np.float64)
        for gi, tr in enumerate(tabs):
            for dst, src in ((kts, tr._kt), (kvs, tr._kv),
                             (cums, tr._cum)):
                row = np.asarray(src, dtype=np.float64)
                dst[gi, :row.size] = row
                dst[gi, row.size:] = row[-1]
            pers[gi] = float(tr.period_s)
        kts[len(tabs):] = kts[0]
        kvs[len(tabs):] = kvs[0]
        cums[len(tabs):] = cums[0]
        pers[len(tabs):] = pers[0]
        g_np = gidx_dev[keys_np // 3]
        # hourly-bin geometry + straddle pairs, exactly the unfused
        # decomposition (_finalize_carbon) but over raw log entries --
        # a device's entries are disjoint in time, so the pair count
        # stays bounded by devices x boundaries
        bin_s = 3600.0
        end = max(horizon, float(b_np.max()))
        nb = max(int(math.ceil(end / bin_s - 1e-12)), 1)
        tbr = bin_s * np.arange(1, nb)
        k_lo = np.searchsorted(tbr, a_np, side="right")
        bucket = np.searchsorted(tbr, b_np, side="left").astype(np.int32)
        cnt = np.maximum(bucket - k_lo, 0)
        total = int(cnt.sum())
        pcap = _pow2(total, lo=1024)
        pseg = np.zeros(pcap, dtype=np.int32)
        pk = np.zeros(pcap, dtype=np.int32)
        pwp = np.zeros(pcap, dtype=np.float64)        # pad pairs weigh 0
        if total:
            ps = np.repeat(np.arange(n, dtype=np.int32), cnt)
            starts = np.cumsum(cnt) - cnt
            pseg[:total] = ps
            pk[:total] = (np.arange(total) - starts[ps] + k_lo[ps])
            pwp[:total] = pw_np[ps]
        tdev = np.array([tier_names.index(t) for t in tiers],
                        dtype=np.int32) if tiers else \
            np.zeros(self.n_dev, dtype=np.int32)
        m = _pow2(n)
        self.t["carbon_s"] += time.perf_counter() - t0
        t1 = time.perf_counter()
        ej, ds, per_dev, tier_s, cums_nb = _meter_fused(
            jnp.asarray(_pad(keys_np, m, 0)),
            jnp.asarray(_pad(a_np, m)), jnp.asarray(_pad(b_np, m)),
            jnp.asarray(_pad(dt_np, m)), jnp.asarray(_pad(pw_np, m)),
            jnp.asarray(_pad(g_np, m, 0)),
            jnp.asarray(_pad(bucket, m, 0)), jnp.asarray(tdev),
            jnp.asarray(pseg), jnp.asarray(pk), jnp.asarray(pwp),
            jnp.asarray(kts), jnp.asarray(kvs), jnp.asarray(cums),
            jnp.asarray(pers), jnp.asarray(tbr),
            n_dev=self.n_dev, nb=nb, n_tier=len(tier_names))
        energy_j = np.asarray(ej).reshape(self.n_dev, 3)
        dur_s = np.asarray(ds).reshape(self.n_dev, 3)
        cums_np = np.asarray(cums_nb)
        timeline = [(min((j + 1) * bin_s, end), float(cums_np[j]))
                    for j in range(nb)]
        tier_billed = {t: float(v)
                       for t, v in zip(tier_names, np.asarray(tier_s))}
        self.t["energy_s"] += time.perf_counter() - t1
        return (energy_j, dur_s, list(np.asarray(per_dev)), timeline,
                tier_billed)


def compiled_program_count() -> int:
    """How many distinct programs this module's jitted bulk reductions
    have compiled so far (summed jit-cache sizes).  The batched planner
    reports the delta per sweep: shared-shape grouping shows up as a
    compile count that stays flat while the point count grows."""
    total = 0
    for fn in (_nextbig_rows, _bill_gather, _energy_segsum,
               _carbon_fused, _meter_fused):
        try:
            total += fn._cache_size()
        except Exception:      # cache API moved: count as unknown/0
            pass
    return total


# ---------------------------------------------------------------------------
# Vmapped sweeps: many production-shaped days through one compiled stack.
# ---------------------------------------------------------------------------

def _diurnal_hr_j(base_hr: float, t: jnp.ndarray) -> jnp.ndarray:
    """jnp twin of ``traces._diurnal_hr`` (same day shape)."""
    h = (t / 3600.0) % 24.0
    return base_hr * (0.55 + 0.45 * jnp.sin((h - 9.0) * jnp.pi / 12.0))


def _sample_group(keys: np.ndarray, rate_fn, rate_max: float,
                  horizon_s: float, n_max: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized thinned inhomogeneous Poisson at a STATIC shape: draw
    the envelope count (clamped to ``n_max``, sized for ~10 sigma of
    headroom), keep the first ``n`` of ``n_max`` uniforms, thin by
    ``rate(t)/rate_max``, and sort rejected samples to +inf.  One
    jit-compiled vmap over every (sweep point, route) in the group --
    the whole sweep's trace generation is a single compiled call."""

    def one(key):
        k1, k2, k3 = jax.random.split(key, 3)
        lam = rate_max * horizon_s / 3600.0
        cnt = jnp.minimum(jax.random.poisson(k1, lam), n_max)
        t = jax.random.uniform(k2, (n_max,), dtype=jnp.float64,
                               maxval=horizon_s)
        u = jax.random.uniform(k3, (n_max,), dtype=jnp.float64,
                               maxval=rate_max)
        keep = (jnp.arange(n_max) < cnt) & (u < rate_fn(t))
        return jnp.sort(jnp.where(keep, t, jnp.inf)), keep.sum()

    with enable_x64():
        ts, counts = jax.jit(jax.vmap(one))(jnp.asarray(keys))
    return np.asarray(ts), np.asarray(counts)


def _envelope_n(rate_max_hr: float, horizon_s: float) -> int:
    lam = rate_max_hr * horizon_s / 3600.0
    return int(lam + 10.0 * math.sqrt(lam + 1.0) + 20.0)


def sweep_traces(seeds: Sequence[int], *, generator: str = "flash-crowd",
                 n_routes: int = 8, fleet: str = "2xh100+2xa100+2xl40s",
                 horizon_s: float = DAY, base_rate_hr: float = 40.0,
                 spike_x: float = 40.0,
                 spike_start_s: float = 13 * 3600.0,
                 spike_width_s: float = 1800.0) -> List[FleetTrace]:
    """A batch of production-shaped days, generated on the compiled
    stack: per-route PRNG keys derive from the same ``_route_plan``
    child seeds as the numpy generators (same checkpoint plan, same
    seed discipline -- same seed, bit-identical batch), and ALL routes
    of ALL sweep points sample in one vmapped thinning call per rate
    family.  The day shapes mirror ``traces.flash_crowd`` /
    ``product_launch`` / ``regional_outage``; arrival streams come
    from jax's PRNG, so they are statistically -- not bitwise -- the
    numpy generators' days."""
    if generator not in ("flash-crowd", "product-launch",
                         "regional-outage"):
        raise KeyError(f"unknown sweep generator {generator!r}")
    plans = [_route_plan(np.random.default_rng(int(s)), n_routes)
             for s in seeds]
    keys = np.stack([
        np.asarray(jax.random.PRNGKey(int(child)))
        for child_seeds, _ in plans for child in child_seeds])
    keys = keys.reshape(len(seeds), n_routes, 2)

    tail_s = 2.0 * spike_width_s

    def flash_rate(t):
        r = _diurnal_hr_j(base_rate_hr, t)
        dt = t - spike_start_s
        hot = (dt >= 0.0) & (dt < spike_width_s)
        cool = (dt >= spike_width_s) & (dt < spike_width_s + tail_s)
        boost = jnp.where(hot, spike_x, 0.0) + jnp.where(
            cool, spike_x * jnp.exp(-(dt - spike_width_s)
                                    / (0.35 * spike_width_s)), 0.0)
        return r * (1.0 + boost)

    def launch_rate(t):
        dt = t - 9 * 3600.0
        surge = 60.0 + (600.0 - 60.0) * jnp.exp(-jnp.maximum(dt, 0.0)
                                                / (4 * 3600.0))
        return jnp.where(dt >= 0.0, surge, 0.0)

    def outage_rate(t):
        out0, out1 = 11 * 3600.0, 12 * 3600.0
        r = _diurnal_hr_j(base_rate_hr, t)
        dark = (t >= out0) & (t < out1)
        surge = (t >= out1) & (t < out1 + 1800.0)
        return jnp.where(dark, 0.0, r * jnp.where(surge, 3.0, 1.0))

    base_fn = _diurnal_hr_j
    if generator == "flash-crowd":
        groups = [(keys[:, 0, :], flash_rate,
                   base_rate_hr * (1.0 + spike_x)),
                  (keys[:, 1:, :].reshape(-1, 2),
                   lambda t: base_fn(base_rate_hr, t), base_rate_hr)]
    elif generator == "product-launch":
        groups = [(keys[:, 0, :], launch_rate, 600.0),
                  (keys[:, 1:, :].reshape(-1, 2),
                   lambda t: base_fn(base_rate_hr, t), base_rate_hr)]
    else:
        groups = [(keys.reshape(-1, 2), outage_rate, base_rate_hr * 3.0)]

    sampled: List[Tuple[np.ndarray, np.ndarray]] = []
    for gkeys, rate_fn, rmax in groups:
        sampled.append(_sample_group(
            gkeys, rate_fn, rmax, horizon_s,
            _envelope_n(rmax, horizon_s)) if gkeys.size
            else (np.empty((0, 0)), np.empty(0, dtype=np.int64)))

    traces: List[FleetTrace] = []
    for p, (seed, (_, ckpt)) in enumerate(zip(seeds, plans)):
        routes = []
        for i in range(n_routes):
            if len(groups) == 1:
                ts, cnt = sampled[0]
                row = p * n_routes + i
            elif i == 0:
                ts, cnt = sampled[0]
                row = p
            else:
                ts, cnt = sampled[1]
                row = p * (n_routes - 1) + (i - 1)
            arr = ts[row, :int(cnt[row])].copy()
            routes.append(RouteTrace(route_id=f"r{i}", arrivals_s=arr,
                                     checkpoint_gb=float(ckpt[i])))
        traces.append(FleetTrace(name=f"{generator}-sweep", fleet=fleet,
                                 horizon_s=horizon_s, routes=tuple(routes),
                                 seed=int(seed)))
    return traces


def run_mega_sweep(scenarios=None, *, seeds: Optional[Sequence[int]] = None,
                   policy_factory=None, router: str = "warm-first",
                   compute_bound: bool = False,
                   scenario_kw: Optional[dict] = None,
                   on_unsupported: str = "raise",
                   **trace_kw) -> List[Optional[FleetResult]]:
    """Run a sweep of mega days on the jax backend: either explicit
    ``scenarios`` (any ``FleetScenario`` in run_mega's scope) or
    ``seeds`` + generator kwargs (``generator=``, ``n_routes=``,
    ``fleet=``, ... -- see ``sweep_traces``), in which case trace
    generation for the whole batch is one vmapped compiled call.

    The points then replay through ``run_mega(backend="jax")``
    sequentially (the structural event loop is inherently serial), but
    every compiled bulk program -- nextbig scans, billing gather,
    energy segment-sums, carbon integrals -- is shared across points
    through the power-of-two shape buckets, so the batch pays each
    compile once: point 1 is compile-bound, points 2..P run hot.
    Returns one ``FleetResult`` per point, in input order.

    ``on_unsupported="skip"`` returns ``None`` for points outside
    run_mega's scope (``MegaUnsupportedError``) instead of raising --
    the seam the batched planner dispatches event-loop fallbacks
    behind; the default ``"raise"`` keeps the PR-7 contract.
    """
    if (scenarios is None) == (seeds is None):
        raise ValueError("pass exactly one of scenarios= or seeds=")
    if on_unsupported not in ("raise", "skip"):
        raise ValueError(f"on_unsupported={on_unsupported!r}")
    if seeds is not None:
        if policy_factory is None:
            from repro.core.scheduler import Breakeven
            policy_factory = Breakeven
        traces = sweep_traces(seeds, **trace_kw)
        scenarios = [tr.to_scenario(policy_factory, router,
                                    **(scenario_kw or {}))
                     for tr in traces]
    elif trace_kw:
        raise ValueError(f"trace kwargs {sorted(trace_kw)} need seeds=")
    out: List[Optional[FleetResult]] = []
    for sc in scenarios:
        try:
            out.append(megasim.run_mega(sc, compute_bound=compute_bound,
                                        backend="jax"))
        except megasim.MegaUnsupportedError:
            if on_unsupported != "skip":
                raise
            out.append(None)
    return out
