"""Trace-replay frontend for the mega-fleet simulator.

The paper is built on production telemetry (18 days / 335k samples of
H100 fleet data); this module gives the simulators a telemetry-shaped
ingestion schema and a gallery of synthetic production days to replay
at mega scale:

  * ``FleetTrace`` -- a named day: a device inventory (a
    ``build_fleet`` spec string) plus per-route timestamped arrival
    streams (``RouteTrace``).  ``to_scenario`` turns it into the exact
    ``FleetScenario`` shape ``run_fleet``/``run_mega`` consume (homes
    assigned round-robin, VRAM derived from checkpoint size -- the
    ``mixed_fleet_scenario`` conventions).
  * ``to_records`` / ``trace_from_records`` -- a flat, JSON-able record
    form (one ``{"t_s", "route"}`` event row per arrival + a route/
    inventory header), the shape real telemetry exports take, with a
    lossless round trip pinned in tests.
  * Synthetic day generators, all explicitly seeded (same seed =>
    bit-identical trace, pinned in tests) and vectorized (thinned
    homogeneous Poisson -- no per-event Python loop, so million-request
    days generate in milliseconds):
      - ``flash_crowd``     one route's rate spikes by a large factor
                            for a short window (viral moment) on top of
                            everyone's diurnal baseline.
      - ``product_launch``  a new route has EXACTLY zero traffic before
                            launch, then a launch surge decaying to its
                            steady rate.
      - ``regional_outage`` an upstream region drops: NO arrivals reach
                            the fleet during the outage window, then the
                            deferred demand returns as a recovery surge.

Rates are per-route Poisson intensities lambda(t) sampled by thinning:
draw a homogeneous Poisson at the envelope rate, keep each point with
probability lambda(t)/lambda_max -- exact, and fully vectorized.
"""
from __future__ import annotations

import array
import dataclasses
import heapq
import json
import math
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fleet.catalog import build_fleet
from repro.fleet.cluster import FleetModelSpec
from repro.fleet.fleetsim import DAY, FleetModel, FleetScenario

_GB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class RouteTrace:
    """One route's day: its arrival timestamps + model footprint.

    ``zone`` optionally names the electricity zone the route's traffic
    originates in (a ``catalog.MIXES`` key); ``to_scenario`` then homes
    the route on that zone's devices when the inventory has any."""
    route_id: str
    arrivals_s: np.ndarray          # seconds since day start, sorted
    checkpoint_gb: float
    zone: Optional[str] = None

    def __post_init__(self):
        arr = np.sort(np.asarray(self.arrivals_s, dtype=np.float64))
        object.__setattr__(self, "arrivals_s", arr)

    @property
    def requests(self) -> int:
        return int(self.arrivals_s.size)


@dataclasses.dataclass(frozen=True)
class FleetTrace:
    """A replayable production-shaped day: inventory + per-route streams."""
    name: str
    fleet: str                      # build_fleet spec, e.g. "8xh100+4xa100"
    horizon_s: float
    routes: Tuple[RouteTrace, ...]
    seed: Optional[int] = None      # generator seed (None for ingested data)

    @property
    def requests(self) -> int:
        return sum(r.requests for r in self.routes)

    def to_scenario(self, policy_factory, router: str = "warm-first",
                    **kwargs) -> FleetScenario:
        """Materialize the FleetScenario this trace replays: homes
        round-robin across the inventory, VRAM at 1.1x checkpoint (the
        ``mixed_fleet_scenario`` conventions), extra kwargs passed
        through (e.g. ``carbon_trace=``).  Routes carrying a ``zone``
        home round-robin WITHIN that zone's devices when the inventory
        pins any there (zone-less routes keep the global round-robin)."""
        devices = build_fleet(self.fleet)
        by_zone: Dict[str, List] = {}
        for d in devices:
            if d.zone is not None:
                by_zone.setdefault(d.zone, []).append(d)
        zone_rr: Dict[str, int] = {}
        models: List[FleetModel] = []
        for i, route in enumerate(self.routes):
            pool = by_zone.get(route.zone) if route.zone else None
            if pool:
                k = zone_rr.get(route.zone, 0)
                zone_rr[route.zone] = k + 1
                home = pool[k % len(pool)].instance_id
            else:
                home = devices[i % len(devices)].instance_id
            spec = FleetModelSpec(
                model_id=route.route_id, policy_factory=policy_factory,
                checkpoint_bytes=int(route.checkpoint_gb * _GB),
                vram_gb=route.checkpoint_gb * 1.1,
                home=home)
            models.append(FleetModel(spec, route.arrivals_s))
        return FleetScenario(devices=devices, models=models, router=router,
                             horizon_s=self.horizon_s, **kwargs)

    def to_jsonl(self, path: str | os.PathLike) -> None:
        """Stream the trace to JSON-Lines telemetry: line 1 is the
        header (name / fleet / horizon_s / seed / per-route footprints),
        every following line one ``{"t_s", "route"}`` arrival event in
        global time order -- written incrementally, so a multi-million-
        request day never materializes its event list in memory.
        ``from_jsonl`` reads it back losslessly (pinned in tests);
        timestamps survive the round trip exactly via ``repr`` floats.
        """
        header = {
            "name": self.name,
            "fleet": self.fleet,
            "horizon_s": float(self.horizon_s),
            "seed": self.seed,
            "routes": [{"route": r.route_id,
                        "checkpoint_gb": float(r.checkpoint_gb),
                        **({"zone": r.zone} if r.zone else {})}
                       for r in self.routes],
        }
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(json.dumps(header) + "\n")
            # lazy k-way merge over the (already sorted) per-route
            # streams, route id breaking timestamp ties -- the
            # to_records event order, without the event-list buffer

            def _events(route: RouteTrace):
                rid = route.route_id
                return ((float(t), rid) for t in route.arrivals_s)

            for t, rid in heapq.merge(*map(_events, self.routes)):
                fh.write(f'{{"t_s": {t!r}, "route": {json.dumps(rid)}}}\n')

    @classmethod
    def from_jsonl(cls, path: str | os.PathLike) -> "FleetTrace":
        """Stream a ``to_jsonl`` file back into a ``FleetTrace`` --
        line-at-a-time, appending each event to its route's buffer, so
        peak memory is the arrival arrays themselves.  Tolerant of
        unsorted event lines (RouteTrace re-sorts) and of leading blank
        lines before the header; routes declared in the header with no
        events come back zero-traffic.  Malformed input fails with the
        offending line number: unknown route ids, duplicate route ids
        in the header, and missing/malformed ``t_s`` each get their own
        ``ValueError`` (a bad timestamp is NOT an unknown route)."""
        with open(path, "r", encoding="utf-8") as fh:
            hdr_ln = 1
            first = fh.readline()
            while first and not first.strip():   # tolerate leading blanks
                hdr_ln += 1
                first = fh.readline()
            if not first:
                raise ValueError(f"{path}: empty jsonl trace")
            header = json.loads(first)
            per_route: Dict[str, array.array] = {}
            for r in header["routes"]:
                if r["route"] in per_route:
                    raise ValueError(
                        f"{path}:{hdr_ln}: duplicate route id "
                        f"{r['route']!r} in header")
                per_route[r["route"]] = array.array("d")
            for ln, line in enumerate(fh, start=hdr_ln + 1):
                if not line.strip():
                    continue
                e = json.loads(line)
                try:
                    bucket = per_route[e.get("route")]
                except KeyError:
                    raise ValueError(
                        f"{path}:{ln}: event references unknown route "
                        f"{e.get('route')!r}") from None
                t_s = e.get("t_s")
                if t_s is None:
                    raise ValueError(f"{path}:{ln}: event missing 't_s'")
                try:
                    bucket.append(float(t_s))
                except (TypeError, ValueError):
                    raise ValueError(
                        f"{path}:{ln}: malformed 't_s' {t_s!r}") from None
        routes = tuple(
            RouteTrace(route_id=r["route"],
                       arrivals_s=np.frombuffer(
                           per_route[r["route"]], dtype=np.float64).copy(),
                       checkpoint_gb=float(r["checkpoint_gb"]),
                       zone=r.get("zone"))
            for r in header["routes"])
        return cls(name=str(header["name"]), fleet=str(header["fleet"]),
                   horizon_s=float(header["horizon_s"]), routes=routes,
                   seed=header.get("seed"))

    def to_records(self) -> Dict:
        """Flat telemetry-export form: a header (inventory + per-route
        footprints) and one timestamped event row per arrival, time-
        ordered across routes -- the shape a real telemetry dump takes,
        and the input ``trace_from_records`` ingests back losslessly."""
        events = [{"t_s": float(t), "route": r.route_id}
                  for r in self.routes for t in r.arrivals_s]
        events.sort(key=lambda e: (e["t_s"], e["route"]))
        return {
            "name": self.name,
            "fleet": self.fleet,
            "horizon_s": float(self.horizon_s),
            "seed": self.seed,
            "routes": [{"route": r.route_id,
                        "checkpoint_gb": float(r.checkpoint_gb),
                        **({"zone": r.zone} if r.zone else {})}
                       for r in self.routes],
            "events": events,
        }


def trace_from_records(records: Dict) -> FleetTrace:
    """Ingest the ``to_records`` telemetry shape (tolerant of unsorted
    event rows; routes listed in the header but absent from the events
    come back as zero-traffic routes)."""
    per_route: Dict[str, List[float]] = {
        r["route"]: [] for r in records["routes"]}
    for e in records["events"]:
        rid = e["route"]
        if rid not in per_route:
            raise ValueError(f"event references unknown route {rid!r}")
        per_route[rid].append(float(e["t_s"]))
    routes = tuple(
        RouteTrace(route_id=r["route"],
                   arrivals_s=np.asarray(per_route[r["route"]],
                                         dtype=np.float64),
                   checkpoint_gb=float(r["checkpoint_gb"]),
                   zone=r.get("zone"))
        for r in records["routes"])
    return FleetTrace(name=str(records["name"]), fleet=str(records["fleet"]),
                      horizon_s=float(records["horizon_s"]), routes=routes,
                      seed=records.get("seed"))


# ---------------------------------------------------------------------------
# Vectorized inhomogeneous-Poisson sampling (thinning).
# ---------------------------------------------------------------------------

def _thinned(rng: np.random.Generator, rate_hr: Callable[[np.ndarray],
             np.ndarray], rate_max_hr: float, horizon_s: float
             ) -> np.ndarray:
    """Exact lambda(t) sample on [0, horizon) by thinning a homogeneous
    envelope -- one Poisson draw + two vectorized passes, no event loop
    (core.traffic's Lewis-Shedler generator is a per-event Python loop
    and would dominate mega-trace generation)."""
    if rate_max_hr <= 0.0:
        return np.empty(0, dtype=np.float64)
    n = rng.poisson(rate_max_hr * horizon_s / 3600.0)
    t = np.sort(rng.uniform(0.0, horizon_s, size=n))
    keep = rng.uniform(0.0, rate_max_hr, size=n) < rate_hr(t)
    return t[keep]


def _diurnal_hr(base_hr: float, t: np.ndarray) -> np.ndarray:
    """A day-shaped baseline: quiet overnight, peaking mid-afternoon."""
    h = (t / 3600.0) % 24.0
    return base_hr * (0.55 + 0.45 * np.sin((h - 9.0) * np.pi / 12.0))


def _route_plan(rng: np.random.Generator, n_routes: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-route (child seed, checkpoint GB) drawn ONCE from the master
    stream, so every route regenerates bit-identically from the trace
    seed regardless of generation order."""
    seeds = rng.integers(0, 2 ** 31 - 1, size=n_routes)
    ckpt_gb = np.round(rng.uniform(4.0, 28.0, size=n_routes), 1)
    return seeds, ckpt_gb


def flash_crowd(*, n_routes: int = 8, fleet: str = "2xh100+2xa100+2xl40s",
                horizon_s: float = DAY, seed: int = 100,
                base_rate_hr: float = 40.0, spike_x: float = 40.0,
                spike_start_s: float = 13 * 3600.0,
                spike_width_s: float = 1800.0) -> FleetTrace:
    """Viral-moment day: route 0's rate multiplies by ``spike_x`` for
    ``spike_width_s`` (sharp rise, exponential cool-down) on top of the
    shared diurnal baseline."""
    rng = np.random.default_rng(seed)
    seeds, ckpt = _route_plan(rng, n_routes)
    routes = []
    for i in range(n_routes):
        child = np.random.default_rng(int(seeds[i]))
        if i == 0:
            tail_s = 2.0 * spike_width_s     # exponential cool-down span

            def rate(t: np.ndarray) -> np.ndarray:
                r = _diurnal_hr(base_rate_hr, t)
                dt = t - spike_start_s
                hot = (dt >= 0.0) & (dt < spike_width_s)
                cool = (dt >= spike_width_s) & (dt < spike_width_s + tail_s)
                boost = np.where(hot, spike_x, 0.0) + np.where(
                    cool, spike_x * np.exp(-(dt - spike_width_s)
                                           / (0.35 * spike_width_s)), 0.0)
                return r * (1.0 + boost)

            rmax = base_rate_hr * (1.0 + spike_x)
        else:
            def rate(t: np.ndarray) -> np.ndarray:
                return _diurnal_hr(base_rate_hr, t)

            rmax = base_rate_hr
        routes.append(RouteTrace(
            route_id=f"r{i}", arrivals_s=_thinned(child, rate, rmax,
                                                  horizon_s),
            checkpoint_gb=float(ckpt[i])))
    return FleetTrace(name="flash-crowd", fleet=fleet, horizon_s=horizon_s,
                      routes=tuple(routes), seed=seed)


def product_launch(*, n_routes: int = 8,
                   fleet: str = "2xh100+2xa100+2xl40s",
                   horizon_s: float = DAY, seed: int = 100,
                   launch_s: float = 9 * 3600.0,
                   launch_rate_hr: float = 600.0,
                   steady_rate_hr: float = 60.0,
                   decay_s: float = 4 * 3600.0,
                   base_rate_hr: float = 40.0) -> FleetTrace:
    """Launch day: route 0 has EXACTLY zero traffic before ``launch_s``
    (the model is not public yet), then a surge at ``launch_rate_hr``
    decaying toward ``steady_rate_hr``; other routes run the diurnal
    baseline."""
    rng = np.random.default_rng(seed)
    seeds, ckpt = _route_plan(rng, n_routes)
    routes = []
    for i in range(n_routes):
        child = np.random.default_rng(int(seeds[i]))
        if i == 0:
            def rate(t: np.ndarray) -> np.ndarray:
                dt = t - launch_s
                surge = steady_rate_hr + (launch_rate_hr - steady_rate_hr) \
                    * np.exp(-np.maximum(dt, 0.0) / decay_s)
                return np.where(dt >= 0.0, surge, 0.0)

            rmax = launch_rate_hr
        else:
            def rate(t: np.ndarray) -> np.ndarray:
                return _diurnal_hr(base_rate_hr, t)

            rmax = base_rate_hr
        routes.append(RouteTrace(
            route_id=f"r{i}", arrivals_s=_thinned(child, rate, rmax,
                                                  horizon_s),
            checkpoint_gb=float(ckpt[i])))
    return FleetTrace(name="product-launch", fleet=fleet,
                      horizon_s=horizon_s, routes=tuple(routes), seed=seed)


def regional_outage(*, n_routes: int = 8,
                    fleet: str = "2xh100+2xa100+2xl40s",
                    horizon_s: float = DAY, seed: int = 100,
                    base_rate_hr: float = 60.0,
                    outage_start_s: float = 11 * 3600.0,
                    outage_s: float = 3600.0,
                    recovery_x: float = 3.0,
                    recovery_s: float = 1800.0) -> FleetTrace:
    """Upstream-region loss: EVERY route sees zero arrivals during
    [outage_start, outage_start + outage_s), then the deferred demand
    returns as a ``recovery_x`` surge over ``recovery_s`` before
    settling back to the diurnal baseline."""
    rng = np.random.default_rng(seed)
    seeds, ckpt = _route_plan(rng, n_routes)
    out0, out1 = outage_start_s, outage_start_s + outage_s

    def rate(t: np.ndarray) -> np.ndarray:
        r = _diurnal_hr(base_rate_hr, t)
        dark = (t >= out0) & (t < out1)
        surge = (t >= out1) & (t < out1 + recovery_s)
        return np.where(dark, 0.0, r * np.where(surge, recovery_x, 1.0))

    rmax = base_rate_hr * recovery_x
    routes = []
    for i in range(n_routes):
        child = np.random.default_rng(int(seeds[i]))
        routes.append(RouteTrace(
            route_id=f"r{i}", arrivals_s=_thinned(child, rate, rmax,
                                                  horizon_s),
            checkpoint_gb=float(ckpt[i])))
    return FleetTrace(name="regional-outage", fleet=fleet,
                      horizon_s=horizon_s, routes=tuple(routes), seed=seed)


GENERATORS: Dict[str, Callable[..., FleetTrace]] = {
    "flash-crowd": flash_crowd,
    "product-launch": product_launch,
    "regional-outage": regional_outage,
}
