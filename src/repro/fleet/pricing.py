"""Dollar accounting and spot preemption: the price of standing warmth.

The paper's breakeven model (Eq. 12-13) prices parking in joules, but
the decision operators actually buy is dollars.  This module converts a
run's metered power-state timeline into money under the catalog's
purchase tiers, and models the failure mode that makes the cheap tier
cheap: spot revocation.

Billing semantics (the tier model docs/COST.md walks through):

  * ``on_demand`` and ``spot`` bill only POWERED-ON seconds -- every
    metered state except SLEEP and OFF.  Gating a device to sleep (or a
    preemption forcing it OFF) releases the rental; that is the dollar
    face of the parking tax, and it is what makes power gating show up
    on the cost axis at all.
  * ``reserved`` bills the whole horizon regardless of power state: the
    commitment is paid for whether the device sleeps or not, in exchange
    for a lower rate.

  * energy dollars reuse the per-zone tariff pricing
    (``catalog.energy_cost_usd``) that ``FleetResult.energy_usd``
    already carries -- ``cost_usd = gpu_hours_usd + energy_usd``.

Every reduction is ``math.fsum`` (correctly rounded regardless of
summand order), so the per-device / per-zone decompositions sum back to
the totals and agree across the event-loop and vectorized engines to
the same <=1e-9 rel the energy anchors hold.

Preemption (``PreemptionModel``) draws seeded spot revocations as pure
data; the engines replay them as events.  Only ``spot``-tier devices
are revocable.  The draw is per-device seeded (seed mixed with a CRC of
the instance id), so adding a device to the fleet never reshuffles
another device's fault times.
"""
from __future__ import annotations

import dataclasses
import math
import random
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.fleet.catalog import (DeviceInstance, energy_cost_usd, get_mix,
                                 normalize_tier)

# Power states whose seconds are NOT billed under usage tiers
# (on_demand / spot): the device is released back to the provider.
UNBILLED_STATES = ("sleep", "off")


def billed_seconds(durations_s: Dict[str, float], tier: str) -> float:
    """Rentable seconds in a per-state duration dict under ``tier``.

    ``reserved`` pays for every metered second (the commitment runs
    through sleep); usage tiers pay only for powered-on states.  fsum
    over sorted keys, so the result is correctly rounded and identical
    across engines whatever order their state dicts iterate in.
    """
    t = normalize_tier(tier)
    keys = sorted(k for k in durations_s if k != "total")
    if t == "reserved":
        return math.fsum(durations_s[k] for k in keys)
    return math.fsum(durations_s[k] for k in keys
                     if k not in UNBILLED_STATES)


def device_gpu_usd(device: DeviceInstance, durations_s: Dict[str, float],
                   tier: str) -> float:
    """Rental dollars for one device: its tier rate x billed hours."""
    t = normalize_tier(tier)
    return device.sku.price_usd_per_hr(t) * billed_seconds(durations_s,
                                                           t) / 3600.0


def device_tier_map(devices: Sequence[DeviceInstance],
                    default_tier: str = "on_demand") -> Dict[str, str]:
    """instance_id -> purchase tier: the device's own pinned tier
    (``DeviceInstance.tier``) or the scenario default, canonical --
    the exact inheritance shape of ``FleetScenario.device_zones``."""
    dt = normalize_tier(default_tier)
    return {d.instance_id: (normalize_tier(d.tier) if d.tier else dt)
            for d in devices}


def tier_billed_seconds(devices: Sequence[DeviceInstance],
                        reports: Sequence,
                        default_tier: str = "on_demand"
                        ) -> Dict[str, float]:
    """tier -> fsum of billed seconds across the devices billed under
    it: the scalar the fused metering kernel also emits per tier, and
    the cross-engine comparable for powered-on billing time.  Same
    report duck-typing as ``price_fleet``."""
    tiers = device_tier_map(devices, default_tier)
    out: Dict[str, float] = {}
    for t in sorted(set(tiers.values())):
        out[t] = math.fsum(
            billed_seconds(r.durations_s, t)
            for r in sorted(reports, key=lambda r: r.instance_id)
            if tiers[r.instance_id] == t)
    return out


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """One run's dollars, decomposed three ways.

    ``cost_usd = gpu_hours_usd + energy_usd`` exactly (one addition);
    ``device_cost_usd`` fsums to ``cost_usd`` and ``zone_cost_usd``
    fsums over ``device_cost_usd`` (both to float rounding, property-
    tested at 1e-12 rel like the zone decompositions).
    """
    cost_usd: float                       # total: rental + electricity
    gpu_hours_usd: float                  # rental: tier rate x billed hrs
    energy_usd: float                     # electricity at per-zone tariffs
    device_gpu_usd: Dict[str, float]      # instance_id -> rental dollars
    device_cost_usd: Dict[str, float]     # instance_id -> rental + energy
    zone_cost_usd: Dict[str, float]       # zone -> fsum of its devices
    device_tiers: Dict[str, str]          # instance_id -> tier billed under


def price_fleet(devices: Sequence[DeviceInstance], reports: Sequence,
                *, default_tier: str = "on_demand",
                energy_usd: float = 0.0) -> CostBreakdown:
    """Price a finished run from its device reports.

    ``reports`` duck-types ``fleetsim.DeviceReport``: each needs
    ``instance_id``, ``durations_s`` (per-state seconds), ``zone`` and
    ``energy_wh["total"]``.  ``energy_usd`` is the engine's own
    electricity total (the existing ``FleetResult.energy_usd``), passed
    through so ``cost_usd`` decomposes against the exact number the
    engines already anchor bit-exactly; the per-device energy dollars
    here re-price each device at its zone tariff and fsum back to it
    within float rounding.
    """
    by_id = {d.instance_id: d for d in devices}
    tiers = device_tier_map(devices, default_tier)
    gpu: Dict[str, float] = {}
    dev_cost: Dict[str, float] = {}
    dev_zone: Dict[str, str] = {}
    for r in reports:
        did = r.instance_id
        gpu[did] = device_gpu_usd(by_id[did], r.durations_s, tiers[did])
        dev_cost[did] = gpu[did] + energy_cost_usd(r.energy_wh["total"],
                                                   get_mix(r.zone))
        dev_zone[did] = get_mix(r.zone).zone
    zones = sorted(set(dev_zone.values()))
    zone_cost = {z: math.fsum(dev_cost[did] for did in sorted(dev_cost)
                              if dev_zone[did] == z) for z in zones}
    gpu_total = math.fsum(gpu[did] for did in sorted(gpu))
    return CostBreakdown(
        cost_usd=gpu_total + energy_usd,
        gpu_hours_usd=gpu_total,
        energy_usd=energy_usd,
        device_gpu_usd=gpu,
        device_cost_usd=dev_cost,
        zone_cost_usd=zone_cost,
        device_tiers=tiers)


# ---------------------------------------------------------------------------
# Spot preemption: seeded revocation draws (pure data; engines replay).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Revocation:
    """One spot revocation: the provider reclaims ``device_id``.

    The warning lands at ``warn_at_s`` (capacity planners stop placing
    on the device), power is cut at ``off_at_s`` (in-flight work is
    orphaned and re-queued), and the device -- if the outage is finite
    -- returns to BARE at ``restore_at_s``.
    """
    device_id: str
    off_at_s: float
    warning_s: float = 120.0
    outage_s: float = math.inf

    def __post_init__(self):
        if self.off_at_s < 0.0 or self.warning_s < 0.0:
            raise ValueError("revocation times must be non-negative")
        if self.outage_s <= 0.0:
            raise ValueError("outage must be positive")

    @property
    def warn_at_s(self) -> float:
        return max(self.off_at_s - self.warning_s, 0.0)

    @property
    def restore_at_s(self) -> float:
        return self.off_at_s + self.outage_s


@dataclasses.dataclass(frozen=True)
class PreemptionModel:
    """Seeded spot-revocation process.

    ``draw`` is PURE: same (model, fleet, horizon) -> same event list,
    so the event-loop and any replay engine inject identical faults.
    Each spot device runs an independent exponential clock at
    ``rate_per_device_day`` revocations per device-day, seeded from
    ``seed`` mixed with a CRC of its instance id -- growing the fleet
    never reshuffles an existing device's fault times.  The next draw
    starts after the previous outage ends (a device cannot be revoked
    while it is already gone).  ``schedule`` short-circuits the process
    with hand-pinned revocations (fault-injection tests).
    """
    rate_per_device_day: float = 0.0
    warning_s: float = 120.0
    outage_s: float = math.inf
    seed: int = 0
    schedule: Optional[Tuple[Revocation, ...]] = None

    def __post_init__(self):
        if self.rate_per_device_day < 0.0:
            raise ValueError("preemption rate must be non-negative")
        if self.warning_s < 0.0:
            raise ValueError("warning window must be non-negative")
        if self.outage_s <= 0.0:
            raise ValueError("outage must be positive")

    def draw(self, devices: Sequence[DeviceInstance],
             tiers: Dict[str, str], horizon_s: float) -> List[Revocation]:
        """The run's revocations, sorted by (off time, device id).

        Only ``spot``-tier devices (per ``tiers``, the resolved
        instance_id -> tier map) are revocable; revocations whose OFF
        lands at/after the horizon are dropped.
        """
        if self.schedule is not None:
            evs = [r for r in self.schedule if r.off_at_s < horizon_s]
            return sorted(evs, key=lambda r: (r.off_at_s, r.device_id))
        if self.rate_per_device_day <= 0.0:
            return []
        rate_per_s = self.rate_per_device_day / 86400.0
        out: List[Revocation] = []
        for d in devices:
            did = d.instance_id
            if tiers.get(did) != "spot":
                continue
            rng = random.Random((self.seed << 32)
                                ^ zlib.crc32(did.encode("utf-8")))
            t = rng.expovariate(rate_per_s)
            while t < horizon_s:
                out.append(Revocation(did, off_at_s=t,
                                      warning_s=self.warning_s,
                                      outage_s=self.outage_s))
                restore = t + self.outage_s
                if not math.isfinite(restore) or restore >= horizon_s:
                    break
                t = restore + rng.expovariate(rate_per_s)
        return sorted(out, key=lambda r: (r.off_at_s, r.device_id))
