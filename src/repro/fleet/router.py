"""Request routing + consolidation across a heterogeneous fleet.

Routing answers: which device serves the next request for model m?
The strategies span the design space the paper's cluster-scale question
opens:

  * warm-first      -- never cold-start when a warm replica exists;
                       placement falls back to least-loaded.
  * least-loaded    -- classic load balancing, blind to warmth (the
                       baseline that shows why energy-aware routing
                       matters: it sprays cold starts).
  * energy-greedy   -- myopic joules: place a cold model where
                       (above-bare load energy + marginal parking
                       energy until the expected next arrival) is
                       minimal.  "Marginal" is the key word: a device
                       that already has a live context has paid its
                       DVFS step, so packing there parks for free.
  * breakeven-aware -- architecture-aware steady state: adds the
                       per-arrival-period ski-rental cost
                       min(step * E[gap], reload) so models with
                       sub-breakeven traffic land on low-step devices
                       (A100) and hot models on fast-loading ones.
  * slo-aware       -- energy min subject to a p99 added-latency
                       budget: estimates each candidate's queue wait +
                       cold-start time from live slot occupancy and
                       loader backlog, routes energy-greedy inside the
                       budget, latency-greedy when nothing fits.

  * carbon-aware    -- slo-aware's latency machinery with the cold-
                       placement score priced in kgCO2e against the
                       run's grid-intensity trace (fleet/carbon.py):
                       the immediate load burst and near-term parking
                       are priced at the CURRENT intensity window, the
                       eventual reload at the daily mean -- so high-
                       intensity hours push placements onto devices
                       that park at zero marginal watts, and cold
                       starts drift toward low-intensity windows.

Consolidation is the placement half: periodically migrate parked models
off lightly-packed devices onto already-on devices with room, so the
drained device falls back to ``p_base_w``.  The benefit side of the
cost test is exact, not estimated: without the migration the source
keeps its context until its LAST armed idle timeout fires, so draining
it now saves ``dvfs_step_w * (max evict_at - now)``.  In carbon-aware
mode the same windows are integrated against the intensity trace, so a
migration whose load burst lands in a trough but whose saving spans the
evening peak clears the margin earlier -- deferrable packing work
shifts into low-intensity windows without changing the safety rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.core.breakeven import breakeven_seconds
from repro.core.power_states import PowerState, gate_breakeven_s
from repro.fleet.carbon import CarbonTrace, _J_PER_KWH
from repro.fleet.catalog import (above_base_load_j, marginal_park_w,
                                 wake_cost_j, wake_cost_kg)
from repro.fleet.cluster import Cluster


def _above_base_load_j(cluster: Cluster, model_id: str, device_id: str
                       ) -> float:
    """Above-bare reload energy, from the shared catalog cost model (one
    formula for routers, consolidator, and autoscaler placement)."""
    return above_base_load_j(cluster.devices[device_id],
                             cluster.loader_for(model_id, device_id))


class Router:
    """Picks a device for one request; stateless across requests (all
    adaptivity lives in the cluster's rate estimators)."""

    name = "base"

    def choose(self, model_id: str, t_s: float, cluster: Cluster) -> str:
        """Pick the device that serves this request.

        Args:
          model_id: the requested model (registered on the cluster).
          t_s:      arrival time (sim seconds).
          cluster:  live fleet state (residency, occupancy, rates).
        Returns: the chosen device's ``instance_id``."""
        raise NotImplementedError

    # -- shared helpers -----------------------------------------------------
    def _placeable(self, model_id: str, cluster: Cluster) -> List[str]:
        """Placement candidates: devices that fit, revoked ones (spot
        warning/outage in force) excluded.  Best-effort fallbacks relax
        fit before they relax revocation -- only an all-revoked fleet
        places on a revoked device (requests must route SOMEWHERE for
        the conservation invariant; they will be orphaned and re-queued
        when the OFF lands)."""
        alive = [did for did in sorted(cluster.devices)
                 if did not in cluster.revoked]
        fits = [did for did in alive if cluster.fits(did, model_id)]
        return fits or alive or sorted(cluster.devices)   # best effort

    def _least_loaded(self, model_id: str, cluster: Cluster) -> str:
        return min(self._placeable(model_id, cluster),
                   key=lambda did: (cluster.occupancy(did),
                                    -cluster.free_vram_gb(did), did))

    def _warm(self, model_id: str, cluster: Cluster) -> Optional[str]:
        """Least-pressure member of the warm replica set.  With one
        replica this is the old single-location behaviour; once the
        autoscaler grows the set, every router spreads requests to the
        member with the shortest queue (waiters, then busy slots, then
        stable id) instead of hot-spotting the first device.  A replica
        still mid-load counts as a FULL pool of busy slots, so it never
        outranks a resident replica with free capacity (requests would
        otherwise park behind the load residual)."""
        locs = cluster.locations(model_id, include_loading=True)
        # a warm replica on a revoked device is about to vanish: do not
        # route new work there (unless it is the only copy anywhere)
        live = [d for d in locs if d not in cluster.revoked]
        locs = live or locs
        if not locs:
            return None

        def key(d: str):
            m = cluster.managers[d].models.get(model_id)
            loading_penalty = 0 if (m is not None and m.resident) \
                else cluster.decode_slots(d)
            return (cluster.waiting_requests(d, model_id),
                    cluster.busy_slots(d, model_id) + loading_penalty, d)

        return min(locs, key=key)

    def _joule_score(self, model_id: str, cluster: Cluster, *,
                     steady_state: bool):
        """Scoring key for cold placement, shared by the energy-aware
        routers: above-bare load energy + MARGINAL parking energy until
        the expected next arrival (a context-on device has already paid
        its DVFS step, so packing there parks for free).  With
        ``steady_state`` the per-arrival-period ski-rental cost
        min(step * E[gap], reload) is added, making low-step devices win
        for sub-breakeven traffic.  A GATED (sleeping) candidate also
        pays its wake cost -- ramp energy above sleep plus the
        bare-minus-sleep delta over the expected hold -- so routers only
        wake a device when cheaper watts genuinely beat staying on an
        already-awake one."""
        gap = cluster.rates[model_id].expected_gap_s()

        def score(did: str) -> Tuple[float, str]:
            prof = cluster.devices[did].profile
            ld = cluster.loader_for(model_id, did)
            load_j = _above_base_load_j(cluster, model_id, did)
            step_w = marginal_park_w(cluster.devices[did],
                                     cluster.context_on(did))
            t_star = breakeven_seconds(ld, prof, paper_convention=False)
            park_j = step_w * min(gap, t_star)
            wake_j = 0.0
            if cluster.power_state(did) is PowerState.SLEEP:
                wake_j = wake_cost_j(cluster.devices[did],
                                     min(gap, t_star))
            if steady_state:
                return (load_j + wake_j
                        + min(step_w * gap, load_j + park_j), did)
            return (load_j + wake_j + park_j, did)

        return score


class WarmFirstRouter(Router):
    """Never cold-start when a warm replica exists (the parking tax is
    already paid there -- Eq. 1's context term); placement for cold
    models falls back to least-loaded."""

    name = "warm-first"

    def choose(self, model_id, t_s, cluster) -> str:
        warm = self._warm(model_id, cluster)
        if warm is not None:
            return warm
        return self._least_loaded(model_id, cluster)


class LeastLoadedRouter(Router):
    """Classic load balancing, blind to warmth: the baseline that
    sprays cold starts and shows why energy-aware routing matters."""

    name = "least-loaded"

    def choose(self, model_id, t_s, cluster) -> str:
        return self._least_loaded(model_id, cluster)


class EnergyGreedyRouter(Router):
    """Myopic joules for the imminent cold start + park-until-next-arrival."""

    name = "energy-greedy"
    steady_state = False

    def choose(self, model_id, t_s, cluster) -> str:
        warm = self._warm(model_id, cluster)
        if warm is not None:
            return warm
        return min(self._placeable(model_id, cluster),
                   key=self._joule_score(model_id, cluster,
                                         steady_state=self.steady_state))


class BreakevenRouter(EnergyGreedyRouter):
    """Architecture-aware breakeven routing (ISSUE tentpole variant):
    immediate load cost + expected per-period ski-rental cost, so the
    device whose (dvfs_step_w, t_load) pair minimizes expected joules
    wins even when every candidate is currently bare."""

    name = "breakeven-aware"
    steady_state = True


class SLOAwareRouter(Router):
    """Energy minimization subject to a per-request latency budget.

    The router estimates the added latency (queue wait + cold start)
    a request would see on every candidate device, from the live
    concurrency state the fleet event loop publishes through the
    cluster: loader-channel backlog, decode-slot occupancy, and
    per-model wait-queue depth.  Among devices whose estimate fits the
    budget it picks the energy-greedy choice (warm replicas are free);
    when NO device fits -- e.g. the model is cold everywhere and its
    load alone blows the budget -- it degrades to latency-greedy, which
    is what keeps the realized p99 pinned near the best achievable
    rather than wherever cheap joules happen to live.  ``budget_s`` is
    the p99 added-latency target the operator configures."""

    name = "slo-aware"

    def __init__(self, budget_s: float = 60.0, *, headroom: float = 1.0):
        if budget_s <= 0:
            raise ValueError("budget must be positive")
        self.budget_s = budget_s
        self.headroom = headroom      # <1.0 routes against a tighter bar

    # -- latency estimate ---------------------------------------------------
    def estimated_wait_s(self, model_id: str, device_id: str, t_s: float,
                         cluster: Cluster) -> float:
        """Added latency one request would see on ``device_id`` NOW:
        queue rounds for a warm replica, load residual for a loading
        one, loader-channel backlog + own load when cold.

        Args: as ``Router.choose`` plus the candidate ``device_id``.
        Returns: estimated seconds of queue wait + cold-start time."""
        m = cluster.managers[device_id].models.get(model_id)
        svc = cluster.service_model
        svc_s = 0.0
        if svc is not None:
            busy = cluster.busy_slots(device_id, model_id)
            svc_s = svc.request_service_s(cluster.specs[model_id],
                                          cluster.devices[device_id],
                                          max(busy, 1))
        waiting = cluster.waiting_requests(device_id, model_id)
        slots = max(cluster.decode_slots(device_id), 1)
        if m is not None and m.resident:
            pool_full = cluster.busy_slots(device_id, model_id) >= slots
            if not pool_full and waiting == 0:
                return 0.0
            # FIFO rounds through the batch until our turn comes up
            return math.ceil((waiting + 1) / slots) * svc_s
        if m is not None and m.loading:
            # the load is in flight: only its residual can delay us
            # (loads queued behind it start after we already serve)
            return (cluster.load_residual_s(device_id, t_s)
                    + (waiting // slots) * svc_s)
        # cold: whatever the loader channel holds, then our own load
        # (excluded from the backlog if a prior request already queued
        # it).  A still-gated device adds its wake latency up front; a
        # wake ramp already in flight is counted by the channel residual.
        backlog = cluster.load_backlog_s(device_id, t_s,
                                         exclude_model=model_id)
        if cluster.power_state(device_id) is PowerState.SLEEP:
            backlog += cluster.devices[device_id].profile.wake_latency_s
        return backlog + cluster.loader_for(model_id, device_id).t_load_s

    def _cold_score(self, model_id: str, t_s: float, cluster: Cluster):
        """Scoring key used for cold placement among budget-feasible
        candidates; subclasses swap the objective (joules here, kgCO2e
        in ``CarbonAwareRouter``) without touching the SLO machinery."""
        return self._joule_score(model_id, cluster, steady_state=True)

    def choose(self, model_id, t_s, cluster) -> str:
        warm = set(cluster.locations(model_id, include_loading=True))
        # pending scale-outs are FUTURE capacity: their load is already
        # paid for, so they compete at zero joules -- the router parks
        # requests behind a landing replica instead of cold-starting a
        # third copy elsewhere
        pending = set(cluster.pending_scaleouts(model_id))
        cands = sorted(set(self._placeable(model_id, cluster))
                       | warm | pending)
        # spot warning/outage: drop revoked candidates (their warmth or
        # pending capacity is about to vanish) unless nothing else is up
        live = [d for d in cands if d not in cluster.revoked]
        cands = live or cands
        est = {d: self.estimated_wait_s(model_id, d, t_s, cluster)
               for d in cands}
        budget = self.budget_s * self.headroom
        ok = [d for d in cands if est[d] <= budget]
        if not ok:                    # infeasible: minimize latency instead
            return min(cands, key=lambda d: (est[d], d))
        score = self._cold_score(model_id, t_s, cluster)

        def key(d: str):
            joules = 0.0 if d in warm or d in pending else score(d)[0]
            return (joules, est[d], d)

        return min(ok, key=key)


class CarbonAwareRouter(SLOAwareRouter):
    """SLO-aware routing with the cold-placement objective in kgCO2e.

    Keeps slo-aware's entire latency estimate/budget machinery (warm
    replicas and pending scale-outs still route free) but prices the
    cold-placement ski rental against the run's grid-intensity trace:

      score(d) = load_now + min(park_through, park_T* + reload_later)

    where ``load_now`` is the above-bare load burst integrated over
    [t, t+t_load] at the CURRENT intensity, ``park_through`` holds the
    marginal DVFS step until the expected next arrival (trace-priced),
    and ``reload_later`` prices the eventual reload at the daily-mean
    intensity (its phase is unknown).  With a flat trace every window
    weighs the same and the score reduces to slo-aware's joule score
    (delegated exactly, so flat-trace runs are trace-identical).

    Args:
    Per-device zones (the follow-the-sun tentpole): when the fleet
    spans electricity zones, ``run_fleet`` binds each device's LOCAL
    intensity trace on the cluster (``cluster.device_traces``) and the
    score prices every candidate against its own zone's trace -- a cold
    start during Germany's evening peak lands on the US device whose
    solar trough is live, even though both candidates are identical
    hardware.  ``zone_aware=False`` restores zone-blind scoring (every
    candidate priced against the scenario trace), which is the
    counterfactual the benchmarks compare against.  Single-zone fleets
    bind the SAME trace object to every device, so this path is
    bit-identical to the pre-zone scoring.

    Args:
      budget_s:   p99 added-latency budget (as ``SLOAwareRouter``).
      headroom:   route against ``budget_s * headroom``.
      trace:      ``CarbonTrace`` to price against; ``run_fleet`` binds
                  the scenario's resolved trace automatically.
      zone_aware: price candidates at their device-local intensity when
                  the cluster carries per-device traces (default True).
    """

    name = "carbon-aware"

    def __init__(self, budget_s: float = 60.0, *, headroom: float = 1.0,
                 trace: Optional[CarbonTrace] = None,
                 zone_aware: bool = True):
        super().__init__(budget_s, headroom=headroom)
        self.carbon_trace = trace
        self.zone_aware = zone_aware

    def set_carbon_trace(self, trace: CarbonTrace) -> None:
        """Bind the run's intensity trace (called by ``run_fleet``)."""
        self.carbon_trace = trace

    def _cold_score(self, model_id, t_s, cluster):
        base = self.carbon_trace
        per_dev = cluster.device_traces if self.zone_aware else {}
        # delegate to the joule score when no trace can change the
        # ranking: none bound anywhere, or one shared flat trace (a
        # flat trace scales every candidate by the same constant)
        distinct = {id(t): t for t in per_dev.values()}
        if base is not None:
            distinct.setdefault(id(base), base)
        traces = list(distinct.values())
        if not traces or (len(traces) == 1 and traces[0].is_flat):
            return super()._cold_score(model_id, t_s, cluster)
        gap = cluster.rates[model_id].expected_gap_s()

        def score(did: str) -> Tuple[float, str]:
            trace = per_dev.get(did) or base
            prof = cluster.devices[did].profile
            ld = cluster.loader_for(model_id, did)
            load_j = _above_base_load_j(cluster, model_id, did)
            step_w = marginal_park_w(cluster.devices[did],
                                     cluster.context_on(did))
            t_star = breakeven_seconds(ld, prof, paper_convention=False)
            t_load = ld.t_load_s
            t_warm = t_s + t_load             # the replica lands here
            load_now = (load_j / t_load) \
                * trace.integral(t_s, t_warm) / _J_PER_KWH \
                if t_load > 0 else 0.0
            park_through = step_w \
                * trace.integral(t_warm, t_warm + gap) / _J_PER_KWH
            park_then_reload = (
                step_w * trace.integral(t_warm, t_warm + min(gap, t_star))
                / _J_PER_KWH
                + load_j * trace.daily_mean_kg_per_kwh / _J_PER_KWH)
            wake_kg = 0.0
            if cluster.power_state(did) is PowerState.SLEEP:
                wake_kg = wake_cost_kg(cluster.devices[did], trace,
                                       t_s, t_warm, min(gap, t_star))
            return (load_now + wake_kg
                    + min(park_through, park_then_reload), did)

        return score


ROUTERS = {r.name: r for r in
           (WarmFirstRouter(), LeastLoadedRouter(), EnergyGreedyRouter(),
            BreakevenRouter(), SLOAwareRouter(), CarbonAwareRouter())}


def get_router(name: str) -> Router:
    """Look up a shared router instance by ``name`` (KeyError with the
    available names otherwise).  Instances are stateless across requests
    -- all adaptivity lives in the cluster's rate estimators -- so
    sharing them between runs is safe; ``run_fleet`` re-binds the carbon
    trace per run."""
    if name not in ROUTERS:
        raise KeyError(f"unknown router {name!r}; have {sorted(ROUTERS)}")
    return ROUTERS[name]


# ---------------------------------------------------------------------------
# Consolidation (placement pass).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Move:
    model_id: str
    src: str
    dst: str


class Consolidator:
    """Periodic packing pass: drain whole devices whose parked residents
    fit elsewhere, whenever the counterfactual saving beats the cost.

    Saving: without the migration the source keeps its context until its
    LAST armed idle timeout fires -- ``src dvfs_step_w * (last evict_at
    - now)``.  Cost: the above-bare migration load energy PLUS the
    destination-side context extension: the migrated replica re-arms a
    fresh timeout on the target, which can keep the target's (possibly
    larger) DVFS step up beyond the window its own residents had armed.
    All windows are capped at ``lookahead_s`` so always-on (infinite)
    timeouts compare finitely.  Draining is all-or-nothing per source
    device -- a partial move saves nothing, the source's context stays
    up for the models left behind.

    Carbon-aware mode (``carbon_aware=True``): identical plan structure
    and safety rules, but every power-x-window product in the benefit /
    cost comparison is integrated against the run's grid-intensity
    trace (kgCO2e instead of joules).  A migration burst in a trough
    that drains a context through the evening peak clears the margin
    earlier; the same migration proposed AT the peak is priced up and
    deferred -- consolidation work shifts into low-intensity windows.
    With a flat trace both sides scale by the same constant, so the
    decisions are exactly the energy decisions.  In a multi-zone fleet
    each window is priced at the owning device's LOCAL trace (source
    benefit at the source's zone, destination cost at the
    destination's), cross-zone moves pay the WAN checkpoint-transfer
    energy and its latency stretches the priced load window -- so
    consolidation also drifts parked models toward cleaner grids when
    the margin clears.

    Power gating (``gate_drained_devices=True``): the packing pass is
    what CREATES fully drained devices, so the same controller also
    decides when a drained device stops paying even ``p_base_w``: a
    device settled at bare for at least ``gate_margin x T*_gate``
    (``power_states.gate_breakeven_s`` -- the device-level ski rental:
    one wake cycle's extra energy over the bare-minus-sleep saving
    rate) is put to SLEEP.  Waiting out T*_gate before gating is the
    classic 2-competitive rent-then-buy rule: whatever the adversarial
    next placement does, the realized cost is at most twice the
    clairvoyant's.  Routers price the wake (latency + energy) into cold
    placement, so gated devices are only woken when genuinely worth it.

    Args:
      period_s:     planning cadence (sim seconds).
      margin:       require benefit >= margin * cost.
      lookahead_s:  cap on every counted window.
      carbon_aware: price benefit/cost in kgCO2e over the bound trace
                    (``run_fleet`` binds ``set_carbon_trace``).
      gate_drained_devices: put bare-idle devices to SLEEP once their
                    idle exceeds the gating breakeven (off by default:
                    every pre-gating result is bit-identical).
      gate_margin:  gate after ``gate_margin x T*_gate`` of bare idle.
    """

    def __init__(self, *, period_s: float = 900.0, margin: float = 1.0,
                 lookahead_s: float = 2 * 3600.0,
                 carbon_aware: bool = False,
                 gate_drained_devices: bool = False,
                 gate_margin: float = 1.0):
        if period_s <= 0:
            raise ValueError("period must be positive")
        if gate_margin <= 0:
            raise ValueError("gate margin must be positive")
        self.period_s = period_s
        self.margin = margin     # require benefit >= margin * cost
        self.lookahead_s = lookahead_s
        self.carbon_aware = carbon_aware
        self.gate_drained_devices = gate_drained_devices
        self.gate_margin = gate_margin
        self.carbon_trace: Optional[CarbonTrace] = None

    def set_carbon_trace(self, trace: CarbonTrace) -> None:
        """Bind the run's intensity trace (called by ``run_fleet``);
        only consulted when ``carbon_aware`` is set."""
        self.carbon_trace = trace

    def plan(self, cluster: Cluster, now_s: float,
             busy: Optional[dict] = None) -> List[Move]:
        """Propose migrations; never increases instantaneous fleet idle
        power (targets are already context-on, sources fully drain).

        Args:
          cluster: live fleet state.
          now_s:   planning instant (sim seconds).
          busy:    device_id -> busy flag; busy devices are skipped on
                   both sides (never migrate under in-flight work).
        Returns: list of ``Move`` actions the event loop applies through
          the destination loader channels (racing requests re-checked
          there)."""
        busy = busy or {}
        free_slots = {did: cluster.free_slots(did)
                      for did in cluster.devices}
        free_vram = {did: cluster.free_vram_gb(did)
                     for did in cluster.devices}
        on = {did for did in cluster.devices if cluster.context_on(did)}

        # drain low-occupancy, high-step sources first
        sources = sorted(
            (did for did in on if not busy.get(did)),
            key=lambda did: (cluster.occupancy(did),
                             -cluster.devices[did].profile.dvfs_step_w, did))
        horizon = now_s + self.lookahead_s

        def cap(t: float) -> float:
            return min(t, horizon)

        def trace_of(did: str):
            """The trace pricing this device's windows in carbon mode:
            its zone-local trace when run_fleet bound per-device traces,
            else the scenario trace (single-zone fleets bind the same
            object everywhere, so decisions are bit-identical)."""
            if not self.carbon_aware:
                return None
            return cluster.device_traces.get(did) or self.carbon_trace

        def weigh(power_w: float, t0: float, t1: float, trace) -> float:
            """One benefit/cost term: power held over [t0, t1], in
            joules -- or kgCO2e (trace-integrated) in carbon mode.
            Both sides of the margin test use the same units, so the
            comparison is homogeneous either way."""
            if t1 <= t0:
                return 0.0
            if trace is None:
                return power_w * (t1 - t0)
            return trace.carbon_kg(power_w, t0, t1)

        def xfer_cost(model_id: str, src: str, dst: str, trace) -> float:
            """WAN checkpoint-shipping energy for a cross-zone move, in
            the margin test's units.  Its grid draw has no single zone
            or phase, so carbon mode prices it at the destination
            trace's daily mean (same convention as the router's
            eventual-reload term).  Zero within one zone."""
            _, xj = cluster.migration_transfer(model_id, src, dst)
            if xj == 0.0 or trace is None:
                return xj
            return xj * trace.daily_mean_kg_per_kwh / _J_PER_KWH

        # per-target context window: how long its OWN residents keep the
        # step up regardless of what we pack onto it
        win = {did: max((m.evict_at
                         for m in cluster.managers[did].models.values()
                         if m.resident), default=now_s)
               for did in cluster.devices}

        moves: List[Move] = []
        drained = set()
        for src in sources:
            mm = cluster.managers[src]
            residents = [m for m in mm.models.values() if m.resident]
            if not residents or any(m.loading for m in mm.models.values()):
                continue
            # autoscaler-held replicas are not packing fodder: the
            # controller paid their load to keep that capacity standing,
            # and a migration would strip the hold (the destination
            # re-arms a policy timeout) -- skip the device (drain is
            # all-or-nothing anyway)
            if any(m.held for m in residents):
                continue
            # counterfactual: src pays its step until the last armed
            # timeout fires (capped so always-on compares finitely)
            last_evict = max(m.evict_at for m in residents)
            # revoked devices (spot warning/outage) are never packing
            # targets -- capacity about to vanish, same as a drained
            # gate -- but a revoked SOURCE may still drain: moving its
            # residents out before the OFF lands is pure win
            targets = [did for did in
                       sorted(on - drained - {src} - cluster.revoked)
                       if not busy.get(did)]
            assignment: List[Move] = []
            cost_j = 0.0
            slots = dict(free_slots)
            vram = dict(free_vram)
            trial_win = dict(win)
            # loads serialize on each destination's queue; track when
            # each target frees up so multi-model drains are priced at
            # their real start/finish times, not all at `now`
            dst_free = {did: now_s for did in targets}
            last_start = now_s      # src keeps its step until the last
            ok = True               # resident unloads (migration start)
            for m in sorted(residents, key=lambda r: -r.vram_gb):
                placed = False
                for dst in sorted(targets,
                                  key=lambda d: (-vram[d], d)):
                    if slots[dst] >= 1 and vram[dst] >= m.vram_gb:
                        assignment.append(Move(m.model_id, src, dst))
                        ld = cluster.loader_for(m.model_id, dst)
                        dst_trace = trace_of(dst)
                        xfer_s, _ = cluster.migration_transfer(
                            m.model_id, src, dst)
                        t_start = dst_free[dst]
                        # cross-zone: the checkpoint ships over the WAN
                        # first, stretching the destination's load
                        # window exactly as start_migration will
                        t_done = t_start + xfer_s + ld.t_load_s
                        # above-bare load burst over its real window
                        # (joules: exactly above_base_load_j; carbon:
                        # the same watts against the trace)
                        p_above = max(
                            ld.p_load_w
                            - cluster.devices[dst].profile.p_base_w, 0.0)
                        cost_j += weigh(p_above, t_start, t_done,
                                        dst_trace)
                        cost_j += xfer_cost(m.model_id, src, dst,
                                            dst_trace)
                        # destination-side extension: the migrated
                        # replica re-arms on dst and may hold dst's step
                        # up past its own residents' window
                        dst_free[dst] = t_done
                        last_start = max(last_start, t_start)
                        timeout = cluster.preview_timeout_s(
                            m.model_id, dst, t_done)
                        armed_end = t_done + timeout
                        step_dst = cluster.devices[dst].profile.dvfs_step_w
                        cost_j += weigh(step_dst,
                                        cap(max(trial_win[dst], now_s)),
                                        cap(armed_end), dst_trace)
                        trial_win[dst] = max(trial_win[dst], armed_end)
                        slots[dst] -= 1
                        vram[dst] -= m.vram_gb
                        placed = True
                        break
                if not placed:
                    ok = False
                    break
            if not ok or not assignment:
                continue
            # realized benefit starts when the LAST resident leaves src
            benefit_j = weigh(cluster.devices[src].profile.dvfs_step_w,
                              cap(last_start), cap(last_evict),
                              trace_of(src))
            if benefit_j >= self.margin * cost_j:
                moves.extend(assignment)
                drained.add(src)
                free_slots, free_vram = slots, vram
                win = trial_win
        return moves

    def plan_gating(self, cluster: Cluster, now_s: float,
                    busy: Optional[dict] = None) -> List[str]:
        """Devices to put to SLEEP now (empty unless
        ``gate_drained_devices``): settled at bare, no runtime work, and
        bare-idle at least ``gate_margin x T*_gate`` (the device-level
        ski rental -- see the class docstring).  The event loop applies
        each through ``Cluster.gate_device``, which re-checks safety."""
        if not self.gate_drained_devices:
            return []
        busy = busy or {}
        out: List[str] = []
        for did in sorted(cluster.devices):
            if busy.get(did) or did in cluster.revoked:
                continue       # revoked: about to go OFF, gating is moot
            if cluster.power_state(did) is not PowerState.BARE:
                continue
            if cluster.occupancy(did) > 0:
                continue
            t_gate = gate_breakeven_s(cluster.devices[did].profile)
            if not math.isfinite(t_gate):
                continue
            if cluster.bare_idle_s(did, now_s) >= self.gate_margin * t_gate:
                out.append(did)
        return out
