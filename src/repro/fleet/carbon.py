"""Time-varying grid carbon intensity: traces, generators, zone presets.

The paper prices the parking tax at a FIXED grid intensity (kgCO2e =
kWh x scalar); real grids swing 3-10x over a day (solar duck curves,
night wind), so WHEN a joule is drawn changes its carbon cost even when
the joule count does not.  This module makes that first-class:

  * ``CarbonTrace`` -- a periodic piecewise-linear intensity curve
    i(t) in kgCO2e/kWh over a 24 h horizon, with exact integration
    (``integral``/``mean``/``carbon_kg``) so fleetsim can integrate
    emissions over the metered power timeline instead of multiplying
    total energy by a scalar.  A flat trace reproduces the scalar
    accounting bit-for-bit (the equivalence anchor fleetsim pins).
  * synthetic diurnal generators -- ``solar_duck`` (midday solar trough,
    evening ramp peak), ``wind_night`` (windy-night trough, midday
    peak), and ``flat_trace`` -- each scaled so the DAILY MEAN equals a
    target intensity, so swapping shapes never changes the zone's
    yearly-average bookkeeping.
  * per-zone presets -- ``trace_for_zone`` builds the preset shape named
    by ``catalog.ElectricityMix.trace_shape`` at that zone's mean
    intensity (ecologits per-zone-mix idiom, lifted to time-varying).

Every quantity is deterministic and exact for piecewise-linear traces:
segment integrals are trapezoids, no sampling error.  See
``docs/CARBON.md`` for the model and a worked example.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Iterable, List, Sequence, Tuple

DAY_S = 24 * 3600.0
_J_PER_KWH = 3.6e6


@dataclasses.dataclass(frozen=True)
class CarbonTrace:
    """Periodic piecewise-linear grid intensity i(t), kgCO2e/kWh.

    Args:
      name:     shape label (reported in FleetResult / bench rows).
      points:   ((t_s, kg_per_kwh), ...) knots with strictly increasing
                times in [0, period_s); intensity interpolates linearly
                between knots and wraps from the last knot back to the
                first (continuity across midnight).
      period_s: trace period; defaults to 24 h.

    A single-knot trace is constant (the scalar-accounting degenerate
    case); ``is_flat`` also detects multi-knot constant traces so the
    flat fast path stays exact whatever the construction.
    """
    name: str
    points: Tuple[Tuple[float, float], ...]
    period_s: float = DAY_S

    def __post_init__(self):
        pts = tuple((float(t), float(v)) for t, v in self.points)
        if not pts:
            raise ValueError("carbon trace needs at least one point")
        if self.period_s <= 0:
            raise ValueError("period must be positive")
        times = [t for t, _ in pts]
        if any(b <= a for a, b in zip(times, times[1:])):
            raise ValueError("trace times must be strictly increasing")
        if times[0] < 0 or times[-1] >= self.period_s:
            raise ValueError("trace times must lie in [0, period_s)")
        if any(v < 0 for _, v in pts):
            raise ValueError("carbon intensity cannot be negative")
        object.__setattr__(self, "points", pts)
        # knots extended to [0, period] (wrap value at both ends) +
        # prefix trapezoid integrals, so integral() is exact and O(log n)
        kt: List[float] = []
        kv: List[float] = []
        i0 = self._wrap_value_at_zero()
        if times[0] > 0.0:
            kt.append(0.0)
            kv.append(i0)
        for t, v in pts:
            kt.append(t)
            kv.append(v)
        kt.append(self.period_s)
        kv.append(i0)
        cum = [0.0]
        for i in range(1, len(kt)):
            cum.append(cum[-1]
                       + (kt[i] - kt[i - 1]) * (kv[i] + kv[i - 1]) / 2.0)
        object.__setattr__(self, "_kt", kt)
        object.__setattr__(self, "_kv", kv)
        object.__setattr__(self, "_cum", cum)

    def _wrap_value_at_zero(self) -> float:
        """Intensity at t=0 (and t=period) via the wrap segment from the
        last knot to the first knot of the next period."""
        (t0, v0), (tn, vn) = self.points[0], self.points[-1]
        if t0 == 0.0 or len(self.points) == 1:
            return v0
        span = (t0 + self.period_s) - tn        # > 0: times are strict
        return vn + (v0 - vn) * (self.period_s - tn) / span

    # -- point queries -------------------------------------------------------
    @property
    def is_flat(self) -> bool:
        """True when the intensity never varies: the scalar-accounting
        case, taken as an exact fast path everywhere."""
        v0 = self.points[0][1]
        return all(v == v0 for _, v in self.points)

    @property
    def daily_mean_kg_per_kwh(self) -> float:
        """Mean intensity over one full period (the zone's bookkeeping
        average; generators scale their shape so this hits the target)."""
        return self._cum[-1] / self.period_s

    def intensity_at(self, t_s: float) -> float:
        """i(t) in kgCO2e/kWh (periodic, linear between knots)."""
        if len(self.points) == 1:
            return self.points[0][1]
        p = t_s % self.period_s
        kt, kv = self._kt, self._kv
        j = bisect.bisect_right(kt, p) - 1
        j = min(max(j, 0), len(kt) - 2)
        span = kt[j + 1] - kt[j]
        if span <= 0:
            return kv[j]
        return kv[j] + (kv[j + 1] - kv[j]) * (p - kt[j]) / span

    # -- exact integration ---------------------------------------------------
    def _prefix(self, p: float) -> float:
        """F(p) = integral of i over [0, p] for p in [0, period]."""
        kt, kv, cum = self._kt, self._kv, self._cum
        j = bisect.bisect_right(kt, p) - 1
        j = min(max(j, 0), len(kt) - 2)
        dt = p - kt[j]
        if dt <= 0:
            return cum[j]
        return cum[j] + dt * (kv[j] + self.intensity_at(p)) / 2.0

    def integral(self, t0_s: float, t1_s: float) -> float:
        """Exact integral of i(t) dt over [t0, t1], in (kgCO2e/kWh)*s.

        Handles arbitrary horizons (whole periods factor out) and is the
        primitive every carbon quantity below reduces to."""
        if t1_s <= t0_s:
            return 0.0
        if len(self.points) == 1 or self.is_flat:
            return self.points[0][1] * (t1_s - t0_s)
        per, total = self.period_s, self._cum[-1]

        def g(t: float) -> float:
            k = math.floor(t / per)
            return k * total + self._prefix(t - k * per)

        return g(t1_s) - g(t0_s)

    def mean(self, t0_s: float, t1_s: float) -> float:
        """Mean intensity over [t0, t1] (i(t0) for an empty window)."""
        if t1_s <= t0_s:
            return self.intensity_at(t0_s)
        return self.integral(t0_s, t1_s) / (t1_s - t0_s)

    def carbon_kg(self, power_w: float, t0_s: float, t1_s: float) -> float:
        """kgCO2e of drawing a CONSTANT ``power_w`` over [t0, t1]:
        P * integral(i dt) / 3.6e6 (W*s per kWh)."""
        return power_w * self.integral(t0_s, t1_s) / _J_PER_KWH

    def carbon_for_segments(
            self, segments: Iterable[Tuple[float, float, float]]) -> float:
        """kgCO2e of a metered power timeline: ``segments`` is an
        iterable of (t0_s, t1_s, watts) with constant power per segment
        (exactly what ``EnergyMeter.timeline`` records).

        Flat traces take the energy-first path -- sum joules, multiply
        once -- so the result is bit-comparable with scalar accounting
        (``fsum`` keeps the sum exactly rounded either way)."""
        if self.is_flat:
            joules = math.fsum(p * (b - a) for a, b, p in segments)
            return joules * self.points[0][1] / _J_PER_KWH
        return math.fsum(self.carbon_kg(p, a, b) for a, b, p in segments)

    # -- transforms ----------------------------------------------------------
    def scaled_to_mean(self, target_kg_per_kwh: float) -> "CarbonTrace":
        """Rescale intensities so the daily mean equals ``target``
        (shape-preserving; how zone presets hit their mix average)."""
        mean = self.daily_mean_kg_per_kwh
        if mean <= 0.0:
            raise ValueError("cannot rescale an all-zero trace")
        k = target_kg_per_kwh / mean
        return CarbonTrace(self.name,
                           tuple((t, v * k) for t, v in self.points),
                           self.period_s)

    def shifted(self, dt_s: float) -> "CarbonTrace":
        """Phase-shift the curve: the returned trace reads
        ``self.intensity_at(t + dt_s)`` at time ``t`` -- how zone
        presets authored in LOCAL hours (solar trough ~13:00 local) are
        expressed on the fleet's shared sim clock.  A cyclic knot shift:
        same trapezoids in a different order, so the daily mean is
        preserved.  Identity (``self``) for flat traces or a whole-period
        shift, keeping single-zone runs bit-exact."""
        dt = dt_s % self.period_s
        if dt == 0.0 or self.is_flat:
            return self
        pts = []
        for t, v in self.points:
            nt = (t - dt) % self.period_s
            if nt >= self.period_s:         # fp guard on the mod wrap
                nt = 0.0
            pts.append((nt, v))
        pts.sort()
        return CarbonTrace(self.name, tuple(pts), self.period_s)


# ---------------------------------------------------------------------------
# Synthetic diurnal generators (all scaled to a target daily mean).
# ---------------------------------------------------------------------------

def flat_trace(mean_kg_per_kwh: float, name: str = "flat") -> CarbonTrace:
    """Constant intensity: exactly the paper's scalar accounting."""
    return CarbonTrace(name, ((0.0, float(mean_kg_per_kwh)),))


def _shaped(name: str, shape, mean_kg_per_kwh: float,
            knots: int = 48) -> CarbonTrace:
    """Sample ``shape(hour) -> relative intensity`` at ``knots`` evenly
    spaced knots and scale the piecewise-linear result to the mean."""
    pts = []
    for k in range(knots):
        h = 24.0 * k / knots
        pts.append((h * 3600.0, max(shape(h), 1e-6)))
    return CarbonTrace(name, tuple(pts)).scaled_to_mean(mean_kg_per_kwh)


def solar_duck(mean_kg_per_kwh: float, swing: float = 0.45) -> CarbonTrace:
    """Solar-heavy grid (CAISO-style duck curve): intensity dips through
    the midday solar belly (~13:00) and peaks on the evening ramp
    (~20:00) when solar rolls off into peaker plants.  ``swing`` sets
    the trough depth as a fraction of the base level."""
    if not 0.0 <= swing < 1.0:
        raise ValueError("swing must be in [0, 1)")

    def shape(h: float) -> float:
        belly = math.exp(-((h - 13.0) / 3.0) ** 2)
        ramp = math.exp(-((h - 20.0) / 2.0) ** 2)
        return 1.0 - swing * belly + 0.6 * swing * ramp

    return _shaped("solar-duck", shape, mean_kg_per_kwh)


def wind_night(mean_kg_per_kwh: float, swing: float = 0.35) -> CarbonTrace:
    """Wind-heavy grid: night wind floors the intensity around ~02:00
    and calm midday demand peaks it around ~14:00 (one smooth diurnal
    cosine -- the anti-phase of the solar belly)."""
    if not 0.0 <= swing < 1.0:
        raise ValueError("swing must be in [0, 1)")

    def shape(h: float) -> float:
        return 1.0 + swing * math.cos(2.0 * math.pi * (h - 14.0) / 24.0)

    return _shaped("wind-night", shape, mean_kg_per_kwh)


TRACE_SHAPES = {
    "flat": flat_trace,
    "solar-duck": solar_duck,
    "wind-night": wind_night,
}


def make_trace(shape: str, mean_kg_per_kwh: float) -> CarbonTrace:
    """Build a named shape at a target daily-mean intensity."""
    if shape not in TRACE_SHAPES:
        raise KeyError(
            f"unknown carbon trace shape {shape!r}; have "
            f"{sorted(TRACE_SHAPES)}")
    return TRACE_SHAPES[shape](mean_kg_per_kwh)


def trace_for_zone(zone: str) -> CarbonTrace:
    """The zone's preset diurnal shape at the zone's mean intensity,
    phase-shifted onto the sim clock by the zone's ``tz_offset_s``
    (``catalog.ElectricityMix`` names the shape and offset; the daily
    mean always equals ``gwp_kg_per_kwh``, so yearly totals agree with
    the scalar bookkeeping by construction)."""
    from repro.fleet.catalog import get_mix
    mix = get_mix(zone)
    return make_trace(mix.trace_shape, mix.gwp_kg_per_kwh).shifted(
        mix.tz_offset_s)


def resolve_zone_trace(zone: str, carbon_trace=None,
                       scenario_zone: str = None) -> CarbonTrace:
    """THE zone->trace resolver: one owner of the zone->(trace, mean)
    mapping (prices stay on ``catalog.get_mix``), shared by the
    scenario-level resolution (``FleetScenario.resolved_carbon_trace``)
    and the per-device zone binding, so the two can never disagree.

    ``carbon_trace`` is the scenario-style spec:
      * ``None``        -> flat at the zone's mean (scalar accounting);
      * ``"zone"``      -> the zone's preset via ``trace_for_zone``;
      * a shape name    -> ``make_trace(shape, zone mean)``;
      * a CarbonTrace   -> as-is for the zone it was authored for (the
                          scenario zone), repriced to the target zone's
                          mean (shape-preserving ``scaled_to_mean``)
                          when a device sits in a DIFFERENT zone.
    """
    from repro.fleet.catalog import get_mix
    mix = get_mix(zone)
    if carbon_trace is None:
        return flat_trace(mix.gwp_kg_per_kwh)
    if isinstance(carbon_trace, CarbonTrace):
        if scenario_zone is None or get_mix(scenario_zone).zone == mix.zone:
            return carbon_trace
        return carbon_trace.scaled_to_mean(mix.gwp_kg_per_kwh)
    if carbon_trace == "zone":
        return trace_for_zone(mix.zone)
    return make_trace(carbon_trace, mix.gwp_kg_per_kwh)


class CarbonBreakeven:
    """Carbon-aware ski-rental eviction: the paper's Eq.-12 breakeven
    T* = E_load / P_park, repriced in kgCO2e under a time-varying grid.

    The classic ski-rental argument evicts when cumulative parking cost
    reaches the reload cost.  With intensity i(t) the parking side is
    an integral and the reload is priced AT THE EVICTION INSTANT (the
    adversarial arrival lands right after you evict), so the policy
    evicts at the smallest tau with

        P_park * integral(i, now, now+tau)  >=  E_load * i(now+tau)
        <=>   integral(i, now, now+tau)     >=  T* * i(now+tau)

    (divide by P_park; T* = E_load / P_park is Eq. 12).  On a flat
    trace this is exactly tau = T* -- the energy ``Breakeven`` policy,
    so the fleet equivalence anchors are untouched.  On a diurnal
    trace the behaviour is reload-shifting: riding INTO a peak the
    right side grows and the policy holds the model warm through the
    expensive hours (a reload there would be carbon-dear); riding into
    a trough the reload gets cheap ahead and it evicts early, so the
    reload work lands in the low-intensity window.  tau is capped at
    4 T* (bounded exposure when intensity keeps rising).

    Instantiate via ``FleetModelSpec(policy_factory=CarbonBreakeven)``:
    the cluster feeds each replica its own loader/profile AND the run's
    resolved trace (``Cluster.carbon_trace``) through the factory
    signature, the same way ``Breakeven`` receives loader/profile.

    Args:
      loader / profile: the replica's cold-start + power constants.
      carbon_trace:     the run's intensity curve (None -> energy T*).
      paper_convention: Eq.-12 full-loading-power convention (default),
                        as the energy Breakeven policy uses.
    """

    name = "carbon-breakeven"
    clairvoyant = False
    _CAP_TSTARS = 4.0
    _GRID = 48                  # stopping-time scan resolution

    def __init__(self, loader, profile, *,
                 carbon_trace: "CarbonTrace" = None,
                 paper_convention: bool = True):
        from repro.core.breakeven import breakeven_seconds
        self.t_star_s = breakeven_seconds(loader, profile,
                                          paper_convention=paper_convention)
        self.carbon_trace = carbon_trace
        self.name = f"carbon-breakeven(T*={self.t_star_s:.0f}s)"

    def reset(self) -> None:
        pass

    def observe_arrival(self, t_s: float) -> None:
        pass

    def idle_timeout_s(self, now_s: float, next_gap_s=None) -> float:
        """Idle tolerance from ``now_s`` (the stopping time above);
        exactly T* when no varying trace is bound."""
        t = self.carbon_trace
        ts = self.t_star_s
        if t is None or t.is_flat or not math.isfinite(ts) or ts <= 0:
            return ts
        cap = self._CAP_TSTARS * ts
        prev_tau = 0.0
        prev_g = -ts * t.intensity_at(now_s)
        for k in range(1, self._GRID + 1):
            tau = cap * k / self._GRID
            g = t.integral(now_s, now_s + tau) \
                - ts * t.intensity_at(now_s + tau)
            if g >= 0.0:
                if g > prev_g:          # linear refine inside the cell
                    frac = -prev_g / (g - prev_g)
                    return prev_tau + frac * (tau - prev_tau)
                return tau
            prev_tau, prev_g = tau, g
        return cap


def carbon_timeline_kg(trace: CarbonTrace,
                       segments: Sequence[Tuple[float, float, float]],
                       bin_s: float = 3600.0,
                       end_s: float = 0.0) -> List[Tuple[float, float]]:
    """Cumulative kgCO2e sampled at bin boundaries: [(t_s, kg_so_far)].

    ``segments`` is a metered power timeline ((t0, t1, watts)); bins
    default to hourly.  The last bin extends to cover the latest segment
    even when a final load burst overshoots ``end_s`` (exactly as the
    fleet energy accounting lets the final burst overshoot the horizon).
    """
    return carbon_timeline_multi_kg([(trace, s) for s in segments],
                                    bin_s=bin_s, end_s=end_s)


def carbon_timeline_multi_kg(
        traced_segments: Sequence[Tuple[CarbonTrace,
                                        Tuple[float, float, float]]],
        bin_s: float = 3600.0,
        end_s: float = 0.0) -> List[Tuple[float, float]]:
    """``carbon_timeline_kg`` with a per-segment trace: the multi-zone
    fleet form, where each device's power segments integrate against
    that device's zone trace.  Walks the segments in the given order
    with the single-trace arithmetic, so a fleet whose devices all share
    one trace object reproduces ``carbon_timeline_kg`` bit-for-bit."""
    if bin_s <= 0:
        raise ValueError("bin width must be positive")
    last = max((b for _, (_, b, _) in traced_segments), default=0.0)
    end = max(end_s, last)
    n = max(int(math.ceil(end / bin_s - 1e-12)), 1)
    bins = [0.0] * n
    for trace, (a, b, p) in traced_segments:
        if b <= a:
            continue
        j = min(int(a // bin_s), n - 1)
        t = a
        while t < b:
            hi = min(b, (j + 1) * bin_s) if j < n - 1 else b
            bins[j] += trace.carbon_kg(p, t, hi)
            t = hi
            j += 1
    out: List[Tuple[float, float]] = []
    cum = 0.0
    for j, kg in enumerate(bins):
        cum += kg
        out.append((min((j + 1) * bin_s, end), cum))
    return out
