"""Cluster: N per-device ModelManagers on ONE SimClock, fleet accounting.

The cluster owns the pieces the single-device serving layer cannot
express:

  * a fleet-wide model registry (a model may have replicas on any
    device; each replica gets its own policy instance and an
    architecture-specific ``LoaderSpec`` derived from checkpoint bytes,
    so t_load/T* differ per device),
  * a global eviction-aware time advance (``advance_to`` walks every
    device's armed idle timeouts in time order, so a parked model on
    device B falls to bare at the right instant even while device A is
    mid-load),
  * migration (unload on the source, split-phase load on the target --
    the physical reason consolidation saves energy is that the DVFS
    step is per-DEVICE: one context keeps the clocks up, so packing
    parked models onto fewer devices lets drained devices fall back to
    ``p_base_w``),
  * per-model arrival-rate estimation (EWMA) feeding the energy-aware
    routers and the consolidation benefit model.

Energy invariant: fleet energy is exactly the sum of the per-device
EnergyMeter totals -- there is no separate fleet meter to drift.
"""
from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.coldstart import LoaderSpec, loader_from_checkpoint
from repro.core.power_states import PowerState, state_power_w
from repro.core.scheduler import Policy
from repro.fleet.catalog import (DeviceInstance, transfer_cost_j,
                                 transfer_latency_s)
from repro.serving.energy import SimClock
from repro.serving.model_manager import ManagedModel, ModelManager
from repro.serving.slots import WAKE_CHANNEL


def _make_policy(factory: Callable[..., Policy], loader: LoaderSpec,
                 profile, carbon_trace=None) -> Policy:
    """Instantiate a per-replica policy, feeding the replica's loader,
    device profile, and the run's carbon-intensity trace to factories
    whose signatures want them (Breakeven takes loader/profile;
    carbon.CarbonBreakeven additionally takes carbon_trace)."""
    try:
        params = inspect.signature(factory).parameters
    except (TypeError, ValueError):
        return factory()
    kwargs = {}
    if "loader" in params:
        kwargs["loader"] = loader
    if "profile" in params:
        kwargs["profile"] = profile
    if "carbon_trace" in params:
        kwargs["carbon_trace"] = carbon_trace
    return factory(**kwargs)


class RateEstimator:
    """Time-aware EWMA of a model's inter-arrival gap (fleet-level lambda-hat)."""

    def __init__(self, halflife_s: float = 1800.0):
        self.halflife_s = halflife_s
        self.last_arrival: Optional[float] = None
        self.gap_s: Optional[float] = None

    def observe(self, t_s: float) -> None:
        if self.last_arrival is not None:
            g = max(t_s - self.last_arrival, 1e-9)
            if self.gap_s is None:
                self.gap_s = g
            else:
                alpha = 1.0 - 0.5 ** (g / self.halflife_s)
                self.gap_s += alpha * (g - self.gap_s)
        self.last_arrival = t_s

    def expected_gap_s(self, default: float = 3600.0) -> float:
        return self.gap_s if self.gap_s is not None else default

    def expected_next_arrival(self, now_s: float,
                              default_gap_s: float = 3600.0) -> float:
        if self.last_arrival is None:
            return now_s + default_gap_s
        return max(self.last_arrival + self.expected_gap_s(default_gap_s),
                   now_s)


@dataclasses.dataclass
class FleetModelSpec:
    """Cluster-level model registration (replicas instantiate from this)."""
    model_id: str
    policy_factory: Callable[[], Policy]
    loader: Optional[LoaderSpec] = None      # fixed loader on every device
    checkpoint_bytes: Optional[int] = None   # else derived per device
    vram_gb: float = 0.0
    home: Optional[str] = None               # device to prewarm on at t=0
    # per-model numbers for the calibrated service-time model; None means
    # the model derives them from checkpoint_bytes
    service: Optional[object] = None         # serving.ModelServiceProfile

    def __post_init__(self):
        if self.loader is None and self.checkpoint_bytes is None:
            raise ValueError(f"{self.model_id}: need loader or checkpoint_bytes")


class Cluster:
    def __init__(self, devices: List[DeviceInstance], *,
                 clock: Optional[SimClock] = None):
        if not devices:
            raise ValueError("empty fleet")
        self.clock = clock or SimClock()
        self.devices: Dict[str, DeviceInstance] = {
            d.instance_id: d for d in devices}
        if len(self.devices) != len(devices):
            raise ValueError("duplicate instance_id in fleet")
        self.managers: Dict[str, ModelManager] = {
            did: ModelManager(d.profile, clock=self.clock)
            for did, d in self.devices.items()}
        self.specs: Dict[str, FleetModelSpec] = {}
        self.rates: Dict[str, RateEstimator] = {}
        # per-(device, model) arrival attribution: the autoscaler's
        # scale-in test needs each REPLICA's observed demand, not just
        # the fleet-level lambda-hat the routers consume
        self.rep_rates: Dict[Tuple[str, str], RateEstimator] = {}
        self._loaders: Dict[tuple, LoaderSpec] = {}
        self.migrations = 0
        self.gates = 0          # devices put to SLEEP (power gating)
        # per-route warm-replica-count timeline: (t_s, count) appended
        # whenever snapshot_replicas observes a change; log_replicas
        # gates the appends (run_fleet detail=False -- the log is pure
        # observability, nothing reads it back into the dynamics)
        self.replica_log: Dict[str, List[Tuple[float, int]]] = {}
        self.log_replicas = True
        # attached by the fleet event loop (run_fleet): per-device
        # DeviceRuntime (serving/slots.py) + the scenario's service-time
        # model.  Empty/None when the cluster is driven directly.
        self.runtime: Dict[str, object] = {}
        self.service_model = None
        # the run's grid-intensity trace (fleet/carbon.py), bound by
        # run_fleet BEFORE any replica exists so carbon-aware policies
        # (CarbonBreakeven) receive it at construction; None when the
        # cluster is driven directly (policies fall back to energy T*)
        self.carbon_trace = None
        # per-device electricity zone + intensity trace, bound by
        # run_fleet from the scenario's device list; empty when the
        # cluster is driven directly (all devices price against
        # carbon_trace and migrations never cross a zone boundary)
        self.device_zones: Dict[str, str] = {}
        self.device_traces: Dict[str, object] = {}
        self.transfer_j = 0.0           # WAN checkpoint-transfer energy
        self.cross_zone_migrations = 0
        # spot preemption (fleet/pricing.py): devices the provider has
        # warned about or reclaimed.  Routers, the autoscaler, and the
        # consolidator all treat a revoked device like a drained gate:
        # no new placements, no migration targets.  run_fleet maintains
        # the set from the PreemptionModel's drawn events.
        self.revoked: set = set()
        self.preemptions = 0            # revocations actually applied

    # -- registry -----------------------------------------------------------
    def register_model(self, spec: FleetModelSpec) -> None:
        self.specs[spec.model_id] = spec
        self.rates[spec.model_id] = RateEstimator()
        self.replica_log[spec.model_id] = []

    def replica_rate(self, device_id: str, model_id: str) -> RateEstimator:
        key = (device_id, model_id)
        if key not in self.rep_rates:
            self.rep_rates[key] = RateEstimator()
        return self.rep_rates[key]

    def loader_for(self, model_id: str, device_id: str) -> LoaderSpec:
        """Per-(model, device) LoaderSpec: this is what makes routing
        architecture-aware -- t_load scales with the device's ingest
        bandwidth, so T* and the cold-start cost differ per SKU."""
        key = (model_id, device_id)
        if key not in self._loaders:
            spec = self.specs[model_id]
            if spec.loader is not None:
                self._loaders[key] = spec.loader
            else:
                self._loaders[key] = loader_from_checkpoint(
                    model_id, spec.checkpoint_bytes,
                    self.devices[device_id].profile)
        return self._loaders[key]

    def replica(self, device_id: str, model_id: str) -> ManagedModel:
        """Get (lazily creating) the per-device replica of a model.

        The policy factory is called with ``loader=``/``profile=`` when
        its signature accepts them, so architecture-dependent policies
        (Breakeven and friends -- pass the CLASS as the factory) get
        each replica's own T*."""
        mm = self.managers[device_id]
        if model_id not in mm.models:
            spec = self.specs[model_id]
            loader = self.loader_for(model_id, device_id)
            policy = _make_policy(spec.policy_factory, loader,
                                  self.devices[device_id].profile,
                                  self.carbon_trace)
            mm.register(model_id, policy=policy, loader=loader,
                        vram_gb=spec.vram_gb)
        return mm.models[model_id]

    # -- state queries -------------------------------------------------------
    def locations(self, model_id: str, *, include_loading: bool = True
                  ) -> List[str]:
        out = []
        for did, mm in self.managers.items():
            m = mm.models.get(model_id)
            if m is not None and (m.resident or
                                  (include_loading and m.loading)):
                out.append(did)
        return sorted(out)

    def context_on(self, device_id: str) -> bool:
        mm = self.managers[device_id]
        return any(m.resident or m.loading for m in mm.models.values())

    def occupancy(self, device_id: str) -> int:
        mm = self.managers[device_id]
        return sum(1 for m in mm.models.values() if m.resident or m.loading)

    def free_slots(self, device_id: str) -> int:
        return self.devices[device_id].sku.slots - self.occupancy(device_id)

    def free_vram_gb(self, device_id: str) -> float:
        mm = self.managers[device_id]
        return self.devices[device_id].sku.vram_gb - mm.vram_used_gb()

    def fits(self, device_id: str, model_id: str) -> bool:
        return (self.free_slots(device_id) >= 1
                and self.free_vram_gb(device_id)
                >= self.specs[model_id].vram_gb)

    # -- concurrency state (fed by the attached DeviceRuntimes) --------------
    def attach_runtime(self, runtime: Dict[str, object],
                       service_model=None) -> None:
        """Register the fleet event loop's per-device runtimes so routers
        (queue depth, slot occupancy) and the power composer can see
        in-flight work."""
        self.runtime = runtime
        if service_model is not None:
            self.service_model = service_model

    def busy_slots(self, device_id: str,
                   model_id: Optional[str] = None) -> int:
        rt = self.runtime.get(device_id)
        return rt.busy_slots(model_id) if rt is not None else 0

    def waiting_requests(self, device_id: str,
                         model_id: Optional[str] = None) -> int:
        rt = self.runtime.get(device_id)
        return rt.waiting_count(model_id) if rt is not None else 0

    def decode_slots(self, device_id: str) -> int:
        rt = self.runtime.get(device_id)
        return rt.max_batch if rt is not None else 1

    def queued_load_demand(self, device_id: str) -> Tuple[int, float]:
        """(slots, vram_gb) that loads still QUEUED on this device's
        loader channel will consume when they start.  Queued-not-started
        loads are invisible to occupancy/free_vram_gb (only resident or
        loading replicas count), so capacity planners that look across
        ticks must add this on top of ``fits``."""
        rt = self.runtime.get(device_id)
        if rt is None:
            return 0, 0.0
        slots, vram = 0, 0.0
        seen = set()
        for item in rt.load_q:
            mid = item[-1]
            if mid in seen:               # load + queued migration race:
                continue                  # only one of them will land
            seen.add(mid)
            m = self.managers[device_id].models.get(mid)
            if m is not None and (m.resident or m.loading):
                continue                  # already counted by occupancy
            slots += 1
            vram += self.specs[mid].vram_gb
        return slots, vram

    def pending_scaleouts(self, model_id: str) -> List[str]:
        """Devices where this model's (re)load or migration is in flight
        or queued on the loader channel but the replica is not resident
        yet -- capacity that is COMING UP (the SLO router and the
        autoscaler both count it, so neither double-provisions a route
        mid-scale-out).  Queued migrations never enter ``load_queued``,
        so the channel queue itself is scanned too."""
        out = []
        for did, rt in self.runtime.items():
            if rt is None:
                continue
            m = self.managers[did].models.get(model_id)
            if m is not None and m.resident:
                continue
            if (rt.loading == model_id or model_id in rt.load_queued
                    or any(item[-1] == model_id for item in rt.load_q)):
                out.append(did)
        return sorted(out)

    def snapshot_replicas(self, t_s: float) -> None:
        """Append (t, warm-replica count) per route when the count moved.
        The fleet event loop samples after every event, and advance_to
        samples at each eviction instant it applies, so scale-out
        landings AND timeout evictions are timestamped exactly."""
        if not self.log_replicas:
            return
        for mid in self.specs:
            n = len(self.locations(mid, include_loading=False))
            log = self.replica_log[mid]
            if not log or log[-1][1] != n:
                log.append((t_s, n))

    def load_residual_s(self, device_id: str, now_s: float) -> float:
        """Remaining seconds of the in-flight load (0 when idle)."""
        rt = self.runtime.get(device_id)
        if rt is None or rt.loading is None:
            return 0.0
        return max(rt.loading_until - now_s, 0.0)

    def load_backlog_s(self, device_id: str, now_s: float, *,
                       exclude_model: Optional[str] = None) -> float:
        """Seconds of loader-channel work ahead of a load enqueued now:
        residual of the in-flight load + queued (re)loads/migrations.
        ``exclude_model`` skips that model's own queued load (a caller
        estimating ITS wait would otherwise count it twice)."""
        rt = self.runtime.get(device_id)
        if rt is None:
            return 0.0
        s = self.load_residual_s(device_id, now_s)
        for item in rt.load_q:
            if item[-1] != exclude_model:
                s += self.loader_for(item[-1], device_id).t_load_s
        return s

    def sync_power(self, device_id: str, *,
                   service_util: float = 0.6) -> None:
        """Recompose the device's metered power from its concurrent phase
        state (the additive decomposition that makes overlap meterable):

            P = (p_load if a load is in flight else P_idle(ctx))
                + busy_slots * (P_active - P_ctx)

        With one phase at a time this reduces exactly to the serialized
        accounting (flat p_load during loads, active_power_w(0.6) during
        service), preserving the single-device equivalence anchor; with
        overlap, each busy decode slot adds its above-context increment
        on top of whichever base phase is running.

        Gated devices are the state machine's business, not the
        composer's: a SLEEPING device is left asleep (nothing can be in
        flight there -- illegal transitions would have raised earlier),
        and an in-flight wake ramp keeps its override so a racing event
        cannot settle the ramp's watts away mid-wake."""
        mm = self.managers[device_id]
        prof = self.devices[device_id].profile
        if mm.meter.state in (PowerState.SLEEP, PowerState.OFF):
            # gated or revoked: the state machine owns these (wake ramp /
            # preempt_restore); settling here would silently power the
            # device back up
            return
        rt = self.runtime.get(device_id)
        if rt is not None and rt.loading == WAKE_CHANNEL:
            mm.meter.transition(
                PowerState.BARE,
                power_override_w=mm.meter.power_override_w)
            return
        loading = next((m for m in mm.models.values() if m.loading), None)
        busy = self.busy_slots(device_id)
        if busy > 0:
            base = loading.loader.p_load_w if loading is not None \
                else prof.idle_power_w(context_active=True)
            p = base + busy * (prof.active_power_w(service_util)
                               - prof.p_ctx_w)
            mm.meter.transition(PowerState.ACTIVE, power_override_w=p)
        elif loading is not None:
            mm.meter.transition(PowerState.LOADING,
                                power_override_w=loading.loader.p_load_w)
        else:
            mm.settle()

    def idle_power_w(self) -> float:
        """Instantaneous fleet idle power from power state (Eq. 1 summed
        over devices, with gated devices at their sleep floor;
        loading/active bursts excluded by design -- this is the
        steady-state quantity consolidation + gating optimize)."""
        total = 0.0
        for did, dev in self.devices.items():
            state = self.power_state(did)
            if state is PowerState.OFF:
                continue                  # reclaimed: draws nothing
            if state is PowerState.SLEEP:
                total += dev.profile.p_sleep_w
            else:
                total += dev.profile.idle_power_w(self.context_on(did))
        return total

    # -- power gating (sleep/wake; core/power_states.py) ---------------------
    def power_state(self, device_id: str) -> PowerState:
        """The device's current power state (its meter's machine)."""
        return self.managers[device_id].meter.state

    def gate_device(self, device_id: str) -> bool:
        """Put a fully drained device to SLEEP now, if it is safe to:
        meter settled at BARE (no residents, no burst in flight) and no
        runtime work queued on its loader channel or decode slots.
        Returns whether the device actually gated."""
        mm = self.managers[device_id]
        if mm.meter.state is not PowerState.BARE:
            return False
        if self.occupancy(device_id) > 0:
            return False
        rt = self.runtime.get(device_id)
        if rt is not None and rt.busy:
            return False
        mm.meter.gate()
        self.gates += 1
        return True

    def start_wake(self, device_id: str) -> float:
        """Begin the SLEEP -> BARE wake ramp; returns its duration.  The
        fleet event loop serializes it on the device's loader channel
        (``WAKE_CHANNEL``) so loads start only once the device is up."""
        return self.managers[device_id].meter.begin_wake()

    def finish_wake(self, device_id: str) -> None:
        self.managers[device_id].meter.finish_wake()

    def bare_idle_s(self, device_id: str, now_s: float) -> float:
        """How long the device has been settled at BARE (0 when in any
        other state) -- the realized wait the gating ski rental tests
        against ``gate_breakeven_s``."""
        meter = self.managers[device_id].meter
        if meter.state is not PowerState.BARE:
            return 0.0
        return max(now_s - meter.state_since_s(), 0.0)

    # -- time ---------------------------------------------------------------
    def advance_to(self, target_s: float) -> None:
        """Advance the shared clock, applying every device's armed idle
        timeouts in time order on the way.

        A deadline landing EXACTLY on the target stays armed: the
        single-device simulator keeps a model warm when the idle gap
        equals the timeout (`stay < gap` is strict), and the arriving
        event at `target_s` re-arms or supersedes it."""
        while True:
            pending = [m.evict_at
                       for mm in self.managers.values()
                       for m in mm.models.values()
                       if m.resident and math.isfinite(m.evict_at)
                       and m.evict_at < target_s]
            if not pending:
                break
            t_evt = min(pending)
            self.clock.advance(max(t_evt - self.clock(), 0.0))
            for mm in self.managers.values():
                mm.tick()
            self.snapshot_replicas(t_evt)
        self.clock.advance(max(target_s - self.clock(), 0.0))

    # -- request-path primitives (the fleet event loop sequences these) -----
    def observe_arrival(self, model_id: str, device_id: str, t_s: float
                        ) -> None:
        """Feed one arrival to the fleet rate estimator AND the routed
        replica's policy (at the true arrival time, as the single-device
        simulator does)."""
        self.rates[model_id].observe(t_s)
        self.replica_rate(device_id, model_id).observe(t_s)
        self.replica(device_id, model_id).policy.observe_arrival(t_s)

    def start_load(self, device_id: str, model_id: str) -> float:
        """Begin a split-phase load; returns its duration.  Evicts idle
        parked models first if the device is over capacity."""
        self.replica(device_id, model_id)
        self.make_room(device_id, model_id)
        return self.managers[device_id].begin_load(model_id)

    def finish_load(self, device_id: str, model_id: str) -> None:
        self.managers[device_id].finish_load(model_id)
        self.managers[device_id].arm(model_id)

    def begin_serve(self, device_id: str, model_id: str, arrival_s: float,
                    *, service_s: float = 0.0) -> None:
        m = self.replica(device_id, model_id)
        m.requests += 1
        wait = max(self.clock() - arrival_s, 0.0)
        m.added_latency_s += wait
        m.latency_samples.append(wait)
        m.evict_at = math.inf          # never evict mid-service
        if service_s > 0 and not self.runtime:
            # legacy blocking path (no concurrent runtime attached): the
            # caller owns advancing the clock through the service window
            self.managers[device_id].meter.transition(PowerState.ACTIVE)

    def end_serve(self, device_id: str, model_id: str) -> None:
        mm = self.managers[device_id]
        mm.settle()
        m = mm.models[model_id]
        m.pins = max(0, m.pins - 1)
        if m.resident:
            if m.pins > 0:
                m.evict_at = math.inf     # more queued demand: stay pinned
            else:
                mm.arm(model_id)

    def cancel_serve(self, device_id: str, model_id: str,
                     wait_s: float) -> None:
        """Reverse ``begin_serve``'s bookkeeping for one in-flight
        request a preemption orphaned: the request was NOT served here,
        so its count and latency sample move with it to wherever the
        re-dispatch lands (conservation: served == arrivals, each
        counted exactly once).  ``latency_samples.remove`` drops the
        first equal value -- samples are a multiset, so any equal
        entry is the same observation.  Pins are left alone: the caller
        follows with ``force_off``, whose ``fail()`` zeroes them."""
        m = self.managers[device_id].models[model_id]
        m.requests -= 1
        m.added_latency_s -= wait_s
        m.latency_samples.remove(wait_s)

    # -- spot preemption (fleet/pricing.py draws; run_fleet replays) ---------
    def force_off(self, device_id: str) -> None:
        """Provider reclaims the device NOW: every resident/loading
        replica is dropped instantly (``ModelManager.fail`` -- no
        orderly unload, the weights are just gone) and the meter lands
        at OFF (0 W; OFF seconds are unbilled for usage tiers).  The
        caller has already collected orphaned requests via
        ``cancel_serve`` -- fail() zeroes pins, so cancel must run
        first."""
        mm = self.managers[device_id]
        mm.fail()
        mm.meter.transition(PowerState.OFF)
        self.revoked.add(device_id)
        self.preemptions += 1

    def restore_device(self, device_id: str) -> None:
        """The outage ends: the device returns, cold and empty, at
        BARE, and leaves the revoked set so placement can use it
        again."""
        self.managers[device_id].meter.transition(PowerState.BARE)
        self.revoked.discard(device_id)

    def preview_timeout_s(self, model_id: str, device_id: str,
                          now_s: float) -> float:
        """Idle timeout a replica of this model would arm on this device,
        WITHOUT registering it (the consolidation planner speculates over
        candidate targets and must not mutate managers)."""
        mm = self.managers[device_id]
        m = mm.models.get(model_id)
        if m is not None:
            return m.policy.idle_timeout_s(now_s)
        spec = self.specs[model_id]
        policy = _make_policy(spec.policy_factory,
                              self.loader_for(model_id, device_id),
                              self.devices[device_id].profile,
                              self.carbon_trace)
        return policy.idle_timeout_s(now_s)

    def make_room(self, device_id: str, model_id: str) -> None:
        """Best-effort capacity enforcement: unload parked-idle models
        (soonest-to-evict first) until the new model fits.  In-flight
        (loading) models are never touched."""
        mm = self.managers[device_id]
        need_gb = self.specs[model_id].vram_gb
        sku = self.devices[device_id].sku

        def over() -> bool:
            used = mm.vram_used_gb()
            occ = self.occupancy(device_id)
            return (used + need_gb > sku.vram_gb or occ + 1 > sku.slots)

        victims = sorted(
            (m for m in mm.models.values()
             if m.resident and m.model_id != model_id and m.pins == 0),
            key=lambda m: m.evict_at)
        for v in victims:
            if not over():
                break
            mm.unload(v.model_id)

    # -- replica scale-in (autoscaler) --------------------------------------
    def scale_in(self, device_id: str, model_id: str) -> bool:
        """Retire one warm replica NOW, if it is safe to: resident, not
        mid-load, no pinned/queued demand, no busy decode slots.  Returns
        whether the replica was actually unloaded.  The device's meter
        re-settles, so a fully drained device falls back to bare."""
        m = self.managers[device_id].models.get(model_id)
        if m is None or not m.resident or m.loading or m.pins > 0:
            return False
        if (self.busy_slots(device_id, model_id) > 0
                or self.waiting_requests(device_id, model_id) > 0):
            return False
        self.managers[device_id].unload(model_id)
        self.sync_power(device_id)
        return True

    # -- migration ----------------------------------------------------------
    def device_trace(self, device_id: str):
        """The intensity trace this device's joules are priced against:
        its zone's trace when run_fleet bound one, else the scenario
        trace (so single-zone runs stay on the exact same object)."""
        return self.device_traces.get(device_id) or self.carbon_trace

    def migration_transfer(self, model_id: str, src_id: str, dst_id: str
                           ) -> Tuple[float, float]:
        """(extra latency s, WAN energy J) of shipping model_id's
        checkpoint from src's zone to dst's zone.  (0, 0) when the move
        stays inside one zone, when zones are unbound, or when the spec
        has no checkpoint size to ship."""
        za = self.device_zones.get(src_id)
        zb = self.device_zones.get(dst_id)
        if za is None or zb is None or za == zb:
            return 0.0, 0.0
        ckpt = self.specs[model_id].checkpoint_bytes or 0
        gb = ckpt / 1024 ** 3
        return (transfer_latency_s(gb, za, zb), transfer_cost_j(gb, za, zb))

    def start_migration(self, model_id: str, src_id: str, dst_id: str
                        ) -> float:
        """Unload from src, begin the (split-phase) load on dst; returns
        the load duration.  The caller owns scheduling finish_load.
        Cross-zone moves ship the checkpoint over the WAN first: the
        returned duration stretches by the transfer latency (so the
        added cold-start delay lands in the existing p99 accounting)
        and the transfer energy accrues to transfer_j."""
        src = self.managers[src_id]
        exported_engine = None
        m_src = src.models.get(model_id)
        if m_src is not None and m_src.resident:
            exported_engine = m_src.engine
        src.unload(model_id)
        dst_m = self.replica(dst_id, model_id)
        if dst_m.load_fn is None and exported_engine is not None:
            dst_m.engine = exported_engine
        self.migrations += 1
        xfer_s, xfer_j = self.migration_transfer(model_id, src_id, dst_id)
        if xfer_s > 0.0 or xfer_j > 0.0:
            self.cross_zone_migrations += 1
            self.transfer_j += xfer_j
        return self.start_load(dst_id, model_id) + xfer_s

    # -- reporting ----------------------------------------------------------
    def device_totals(self) -> Dict[str, Dict[str, float]]:
        """Per-device energy (Wh by meter state incl. 'total'); flushes
        meters to 'now'."""
        return {did: mm.meter.totals()
                for did, mm in self.managers.items()}
