"""Event-driven multi-model / multi-device parking-tax simulation.

Lifts ``core/simulator.py`` (one model, one device) to cluster scale:
M models' arrival traces are routed across N heterogeneous devices by a
``Router``; per-replica eviction policies arm idle timeouts; an optional
``Consolidator`` periodically packs parked models onto fewer devices.
Every joule is metered by the per-device ``EnergyMeter`` inside each
``ModelManager`` -- fleet energy is the sum of device meters by
construction.

Faithfulness anchor: with 1 device x 1 model, a stateless policy, and
the same trace, ``run_fleet`` reproduces ``simulator.simulate`` energy
to float precision (tested to 1e-6 Wh): the same power constants are
integrated over the same instants (warm idle at P_ctx, evicted at
P_base, loads at P_load, start-warm counts one cold start).

Events (heap, stable order: phase completions before consolidation
before arrivals at equal times):
  * arrival    -- route, then serve / queue / trigger a load
  * load_done  -- land a split-phase (re)load, drain that model's wait
                  queue into decode slots, pump the loader channel
  * serve_done -- release the decode slot, admit the next waiter
  * consolidate-- run the packing pass, enqueue migrations

Concurrency model (serving/slots.py DeviceRuntime): each device has ONE
serialized loader channel (weight ingest is PCIe/storage-bound) and,
per resident model, ``max_batch`` decode slots -- so loads overlap
serving and up to ``max_batch`` requests per model decode concurrently.
Service time per request comes from the scenario's ``ServiceTimeModel``
(serving/service_model.py), frozen at admission occupancy.  Power under
overlap composes additively (Cluster.sync_power): the idle/loading base
plus one above-context active increment per busy slot -- which reduces
exactly to the old serialized accounting when phases never overlap, so
the single-device equivalence anchor below still holds.  Queued
requests for a model that is mid-load are served the instant the load
completes, which is exactly the single-device simulator's batching
rule.

Carbon accounting integrates by TRACE, not scalar: every device meter
records its power timeline, and ``FleetResult.carbon_kg`` is the
integral of that power against the scenario's grid-intensity trace
(fleet/carbon.py).  With the default flat trace this reproduces the old
``energy_kwh * gwp`` scalar to 1e-9 kg (tested); with a diurnal trace
the SAME joules cost different kgCO2e depending on WHEN they are drawn,
which is what the carbon-aware router/consolidator/autoscaler modes
optimize against.

Power gating (core/power_states.py): with a ``Consolidator`` in
``gate_drained_devices`` mode, fully drained devices fall below
``p_base_w`` to SLEEP once their bare idle clears the wake-energy
breakeven; a load routed to a gated device first runs the SLEEP -> BARE
wake ramp on the device's loader channel (``WAKE_CHANNEL``), so wake
latency and wake energy are metered like any other phase.
``FleetResult`` reports per-state Wh/seconds and ``gated_wh_saved`` --
the first mechanism that cuts below the bare-idle floor.

The clairvoyant lower bound reported alongside is the cluster analogue
of ``scheduler.Clairvoyant``: per model, offline per-gap ski rental
using the fleet's BEST constants (min DVFS step across devices, min
above-bare reload energy).  ``lb_nongated_wh`` takes the max over
models (valid even when co-parked models share one context -- any
feasible schedule restricted to one model is a feasible single-model
schedule); ``cv_per_model_wh`` sums over models (the tighter reference
when contexts are not shared).  Both floors carry a per-device
``p_base`` term that assumes devices never SLEEP, so they bound only
NON-GATED runs: a power-gated run (Consolidator
``gate_drained_devices``) legitimately lands below them -- that is the
point of gating, and the reason the field is scoped (and named)
non-gated rather than universal.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.coldstart import loader_from_checkpoint
from repro.core.power_states import PowerState
from repro.fleet.autoscaler import ReplicaAutoscaler, ScaleOut
from repro.fleet.carbon import (CarbonTrace, carbon_timeline_kg,
                                carbon_timeline_multi_kg,
                                resolve_zone_trace)
from repro.fleet.catalog import (DeviceInstance, build_fleet, carbon_kg,
                                 energy_cost_usd, fleet_price_usd, get_mix)
from repro.fleet.cluster import Cluster, FleetModelSpec
from repro.fleet.pricing import (PreemptionModel, device_tier_map,
                                 price_fleet, tier_billed_seconds)
from repro.fleet.router import Consolidator, Router, get_router
from repro.serving.service_model import ConstantServiceTime, ServiceTimeModel
from repro.serving.slots import DeviceRuntime, WAKE_CHANNEL

DAY = 24 * 3600.0

# event phases at equal timestamps:
# completions < autoscale < consolidation < arrivals < faults
# (faults LAST so a preemption landing exactly at an arrival orphans
# that request like any other in-flight work; phases 0-3 are unchanged,
# keeping zero-preemption runs event-order identical to before)
_P_DONE, _P_AUTO, _P_CONS, _P_ARR, _P_FAULT = 0, 1, 2, 3, 4


@dataclasses.dataclass
class FleetModel:
    """One workload: a cluster-level model spec + its arrival trace."""
    spec: FleetModelSpec
    arrivals_s: Sequence[float]


@dataclasses.dataclass
class FleetScenario:
    devices: List[DeviceInstance]
    models: List[FleetModel]
    router: Union[Router, str] = "warm-first"
    horizon_s: float = DAY
    service_s: float = 0.0                   # legacy constant service time
    consolidator: Optional[Consolidator] = None
    autoscaler: Optional[ReplicaAutoscaler] = None
    zone: str = "USA"
    price_tier: str = "on_demand"
    # concurrency knobs: decode slots per resident model, and the
    # service-time model (None -> ConstantServiceTime(service_s), which
    # with the default service_s=0 reproduces the paper's
    # service-energy-held-constant convention)
    max_batch: int = 4
    service_model: Optional[ServiceTimeModel] = None
    # time-varying grid intensity (fleet/carbon.py):
    #   None          -> flat at the zone's mean (EXACTLY the scalar
    #                    kgCO2e accounting; the equivalence anchor)
    #   "zone"        -> the zone's preset diurnal shape
    #   a shape name  -> that shape at the zone's mean ("solar-duck", ..)
    #   a CarbonTrace -> used as-is
    carbon_trace: Union[CarbonTrace, str, None] = None
    # spot preemption (fleet/pricing.py): None -> no faults (every
    # existing scenario replays bit-exactly); a PreemptionModel draws
    # seeded revocations for the fleet's spot-tier devices, which the
    # event loop replays as warn/off/restore faults
    preemptions: Optional[PreemptionModel] = None

    def resolved_service_model(self) -> ServiceTimeModel:
        return self.service_model or ConstantServiceTime(self.service_s)

    def resolved_carbon_trace(self) -> CarbonTrace:
        """The intensity curve this run integrates emissions against
        (see ``carbon_trace``); flat-at-mean when unset.  Delegates to
        ``carbon.resolve_zone_trace`` -- the one owner of the
        zone->(trace, mean) mapping -- so scenario-level and per-device
        zone resolution can never disagree."""
        return resolve_zone_trace(self.zone, self.carbon_trace)

    def device_zones(self) -> Dict[str, str]:
        """instance_id -> electricity zone: the device's own pinned zone
        (``DeviceInstance.zone``) or the scenario zone, canonical."""
        home = get_mix(self.zone).zone
        return {d.instance_id: (d.zone or home) for d in self.devices}

    def device_tiers(self) -> Dict[str, str]:
        """instance_id -> purchase tier: the device's own pinned tier
        (``DeviceInstance.tier``) or the scenario ``price_tier`` --
        the tier shape of ``device_zones``."""
        return device_tier_map(self.devices, self.price_tier)

    def device_carbon_traces(self, resolved: Optional[CarbonTrace] = None
                             ) -> Dict[str, CarbonTrace]:
        """instance_id -> the intensity curve THAT device's joules price
        against.  Devices in the scenario zone (or with no pinned zone)
        get the scenario's resolved trace OBJECT -- the same floats in
        the same order, so uniform-zone fleets reproduce the scenario-
        zone run bit-exactly; devices pinned elsewhere resolve the same
        ``carbon_trace`` spec against their own zone through the shared
        resolver."""
        base = resolved if resolved is not None \
            else self.resolved_carbon_trace()
        home = get_mix(self.zone).zone
        cache: Dict[str, CarbonTrace] = {home: base}
        out: Dict[str, CarbonTrace] = {}
        for d in self.devices:
            z = d.zone or home
            if z not in cache:
                cache[z] = resolve_zone_trace(z, self.carbon_trace,
                                              scenario_zone=home)
            out[d.instance_id] = cache[z]
        return out


@dataclasses.dataclass
class DeviceReport:
    instance_id: str
    sku: str
    energy_wh: Dict[str, float]          # by power state + "total"
    parking_tax_wh: float
    cold_starts: int
    requests: int
    resident: List[str]                  # models resident at horizon end
    meter_state: str                     # power state at horizon end
    carbon_kg: float = 0.0               # trace-integrated device emissions
    zone: str = ""                       # electricity zone the device sits in
    # per-power-state seconds (same keys as energy_wh, minus "total")
    durations_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    wakes: int = 0                       # SLEEP -> BARE ramps metered
    # Wh below what bare idle would have cost over the gated windows
    gated_wh_saved: float = 0.0

    @property
    def total_wh(self) -> float:
        return self.energy_wh["total"]


@dataclasses.dataclass
class FleetResult:
    router: str
    horizon_s: float
    devices: List[DeviceReport]
    energy_wh: float
    parking_tax_wh: float
    cold_starts: int
    requests: int
    added_latency_s_total: float
    migrations: int
    # clairvoyant floors for NON-GATED runs (see clairvoyant_bound): the
    # p_base term assumes devices never sleep, so a gated run can land
    # below these -- compare against them only when no gating ran
    lb_nongated_wh: float
    cv_per_model_wh: float
    infra_usd: float
    energy_usd: float
    carbon_kg: float
    # per-request added latency (queue wait + cold start), sorted
    latencies_s: Sequence[float] = ()
    # per-route warm-replica-count timeline: model_id -> [(t_s, count)],
    # one entry per change (autoscaler study instrument)
    replica_timeline: Dict[str, List[Tuple[float, int]]] = \
        dataclasses.field(default_factory=dict)
    scale_outs: int = 0
    scale_ins: int = 0
    # carbon accounting (fleet/carbon.py): `carbon_kg` above is the
    # TRACE-INTEGRAL of the metered power over the run's intensity
    # curve; `carbon_kg_flat` is the legacy scalar (energy x zone mean),
    # equal to carbon_kg under a flat trace (pinned to 1e-9 kg)
    carbon_kg_flat: float = 0.0
    carbon_trace_name: str = "flat"
    # cumulative kgCO2e at (hourly) bin boundaries: [(t_s, kg_so_far)]
    carbon_timeline: Sequence[Tuple[float, float]] = ()
    # fleet-wide metered power segments (t0_s, t1_s, watts) -- carbon is
    # a POST-HOC integral over these, so one run can be re-priced under
    # any trace/zone without re-simulating (see carbon_with)
    power_timeline: Sequence[Tuple[float, float, float]] = ()
    # power-state machine breakdowns (core/power_states.py): fleet-wide
    # Wh and seconds per state (summed over devices; keys are the state
    # wire names -- "sleep"/"bare"/"parked"/"loading"/"active")
    state_energy_wh: Dict[str, float] = \
        dataclasses.field(default_factory=dict)
    state_durations_s: Dict[str, float] = \
        dataclasses.field(default_factory=dict)
    # power gating: devices put to SLEEP, wake ramps metered, and the Wh
    # the gated windows saved vs idling bare through them -- the first
    # mechanism that cuts BELOW the p_base floor
    gates: int = 0
    wakes: int = 0
    gated_wh_saved: float = 0.0
    # run_mega backend instrumentation: wall-clock seconds spent in the
    # bulk-scan phases ("biggap_s" / "billing_s" / "energy_s" /
    # "carbon_s" and their sum "bulk_scan_s"); None for event-loop runs
    phase_timings: Optional[Dict[str, float]] = None
    # per-zone decompositions of the global totals (one entry per zone
    # present in the fleet; single-zone runs get a one-key dict whose
    # value fsum-reduces to the global total)
    zone_energy_wh: Dict[str, float] = \
        dataclasses.field(default_factory=dict)
    zone_carbon_kg: Dict[str, float] = \
        dataclasses.field(default_factory=dict)
    # cross-zone checkpoint-transfer accounting (follow-the-sun
    # migrations): NETWORK energy, reported alongside -- not inside --
    # energy_wh, which stays the device-meter integral
    transfer_wh: float = 0.0
    cross_zone_migrations: int = 0
    # dollar accounting (fleet/pricing.py): cost_usd = gpu_hours_usd +
    # energy_usd exactly.  gpu_hours_usd bills each device's metered
    # power-state seconds at its tier rate (SLEEP/OFF unbilled except
    # reserved) -- unlike the legacy infra_usd flat quote above, which
    # stays as the hold-the-whole-fleet-on-demand reference.  The
    # per-device / per-zone dicts fsum back to the totals (1e-12 rel,
    # property-tested) and match across all three engines to 1e-9 rel.
    cost_usd: float = 0.0
    gpu_hours_usd: float = 0.0
    device_gpu_usd: Dict[str, float] = dataclasses.field(default_factory=dict)
    device_cost_usd: Dict[str, float] = \
        dataclasses.field(default_factory=dict)
    zone_cost_usd: Dict[str, float] = dataclasses.field(default_factory=dict)
    device_tiers: Dict[str, str] = dataclasses.field(default_factory=dict)
    # spot preemption: revocations applied and requests orphaned by them
    # that were re-queued elsewhere (conservation: none are dropped)
    preemptions: int = 0
    requeued_requests: int = 0
    # tier -> billed seconds across the devices billed under it
    # (pricing.tier_billed_seconds; the jax backend's fused metering
    # kernel emits it in-pass) -- engines agree to <=1e-9 rel
    tier_billed_s: Dict[str, float] = dataclasses.field(default_factory=dict)

    def peak_replicas(self, model_id: Optional[str] = None) -> int:
        """Max concurrent warm replicas over the horizon (one route, or
        the max across routes)."""
        logs = ([self.replica_timeline.get(model_id, [])] if model_id
                else list(self.replica_timeline.values()))
        return max((n for log in logs for _, n in log), default=0)

    @property
    def mean_added_latency_s(self) -> float:
        return (self.added_latency_s_total / self.requests
                if self.requests else 0.0)

    def _latency_pct(self, q: float) -> float:
        arr = np.asarray(self.latencies_s, dtype=float)
        return float(np.percentile(arr, q)) if arr.size else 0.0

    @property
    def p50_added_latency_s(self) -> float:
        return self._latency_pct(50.0)

    @property
    def p99_added_latency_s(self) -> float:
        return self._latency_pct(99.0)

    @property
    def requests_per_s(self) -> float:
        return self.requests / self.horizon_s if self.horizon_s > 0 else 0.0

    def savings_vs(self, baseline: "FleetResult") -> float:
        """Fractional energy saving vs a baseline run; 0.0 against a
        degenerate zero-energy baseline (instead of inf/ZeroDivision)."""
        if baseline.energy_wh <= 0.0:
            return 0.0
        return 1.0 - self.energy_wh / baseline.energy_wh

    def carbon_savings_vs(self, baseline: "FleetResult") -> float:
        """Fractional kgCO2e saving vs a baseline run (same guard as
        ``savings_vs``) -- the per-policy carbon delta the bench rows
        report."""
        if baseline.carbon_kg <= 0.0:
            return 0.0
        return 1.0 - self.carbon_kg / baseline.carbon_kg

    def carbon_with(self, trace: CarbonTrace) -> float:
        """Re-price this run's emissions under a different intensity
        trace WITHOUT re-simulating: carbon is an integral over the
        recorded ``power_timeline``, which does not depend on the trace
        (the dynamics only change when a carbon-aware component was
        steering -- this prices the same schedule on another grid)."""
        return trace.carbon_for_segments(self.power_timeline)


def run_fleet(scenario: FleetScenario, *, compute_bound: bool = True,
              detail: bool = True) -> FleetResult:
    """Event-loop fleet simulation (the full-scope reference engine).

    ``compute_bound=False`` skips the clairvoyant lower bound (an extra
    whole-fleet analysis pass; ``lb_nongated_wh``/``cv_per_model_wh``
    report 0.0) and ``detail=False`` skips the replica timeline log and
    the hourly carbon timeline -- pure post-processing that no other
    ``FleetResult`` field reads.  The planner's worker pool uses both:
    the PlanPoint objectives (cost/energy/carbon/p99 and their
    decompositions) are bit-identical either way.
    """
    sc = scenario
    router = get_router(sc.router) if isinstance(sc.router, str) else sc.router
    svc = sc.resolved_service_model()
    trace = sc.resolved_carbon_trace()
    # carbon-aware components see the run's intensity curve; everything
    # else ignores it (a flat trace makes the aware components behave
    # exactly like their energy-only counterparts)
    for comp in (router, sc.consolidator, sc.autoscaler):
        if comp is not None and hasattr(comp, "set_carbon_trace"):
            comp.set_carbon_trace(trace)
    if sc.autoscaler is not None:
        sc.autoscaler.reset()
    cluster = Cluster(sc.devices)
    cluster.log_replicas = detail
    cluster.carbon_trace = trace      # before any replica/policy exists
    # per-device zone plumbing: each device prices its joules (and the
    # zone-aware router/consolidator price their candidates) against the
    # device's OWN zone trace; single-zone fleets bind the scenario
    # trace object everywhere, keeping them bit-exact
    zones = sc.device_zones()
    dev_traces = sc.device_carbon_traces(trace)
    multi_zone = len(set(zones.values())) > 1
    cluster.device_zones = zones
    cluster.device_traces = dev_traces
    for fm in sc.models:
        cluster.register_model(fm.spec)
    for fm in sc.models:                      # warm starts (Table-6 style)
        if fm.spec.home is None:
            continue
        mid = fm.spec.model_id
        home = fm.spec.home
        # prewarm respects capacity: an over-committed home falls back to
        # the least-loaded device that fits, else the model starts cold
        # (keeps the warm-everywhere baseline physically feasible)
        if not cluster.fits(home, mid):
            fitting = [d for d in sorted(cluster.devices)
                       if cluster.fits(d, mid)]
            if not fitting:
                continue
            home = min(fitting, key=lambda d: (cluster.occupancy(d),
                                               -cluster.free_vram_gb(d), d))
        cluster.replica(home, mid)
        cluster.managers[home].prewarm(mid)

    heap: List[Tuple[float, int, int, str, tuple]] = []
    seq = itertools.count()

    def push(t: float, phase: int, kind: str, data: tuple) -> None:
        heapq.heappush(heap, (t, phase, next(seq), kind, data))

    for fm in sc.models:
        for a in fm.arrivals_s:
            a = float(a)
            if 0.0 <= a < sc.horizon_s:
                push(a, _P_ARR, "arrival", (fm.spec.model_id,))
    if sc.consolidator is not None and sc.consolidator.period_s < sc.horizon_s:
        push(sc.consolidator.period_s, _P_CONS, "consolidate", ())
    if sc.autoscaler is not None and sc.autoscaler.tick_s < sc.horizon_s:
        push(sc.autoscaler.tick_s, _P_AUTO, "autoscale", ())

    # spot preemption: the model's draw is pure data, replayed here as
    # warn/off/restore faults.  No preemption model (or a draw with no
    # events) pushes nothing -- the heap, and the run, are bit-identical
    # to before the fault path existed.
    tiers = sc.device_tiers()
    revocations = (sc.preemptions.draw(sc.devices, tiers, sc.horizon_s)
                   if sc.preemptions is not None else [])
    for rv in revocations:
        if rv.warn_at_s < rv.off_at_s:
            push(rv.warn_at_s, _P_FAULT, "preempt_warn", (rv.device_id,))
        push(rv.off_at_s, _P_FAULT, "preempt_off", (rv.device_id,))
        if math.isfinite(rv.restore_at_s) and rv.restore_at_s < sc.horizon_s:
            push(rv.restore_at_s, _P_FAULT, "preempt_restore",
                 (rv.device_id,))

    rt = {did: DeviceRuntime(sc.max_batch) for did in cluster.devices}
    cluster.attach_runtime(rt, svc)
    cluster.snapshot_replicas(0.0)            # timeline origin (prewarms)

    # preemption bookkeeping: each device's fault epoch (completion
    # events carry the epoch they were scheduled under; a preempt_off
    # bumps it, orphaning every outstanding serve/load/wake completion),
    # and the in-flight request registry the OFF handler collects for
    # re-dispatch -- (model, slot) -> (arrival time, charged wait)
    epoch = {did: 0 for did in cluster.devices}
    inflight: Dict[str, Dict[Tuple[str, int], Tuple[float, float]]] = \
        {did: {} for did in cluster.devices}
    requeued = 0

    def begin_request(did: str, mid: str, arrival_t: float,
                      now: float) -> None:
        """Start serving one request NOW (caller checked residency and,
        for timed service, slot availability).  Service time is frozen
        at admission occupancy."""
        r = rt[did]
        svc_s = svc.request_service_s(cluster.specs[mid],
                                      cluster.devices[did],
                                      r.pool(mid).busy + 1)
        cluster.begin_serve(did, mid, arrival_t, service_s=svc_s)
        if svc_s <= 0.0:
            cluster.end_serve(did, mid)      # instantaneous, slot-free
            return
        slot = r.pool(mid).acquire()
        inflight[did][(mid, slot)] = (arrival_t, max(now - arrival_t, 0.0))
        push(now + svc_s, _P_DONE, "serve_done", (did, mid, slot,
                                                  epoch[did]))

    def drain_waiting(did: str, mid: str, now: float) -> None:
        """Admit waiters into free decode slots, oldest first."""
        r = rt[did]
        q = r.wait_q(mid)
        while q and not r.pool(mid).full:
            begin_request(did, mid, q.popleft(), now)

    def dispatch(did: str, mid: str, arrival_t: float, now: float) -> None:
        """Serve, queue, or trigger a load for one routed request."""
        r = rt[did]
        m = cluster.replica(did, mid)
        if m.resident:
            if r.pool(mid).full:
                r.wait_q(mid).append(arrival_t)
                return
            begin_request(did, mid, arrival_t, now)
            return
        r.wait_q(mid).append(arrival_t)
        if not m.loading and mid not in r.load_queued:
            r.load_queued.add(mid)
            r.load_q.append(("load", mid))
        pump_loader(did, now)

    def pump_loader(did: str, now: float) -> None:
        """Start the next queued (re)load/migration if the serialized
        loader channel is free.  A gated device wakes FIRST: the
        SLEEP -> BARE ramp serializes on the same channel (nothing can
        ingest weights on a sleeping device -- the state machine would
        raise), and the queued loads start when the wake lands."""
        r = rt[did]
        if cluster.power_state(did) is PowerState.OFF:
            return      # revoked: queued work waits for preempt_restore
        if (r.loading is None and r.load_q
                and cluster.power_state(did) is PowerState.SLEEP):
            dt = cluster.start_wake(did)
            r.loading = WAKE_CHANNEL
            r.loading_until = now + dt
            push(now + dt, _P_DONE, "wake_done", (did, epoch[did]))
            return
        while r.loading is None and r.load_q:
            item = r.load_q.popleft()
            mid = item[-1]
            if item[0] == "load":
                m = cluster.replica(did, mid)
                if m.resident or m.loading:
                    # a migration raced the request here and landed (or
                    # is landing) the model: nothing left to load
                    r.load_queued.discard(mid)
                    if m.resident:
                        drain_waiting(did, mid, now)
                    continue
                dt = cluster.start_load(did, mid)
            else:                            # ("mig", src, mid)
                src = item[1]
                if rt[src].busy:
                    # source started working (possibly serving, or
                    # holding queued requests for, this very model)
                    # since the plan: defer to the next pass
                    continue
                m = cluster.replica(did, mid)
                if m.resident or m.loading:
                    # a request raced the plan and loaded it here;
                    # dedupe the source copy
                    if src != did and mid in cluster.managers[src].models:
                        src_m = cluster.managers[src].models[mid]
                        if src_m.resident:
                            cluster.managers[src].unload(mid)
                            cluster.sync_power(src)
                    continue
                src_m = cluster.managers[src].models.get(mid)
                if src_m is None or not src_m.resident:
                    continue                 # source evicted it meanwhile
                dt = cluster.start_migration(mid, src, did)
                cluster.sync_power(src)
            r.loading = mid
            r.loading_until = now + dt
            push(now + dt, _P_DONE, "load_done", (did, mid, epoch[did]))

    while heap:
        t, _phase, _s, kind, data = heapq.heappop(heap)
        if (kind in ("serve_done", "load_done", "wake_done")
                and data[-1] != epoch[data[0]]):
            continue      # orphaned by a preemption; device was reset
        cluster.advance_to(t)
        if kind == "arrival":
            (mid,) = data
            did = router.choose(mid, t, cluster)
            cluster.observe_arrival(mid, did, t)
            # pin the routed replica: queued demand must not be evicted
            # (by its armed idle timeout OR by make_room capacity
            # pressure) while the request waits for a slot or a load;
            # end_serve unpins and re-arms after serving
            rep = cluster.replica(did, mid)
            rep.pins += 1
            rep.evict_at = math.inf
            dispatch(did, mid, t, t)
            cluster.sync_power(did)
        elif kind == "wake_done":
            did, _ep = data
            rt[did].loading = None
            cluster.finish_wake(did)
            pump_loader(did, t)              # start the queued loads
            cluster.sync_power(did)
        elif kind == "load_done":
            did, mid, _ep = data
            r = rt[did]
            cluster.finish_load(did, mid)
            r.loading = None
            r.load_queued.discard(mid)
            m = cluster.managers[did].models[mid]
            if m.pins > 0:
                m.evict_at = math.inf        # queued demand stays pinned
            drain_waiting(did, mid, t)
            pump_loader(did, t)
            cluster.sync_power(did)
        elif kind == "serve_done":
            did, mid, slot, _ep = data
            inflight[did].pop((mid, slot), None)
            rt[did].pool(mid).release(slot)
            cluster.end_serve(did, mid)
            drain_waiting(did, mid, t)
            cluster.sync_power(did)
        elif kind == "autoscale":
            for act in sc.autoscaler.plan(cluster, t):
                if isinstance(act, ScaleOut):
                    r = rt[act.dst]
                    m = cluster.replica(act.dst, act.model_id)
                    q_slots, q_vram = cluster.queued_load_demand(act.dst)
                    lost_fit = (
                        cluster.free_slots(act.dst) - q_slots < 1
                        or cluster.free_vram_gb(act.dst) - q_vram
                        < cluster.specs[act.model_id].vram_gb)
                    queued_mig = any(item[-1] == act.model_id
                                     for item in r.load_q)
                    if (m.resident or m.loading or queued_mig
                            or act.model_id in r.load_queued or lost_fit):
                        continue      # raced a routed load/mig, lost fit
                    # the controller owns this replica's lifetime: it
                    # parks through lulls (held) until scale-in retires
                    # it -- that standing warmth is the over-provisioning
                    # parking tax the bench quantifies
                    m.held = True
                    r.load_queued.add(act.model_id)
                    r.load_q.append(("load", act.model_id))
                    sc.autoscaler.scale_outs += 1
                    pump_loader(act.dst, t)
                    cluster.sync_power(act.dst)
                elif cluster.scale_in(act.src, act.model_id):
                    sc.autoscaler.scale_ins += 1
            nxt = t + sc.autoscaler.tick_s
            if nxt < sc.horizon_s:
                push(nxt, _P_AUTO, "autoscale", ())
        elif kind == "consolidate":
            busy_map = {did: r.busy for did, r in rt.items()}
            for mv in sc.consolidator.plan(cluster, t, busy_map):
                rt[mv.dst].load_q.append(("mig", mv.src, mv.model_id))
                pump_loader(mv.dst, t)
                cluster.sync_power(mv.dst)
            # power gating rides the same tick: devices the packing
            # passes drained (and anything else settled at bare past the
            # wake-energy breakeven) fall below p_base to SLEEP
            for did in sc.consolidator.plan_gating(cluster, t, busy_map):
                cluster.gate_device(did)
            nxt = t + sc.consolidator.period_s
            if nxt < sc.horizon_s:
                push(nxt, _P_CONS, "consolidate", ())
        elif kind == "preempt_warn":
            # provider warning: stop placing on the device (routers,
            # autoscaler, consolidator targets all skip revoked ids);
            # in-flight work rides out the warning window
            (did,) = data
            cluster.revoked.add(did)
        elif kind == "preempt_off":
            (did,) = data
            cluster.revoked.add(did)
            epoch[did] += 1           # orphan outstanding completions
            r = rt[did]
            # collect every request the revocation strands, oldest
            # first: wait-queue entries (never started) keep their
            # arrival time; in-flight serves are cancelled -- their
            # count and charged wait move with them (conservation),
            # and the re-dispatch re-charges the full wait including
            # the preemption delay
            orphans: List[Tuple[float, str]] = []
            for mid in sorted(r._waiting):
                for arr_t in r._waiting[mid]:
                    orphans.append((arr_t, mid))
            for (mid, slot), (arr_t, wait) in sorted(inflight[did].items()):
                cluster.cancel_serve(did, mid, wait)
                orphans.append((arr_t, mid))
            inflight[did] = {}
            cluster.force_off(did)    # drops residents, meter -> OFF
            rt[did] = DeviceRuntime(sc.max_batch)   # queues/slots die too
            for arr_t, mid in sorted(orphans):
                ndid = router.choose(mid, t, cluster)
                # re-placement, not a new arrival: rates were already
                # observed at the true arrival -- just pin and dispatch
                rep = cluster.replica(ndid, mid)
                rep.pins += 1
                rep.evict_at = math.inf
                dispatch(ndid, mid, arr_t, t)
                cluster.sync_power(ndid)
                requeued += 1
        elif kind == "preempt_restore":
            (did,) = data
            cluster.restore_device(did)       # OFF -> BARE, placeable
            pump_loader(did, t)               # work queued mid-outage
            cluster.sync_power(did)
        if kind != "serve_done":      # serving never changes residency
            cluster.snapshot_replicas(t)

    # trailing idle out to the horizon (a load may overshoot it, exactly
    # as the single-device simulator lets the final burst overshoot)
    cluster.advance_to(max(sc.horizon_s, cluster.clock()))
    cluster.snapshot_replicas(cluster.clock())

    totals = cluster.device_totals()          # flushes every meter to now
    reports = []
    cold = reqs = 0
    latency = 0.0
    samples: List[float] = []
    fleet_segments: List[Tuple[float, float, float]] = []
    for did in sorted(cluster.devices):
        mm = cluster.managers[did]
        d_cold = sum(m.cold_starts for m in mm.models.values())
        d_reqs = sum(m.requests for m in mm.models.values())
        latency += sum(m.added_latency_s for m in mm.models.values())
        for m in mm.models.values():
            samples.extend(m.latency_samples)
        cold += d_cold
        reqs += d_reqs
        fleet_segments.extend(mm.meter.timeline)
        reports.append(DeviceReport(
            instance_id=did, sku=cluster.devices[did].sku.key,
            energy_wh=totals[did],
            parking_tax_wh=mm.meter.parking_tax_wh(),
            cold_starts=d_cold, requests=d_reqs,
            resident=mm.resident_ids(), meter_state=mm.meter.state.value,
            carbon_kg=dev_traces[did].carbon_for_segments(
                mm.meter.timeline),
            zone=zones[did],
            durations_s=mm.meter.durations(),
            wakes=mm.meter.wakes,
            gated_wh_saved=mm.meter.gated_wh_saved()))

    lb_nongated, cv_sum = (clairvoyant_bound(sc) if compute_bound
                           else (0.0, 0.0))
    energy = sum(r.total_wh for r in reports)
    mix = get_mix(sc.zone)
    state_wh: Dict[str, float] = {}
    state_s: Dict[str, float] = {}
    for r in reports:
        for k, v in r.energy_wh.items():
            if k != "total":
                state_wh[k] = state_wh.get(k, 0.0) + v
        for k, v in r.durations_s.items():
            state_s[k] = state_s.get(k, 0.0) + v
    zone_wh, zone_kg = zone_decomposition(reports)
    if multi_zone:
        # dollars and the scalar bookkeeping price each zone's joules at
        # that zone's rates; the carbon timeline integrates each
        # device's segments against ITS trace (device order unchanged)
        energy_usd = math.fsum(
            energy_cost_usd(wh, get_mix(z)) for z, wh in zone_wh.items())
        kg_flat = math.fsum(
            carbon_kg(wh, get_mix(z)) for z, wh in zone_wh.items())
        timeline = carbon_timeline_multi_kg(
            [(dev_traces[did], seg) for did in sorted(cluster.devices)
             for seg in cluster.managers[did].meter.timeline],
            end_s=sc.horizon_s) if detail else []
    else:
        energy_usd = energy_cost_usd(energy, mix)
        kg_flat = carbon_kg(energy, mix)
        timeline = carbon_timeline_kg(trace, fleet_segments,
                                      end_s=sc.horizon_s) if detail else []
    cost = price_fleet(sc.devices, reports, default_tier=sc.price_tier,
                       energy_usd=energy_usd)
    return FleetResult(
        router=router.name, horizon_s=sc.horizon_s, devices=reports,
        energy_wh=energy,
        parking_tax_wh=sum(r.parking_tax_wh for r in reports),
        cold_starts=cold, requests=reqs,
        added_latency_s_total=latency, migrations=cluster.migrations,
        lb_nongated_wh=lb_nongated, cv_per_model_wh=cv_sum,
        infra_usd=fleet_price_usd(sc.devices, sc.horizon_s, sc.price_tier),
        energy_usd=energy_usd,
        carbon_kg=math.fsum(r.carbon_kg for r in reports),
        carbon_kg_flat=kg_flat,
        carbon_trace_name=trace.name,
        carbon_timeline=timeline,
        power_timeline=fleet_segments,
        zone_energy_wh=zone_wh, zone_carbon_kg=zone_kg,
        transfer_wh=cluster.transfer_j / 3600.0,
        cross_zone_migrations=cluster.cross_zone_migrations,
        latencies_s=np.sort(np.asarray(samples, dtype=float)),
        replica_timeline={mid: list(log)
                          for mid, log in cluster.replica_log.items()},
        scale_outs=(sc.autoscaler.scale_outs if sc.autoscaler else 0),
        scale_ins=(sc.autoscaler.scale_ins if sc.autoscaler else 0),
        state_energy_wh=state_wh, state_durations_s=state_s,
        gates=cluster.gates,
        wakes=sum(r.wakes for r in reports),
        gated_wh_saved=math.fsum(r.gated_wh_saved for r in reports),
        cost_usd=cost.cost_usd, gpu_hours_usd=cost.gpu_hours_usd,
        device_gpu_usd=cost.device_gpu_usd,
        device_cost_usd=cost.device_cost_usd,
        zone_cost_usd=cost.zone_cost_usd, device_tiers=cost.device_tiers,
        preemptions=cluster.preemptions, requeued_requests=requeued,
        tier_billed_s=tier_billed_seconds(sc.devices, reports,
                                          sc.price_tier))


def zone_decomposition(reports: Sequence[DeviceReport]
                       ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Per-zone (energy_wh, carbon_kg) decompositions of a device-report
    list.  ``fsum`` per zone, so the values are correctly rounded and
    the decomposition sums back to the global totals regardless of
    device order (shared by ``run_fleet`` and ``run_mega``)."""
    zones = sorted({r.zone for r in reports})
    wh = {z: math.fsum(r.total_wh for r in reports if r.zone == z)
          for z in zones}
    kg = {z: math.fsum(r.carbon_kg for r in reports if r.zone == z)
          for z in zones}
    return wh, kg


# ---------------------------------------------------------------------------
# Clairvoyant lower bound (offline, fleet-best constants).
# ---------------------------------------------------------------------------

def _best_constants(sc: FleetScenario, fm: FleetModel) -> Tuple[float, float]:
    """(min DVFS step across devices, min above-bare reload energy)."""
    step_min = min(d.profile.dvfs_step_w for d in sc.devices)
    load_min = math.inf
    for d in sc.devices:
        if fm.spec.loader is not None:
            ld = fm.spec.loader
        else:
            ld = loader_from_checkpoint(fm.spec.model_id,
                                        fm.spec.checkpoint_bytes, d.profile)
        load_min = min(load_min,
                       max(ld.p_load_w - d.profile.p_base_w, 0.0)
                       * ld.t_load_s)
    return step_min, load_min


def clairvoyant_bound(sc: FleetScenario) -> Tuple[float, float]:
    """(lb_nongated_wh, cv_per_model_wh) -- see module docstring.

    Assumes the paper's evaluation convention of service energy held
    constant across policies (service_s == 0); with service enabled the
    bound still excludes service energy and is simply looser.  SCOPE:
    the ``p_base`` floor term assumes devices never sleep, so these are
    floors for NON-GATED runs only.  A power-GATED run (Consolidator
    ``gate_drained_devices``) can legitimately land BELOW both values --
    that is the point of gating -- which is why ``FleetResult`` reports
    them under the explicitly scoped name ``lb_nongated_wh`` rather
    than as a universal lower bound.
    """
    base_j = sum(d.profile.p_base_w for d in sc.devices) * sc.horizon_s
    extras = []
    for fm in sc.models:
        step_min, load_min = _best_constants(sc, fm)
        arr = sorted(float(a) for a in fm.arrivals_s
                     if 0.0 <= a < sc.horizon_s)
        extra = 0.0
        if not arr:
            extras.append(0.0)
            continue
        if fm.spec.home is not None:
            gaps = np.diff([0.0] + arr)       # starts warm at t=0
        else:
            extra += load_min                 # must load at least once
            gaps = np.diff(arr)
        for g in gaps:
            extra += min(step_min * g, load_min)
        extras.append(extra)
    lb_nongated = (base_j + (max(extras) if extras else 0.0)) / 3600.0
    cv_sum = (base_j + sum(extras)) / 3600.0
    return lb_nongated, cv_sum


# ---------------------------------------------------------------------------
# Convenience constructors.
# ---------------------------------------------------------------------------

def mixed_fleet_scenario(policy_factory, router, *,
                         consolidate: Union[bool, Consolidator] = False,
                         n_models: int = 10,
                         fleet: str = "2xh100+2xa100+2xl40s",
                         horizon_s: float = DAY, seed: int = 100,
                         service_s: float = 0.0,
                         service_model: Optional[ServiceTimeModel] = None,
                         max_batch: int = 4,
                         autoscaler: Optional[ReplicaAutoscaler] = None,
                         carbon_trace: Union[CarbonTrace, str, None] = None,
                         zone: str = "USA") -> FleetScenario:
    """The ISSUE's reference scenario (shared by bench_fleet and the
    fleet_parking example): N models under a diurnal + bursty +
    heavy-tail + steady traffic rotation on a mixed-architecture fleet.

    Checkpoints span ~5..5+3.5(N-1) GB so placement interacts with
    capacity; every model starts prewarmed round-robin (the always-on
    operating point the paper says industry defaults to).

    ``consolidate`` accepts a configured ``Consolidator`` (e.g. the
    carbon-aware one) or a bool for the default; ``carbon_trace``
    passes through to ``FleetScenario.carbon_trace``."""
    from repro.core import traffic
    patterns = ["diurnal", "bursty", "mmpp", "steady"]
    devices = build_fleet(fleet)
    models: List[FleetModel] = []
    gb = 1024 ** 3
    for i in range(n_models):
        arr = traffic.PATTERNS[patterns[i % len(patterns)]](seed=seed + i)
        arr = arr[arr < horizon_s]
        ckpt_gb = 5.0 + 3.5 * i
        spec = FleetModelSpec(
            model_id=f"m{i}", policy_factory=policy_factory,
            checkpoint_bytes=int(ckpt_gb * gb), vram_gb=ckpt_gb * 1.1,
            home=devices[i % len(devices)].instance_id)
        models.append(FleetModel(spec, arr))
    if isinstance(consolidate, Consolidator):
        cons: Optional[Consolidator] = consolidate
    else:
        cons = Consolidator() if consolidate else None
    return FleetScenario(devices=devices, models=models, router=router,
                         horizon_s=horizon_s, service_s=service_s,
                         service_model=service_model, max_batch=max_batch,
                         consolidator=cons, autoscaler=autoscaler,
                         carbon_trace=carbon_trace, zone=zone)


def single_device_scenario(arrivals_s: Sequence[float], policy_factory,
                           loader, sku_key: str = "h100", *,
                           horizon_s: float = DAY, start_warm: bool = True,
                           service_s: float = 0.0, max_batch: int = 1,
                           autoscaler: Optional[ReplicaAutoscaler] = None
                           ) -> FleetScenario:
    """1 device x 1 model -- the fleet degenerate case that must agree
    with ``core.simulator.simulate`` (tested to 1e-6 Wh).  max_batch
    defaults to 1 because the reference simulator serializes service;
    with service_s=0 any slot count is equivalent (tested)."""
    devices = build_fleet([sku_key])
    spec = FleetModelSpec(
        model_id="m0", policy_factory=policy_factory, loader=loader,
        home=devices[0].instance_id if start_warm else None)
    return FleetScenario(devices=devices,
                         models=[FleetModel(spec, list(arrivals_s))],
                         router="warm-first", horizon_s=horizon_s,
                         service_s=service_s, max_batch=max_batch,
                         autoscaler=autoscaler)
