"""GPU fleet catalog: SKUs, capacity, prices, and electricity mixes.

The fleet layer needs three things the per-device ``DeviceProfile`` does
not carry: (1) capacity -- how many models a device can host (VRAM +
runtime slots), (2) what an hour of the device costs, and (3) what a
kWh drawn in some region costs in dollars and in carbon.  The shapes
follow the two related repos: a cloud GPU catalog keyed by SKU with
per-tier prices (dgx-cloud demo) and a per-zone electricity-mix
repository (ecologits).

Prices are representative public cloud list prices (USD per device-hour,
mid-2026), NOT paper measurements: the bench reports relative numbers
and clearly labels absolute dollars as catalog estimates.  Carbon
intensities are grid yearly averages (kgCO2e/kWh); the USA value matches
``repro.core.impact.US_GRID_KG_CO2_PER_KWH``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Union

from repro.core import power_states
from repro.core.impact import US_GRID_KG_CO2_PER_KWH
from repro.core.power_model import DeviceProfile, get_profile


# ---------------------------------------------------------------------------
# Electricity mixes (ecologits idiom: one record per zone).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElectricityMix:
    """Grid characteristics of one operating zone.

    gwp_kg_per_kwh: Global Warming Potential of the mix (kgCO2eq/kWh)
                    -- the DAILY MEAN; the time-varying intensity curve
                    is ``trace_shape`` scaled to this mean
                    (fleet/carbon.py ``trace_for_zone``).
    usd_per_kwh:    industrial electricity price.
    trace_shape:    preset diurnal shape name in ``carbon.TRACE_SHAPES``
                    ("flat" / "solar-duck" / "wind-night").
    tz_offset_s:    local-clock offset vs the fleet's shared sim clock
                    (which is US-fleet local time, the paper's telemetry
                    frame).  Shapes are authored in LOCAL hours (solar
                    trough ~13:00 local); ``trace_for_zone`` phase-shifts
                    them onto the sim clock, so zones peak and trough at
                    different sim times -- the spread follow-the-sun
                    placement exploits.
    region:         coarse geographic region ("NA"/"EU"/"AS"/"GLOBAL"),
                    used by ``zone_hops`` to price cross-zone transfers.
    """
    zone: str
    gwp_kg_per_kwh: float
    usd_per_kwh: float
    trace_shape: str = "flat"
    tz_offset_s: float = 0.0
    region: str = "GLOBAL"


# The USA intensity is DERIVED from core.impact (single source of truth
# for the paper's 180 kT figure); core cannot import fleet, so the
# dependency points this way.
MIXES: Dict[str, ElectricityMix] = {
    "WOR": ElectricityMix("WOR", 0.481, 0.14),   # world average
    "USA": ElectricityMix("USA", US_GRID_KG_CO2_PER_KWH, 0.12,
                          trace_shape="solar-duck", region="NA"),
    "DEU": ElectricityMix("DEU", 0.350, 0.26, trace_shape="solar-duck",
                          tz_offset_s=7 * 3600.0, region="EU"),
    "FRA": ElectricityMix("FRA", 0.056, 0.18,    # nuclear: near-flat
                          tz_offset_s=7 * 3600.0, region="EU"),
    "SWE": ElectricityMix("SWE", 0.020, 0.10, trace_shape="wind-night",
                          tz_offset_s=7 * 3600.0, region="EU"),
    "IND": ElectricityMix("IND", 0.708, 0.08, trace_shape="solar-duck",
                          tz_offset_s=11.5 * 3600.0, region="AS"),
}


def get_mix(zone: str) -> ElectricityMix:
    """Look up a zone's electricity mix (case-insensitive; KeyError
    lists the known zones)."""
    key = zone.upper()
    if key not in MIXES:
        raise KeyError(f"unknown electricity mix {zone!r}; have {sorted(MIXES)}")
    return MIXES[key]


def energy_cost_usd(energy_wh: float, mix: ElectricityMix) -> float:
    """Dollar cost of ``energy_wh`` at the zone's industrial price."""
    return energy_wh / 1e3 * mix.usd_per_kwh


def carbon_kg(energy_wh: float, mix: ElectricityMix) -> float:
    """SCALAR kgCO2e of ``energy_wh`` at the zone's mean intensity --
    the fixed-intensity bookkeeping the paper uses.  Time-varying
    pricing lives in fleet/carbon.py (equal to this under a flat
    trace, pinned to 1e-9 kg)."""
    return energy_wh / 1e3 * mix.gwp_kg_per_kwh


# ---------------------------------------------------------------------------
# SKUs (cloud-catalog idiom: capacity + per-tier device-hour prices).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GPUSku:
    """One rentable accelerator model: power physics + capacity + price."""
    key: str
    profile: DeviceProfile
    slots: int                       # max co-resident model contexts
    usd_per_hr: float                # on-demand device-hour price
    usd_per_hr_reserved: float
    usd_per_hr_spot: float
    # peak dense bf16 throughput (vendor datasheet, no sparsity): the
    # compute roof the service-time model (serving/service_model.py)
    # divides through its MFU; memory bandwidth rides on the profile.
    tflops_bf16: float = 0.0

    @property
    def vram_gb(self) -> float:
        return self.profile.vram_capacity_gb

    def price_usd_per_hr(self, tier: str = "on_demand") -> float:
        try:
            return {"on_demand": self.usd_per_hr,
                    "reserved": self.usd_per_hr_reserved,
                    "spot": self.usd_per_hr_spot}[tier]
        except KeyError:
            raise KeyError(f"unknown price tier {tier!r}") from None


# Purchase tiers a device can be rented under.  Billing semantics live
# in fleet/pricing.py: on_demand and spot bill only powered-on hours
# (SLEEP/OFF release the device), reserved bills the whole horizon;
# spot is the only tier subject to preemption.
PRICE_TIERS = ("on_demand", "reserved", "spot")


def normalize_tier(tier: str) -> str:
    """Canonicalize a price-tier name (case/dash-insensitive; KeyError
    lists the tiers)."""
    t = tier.lower().replace("-", "_")
    if t not in PRICE_TIERS:
        raise KeyError(f"unknown price tier {tier!r}; have "
                       f"{sorted(PRICE_TIERS)}")
    return t


CATALOG: Dict[str, GPUSku] = {
    "h100": GPUSku("h100", get_profile("h100"), slots=8,
                   usd_per_hr=6.98, usd_per_hr_reserved=4.80,
                   usd_per_hr_spot=2.90, tflops_bf16=989.0),
    "a100": GPUSku("a100", get_profile("a100"), slots=8,
                   usd_per_hr=4.10, usd_per_hr_reserved=3.20,
                   usd_per_hr_spot=1.70, tflops_bf16=312.0),
    "l40s": GPUSku("l40s", get_profile("l40s"), slots=6,
                   usd_per_hr=1.90, usd_per_hr_reserved=1.40,
                   usd_per_hr_spot=0.80, tflops_bf16=362.0),
    "tpu_v5e": GPUSku("tpu_v5e", get_profile("tpu_v5e"), slots=2,
                      usd_per_hr=1.20, usd_per_hr_reserved=0.94,
                      usd_per_hr_spot=0.50, tflops_bf16=197.0),
}


def get_sku(key: str) -> GPUSku:
    """Look up a SKU by key (case/dash-insensitive; KeyError lists the
    catalog)."""
    k = key.lower().replace("-", "_")
    if k not in CATALOG:
        raise KeyError(f"unknown SKU {key!r}; have {sorted(CATALOG)}")
    return CATALOG[k]


# ---------------------------------------------------------------------------
# Fleet construction.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceInstance:
    """One physical device in the fleet (SKU + stable identity).

    ``zone`` is the device's electricity zone (a ``MIXES`` key), or
    ``None`` to inherit the scenario zone -- so single-zone fleets carry
    no per-device zone state and every existing spec parses unchanged.
    ``tier`` is the device's purchase tier (a ``PRICE_TIERS`` entry), or
    ``None`` to inherit the scenario ``price_tier`` -- same inheritance
    shape as zones, so tier-less specs parse unchanged too.
    """
    instance_id: str
    sku: GPUSku
    zone: Optional[str] = None
    tier: Optional[str] = None

    @property
    def profile(self) -> DeviceProfile:
        return self.sku.profile


_SPEC_PART = re.compile(
    r"^\s*(?:(\d+)\s*[xX]\s*)?([a-zA-Z0-9_\-]+?)\s*(?:@\s*([a-zA-Z]+)\s*)?"
    r"(?::\s*([a-zA-Z_\-]+)\s*)?$")


def _split_token(key: str) -> tuple:
    """Split an ``sku[@ZONE][:tier]`` token into (sku_key, zone, tier)."""
    tier = None
    if ":" in key:
        key, _, t = key.partition(":")
        tier = normalize_tier(t.strip())
    if "@" in key:
        sku_key, _, zone = key.partition("@")
        return sku_key.strip(), get_mix(zone.strip()).zone, tier
    return key.strip(), None, tier


def build_fleet(spec: Union[str, Sequence[str]]) -> List[DeviceInstance]:
    """Build device instances from a spec like ``"2xh100+2xa100+2xl40s"``.

    Each part takes an optional ``@ZONE`` suffix pinning those devices
    to an electricity zone (``"2xh100@DEU+2xa100@USA+2xl40s@IND"``) and
    an optional ``:tier`` suffix pinning their purchase tier
    (``"2xh100@DEU:spot"``); zone-less / tier-less parts inherit the
    scenario zone / price tier at run time.  Also accepts a sequence of
    SKU keys (``"sku[@ZONE][:tier]"``, one instance each).  Instance ids
    are ``<sku>-<i>`` and are stable across runs (deterministic routing
    tie-breaks sort on them).
    """
    if isinstance(spec, str):
        parts = [p for p in spec.split("+") if p.strip()]
        if not parts:
            raise ValueError(f"empty fleet spec {spec!r}")
        expanded: List[str] = []
        for part in parts:
            m = _SPEC_PART.match(part)
            if not m:
                raise ValueError(f"bad fleet spec part {part!r}")
            count = int(m.group(1) or 1)
            token = (m.group(2)
                     + (f"@{m.group(3)}" if m.group(3) else "")
                     + (f":{m.group(4)}" if m.group(4) else ""))
            expanded.extend([token] * count)
    else:
        expanded = list(spec)
    counters: Dict[str, int] = {}
    out: List[DeviceInstance] = []
    for key in expanded:
        sku_key, zone, tier = _split_token(key)
        sku = get_sku(sku_key)
        i = counters.get(sku.key, 0)
        counters[sku.key] = i + 1
        out.append(DeviceInstance(instance_id=f"{sku.key}-{i}", sku=sku,
                                  zone=zone, tier=tier))
    return out


def fleet_price_usd(devices: Sequence[DeviceInstance], horizon_s: float,
                    tier: str = "on_demand") -> float:
    """Infrastructure (rental) cost of holding the fleet for the horizon."""
    hours = horizon_s / 3600.0
    return sum(d.sku.price_usd_per_hr(tier) for d in devices) * hours


# ---------------------------------------------------------------------------
# Cross-zone transfer costs (follow-the-sun placement / migration).
# ---------------------------------------------------------------------------

# Moving a checkpoint between zones is not free: the WAN transfer burns
# network+storage energy and adds wall-clock before the load can start.
# Both are priced per GB per "hop" -- 0 hops within a zone, 1 between
# zones of the same region, 2 cross-region (the WOR pseudo-zone counts
# as its own region, so it is always 2 hops from a real zone).
XFER_J_PER_GB_HOP = 5400.0      # ~1.5 Wh/GB/hop (WAN transport estimate)
XFER_S_PER_GB_HOP = 0.8         # ~1.25 GB/s per hop (~10 Gbit effective)


def zone_hops(zone_a: str, zone_b: str) -> int:
    """Transfer distance between two zones in pricing hops."""
    a, b = get_mix(zone_a), get_mix(zone_b)
    if a.zone == b.zone:
        return 0
    if a.region == b.region and a.region != "GLOBAL":
        return 1
    return 2


def transfer_cost_j(checkpoint_gb: float, zone_a: str, zone_b: str) -> float:
    """Network energy of moving ``checkpoint_gb`` between zones (J)."""
    return XFER_J_PER_GB_HOP * checkpoint_gb * zone_hops(zone_a, zone_b)


def transfer_latency_s(checkpoint_gb: float, zone_a: str,
                       zone_b: str) -> float:
    """Added wall-clock of the cross-zone checkpoint transfer (s)."""
    return XFER_S_PER_GB_HOP * checkpoint_gb * zone_hops(zone_a, zone_b)


# ---------------------------------------------------------------------------
# Scale-out placement costs (replica autoscaling).
# ---------------------------------------------------------------------------

def marginal_park_w(device: DeviceInstance, context_on: bool) -> float:
    """Marginal power of holding ONE MORE warm replica on this device.

    The DVFS step is per-device: a device that already has a live
    context has paid it, so an extra replica parks for free there;
    a bare device pays its full step the moment the context comes up.
    This is the watt rate behind the over-provisioning parking tax."""
    return 0.0 if context_on else device.profile.dvfs_step_w


def above_base_load_j(device: DeviceInstance, loader) -> float:
    """Above-bare-idle energy of one (re)load on this device (the
    energy-exact reload cost the autoscaler's ski-rental tests use).
    Load watts resolve through ``DeviceProfile.load_power_w`` -- the
    loader's own number when it has one, the SKU's catalog ``p_load_w``
    otherwise -- the same rule the EnergyMeter prices LOADING with."""
    return max(device.profile.load_power_w(loader)
               - device.profile.p_base_w, 0.0) * loader.t_load_s


def wake_cost_j(device: DeviceInstance, hold_s: float = 0.0) -> float:
    """Marginal joules of WAKING this device for a placement versus
    leaving it gated: the wake ramp's above-sleep energy plus the
    bare-minus-sleep delta over the expected awake window.  Added to a
    sleeping candidate's cold-placement score by the energy-aware
    routers and the autoscaler (gated devices are cheap watts but not
    free first-token)."""
    return power_states.wake_penalty_j(device.profile, hold_s)


def wake_cost_kg(device: DeviceInstance, trace, now_s: float,
                 t_warm_s: float, hold_s: float) -> float:
    """kgCO2e analogue of ``wake_cost_j`` under a grid-intensity trace:
    the ramp burst priced at the [now, t_warm] window's mean intensity,
    the above-sleep hold INTEGRATED over its own window (the hold can
    span trace swings).  One formula for the carbon-aware router and
    autoscaler, so the two cannot drift apart."""
    prof = device.profile
    return (wake_cost_j(device, 0.0) * trace.mean(now_s, t_warm_s)
            + (prof.p_base_w - prof.p_sleep_w)
            * trace.integral(t_warm_s, t_warm_s + max(hold_s, 0.0))
            ) / 3.6e6


def scaleout_cost_j(device: DeviceInstance, loader, hold_s: float, *,
                    context_on: bool) -> float:
    """Expected joules of placing one more warm replica on ``device``:
    the above-bare load burst plus the marginal parking power held for
    ``hold_s`` (the planner caps hold_s at the device's breakeven
    window, so an always-idle replica is priced at one reload)."""
    return (above_base_load_j(device, loader)
            + marginal_park_w(device, context_on) * max(hold_s, 0.0))
