"""Telemetry substrate: power sampling interfaces + the simulated oracle.

The paper's measurements come from nvidia-smi / DCGM at 30 s cadence.  This
module provides the hardware-agnostic ``PowerReader`` interface the
dose-response harness and the serving EnergyMeter consume, plus a
``SimulatedPowerReader`` whose *ground truth is the paper's physics*:

  * idle power is exactly Eq. 1 with the profile's (true) beta,
  * within-phase noise is AR(1) with the per-device sigma of section 3.3
    (tau ~ 6-10 samples of thermal correlation, Eq. 6),
  * an optional slow thermal drift reproduces the A100's confounded
    negative slope (section 4.2: -0.09 W over 72 GB <-> 0.7 C HBM drift),
  * per-instance intercept offsets reproduce the ~23 W inter-node spread.

On real hardware one would register an SMI/DCGM-backed reader with the same
interface; nothing downstream changes (DESIGN.md section 3).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Protocol, Sequence

import numpy as np

from repro.core.power_model import DeviceProfile


@dataclasses.dataclass(frozen=True)
class PowerSample:
    t_s: float              # seconds since epoch of the experiment
    power_w: float
    util_pct: float
    vram_gb: float
    sm_clock_mhz: float
    temp_c: float
    device: str
    context_active: bool


class PowerReader(Protocol):
    """One accelerator's telemetry stream (30 s cadence by default)."""

    def sample(self, t_s: float) -> PowerSample: ...

    def set_state(self, *, context_active: bool, vram_gb: float) -> None: ...


class SimulatedPowerReader:
    """Paper-physics oracle for one device instance.

    AR(1) noise: x_t = rho * x_{t-1} + sqrt(1-rho^2) * sigma * eps_t keeps the
    *stationary* std at sigma while giving the thermal autocorrelation time
    tau = -1/ln(rho) samples (paper Eq. 6 uses tau ~ 6-10 at 30 s cadence).
    """

    def __init__(
        self,
        profile: DeviceProfile,
        *,
        seed: int = 0,
        instance_offset_w: float = 0.0,
        thermal_drift_w_per_hr: float = 0.0,
        ar_tau_samples: float = 8.0,
        base_temp_c: float = 50.0,
    ) -> None:
        self.profile = profile.with_instance_offset(instance_offset_w)
        self._rng = np.random.default_rng(seed)
        self._rho = float(np.exp(-1.0 / ar_tau_samples))
        self._noise_state = 0.0
        self._drift_w_per_s = thermal_drift_w_per_hr / 3600.0
        self._base_temp_c = base_temp_c
        self._context_active = False
        self._vram_gb = 0.0
        self._util = 0.0

    # -- state the experiment manipulates ---------------------------------
    def set_state(self, *, context_active: bool, vram_gb: float,
                  util: float = 0.0) -> None:
        if vram_gb < 0 or vram_gb > self.profile.vram_capacity_gb:
            raise ValueError(
                f"vram {vram_gb} GB out of range for {self.profile.name} "
                f"(capacity {self.profile.vram_capacity_gb} GB)")
        self._context_active = context_active
        self._vram_gb = vram_gb
        self._util = util

    # -- telemetry ---------------------------------------------------------
    def sample(self, t_s: float) -> PowerSample:
        sigma = self.profile.sigma_w
        eps = self._rng.standard_normal()
        self._noise_state = (self._rho * self._noise_state
                             + np.sqrt(1.0 - self._rho ** 2) * sigma * eps)
        if self._util > 0:
            mean = self.profile.active_power_w(self._util)
        else:
            mean = self.profile.idle_power_w(self._context_active, self._vram_gb)
        # slow monotone thermal drift (models the A100 cooling transient that
        # confounds a sequential dose ladder into a tiny negative slope)
        drift = -self._drift_w_per_s * t_s
        power = mean + drift + self._noise_state
        clock = (self.profile.sm_clock_ctx_mhz if self._context_active
                 else self.profile.sm_clock_idle_mhz)
        # 0.7 C drift over the ladder scaled off the power drift
        temp = self._base_temp_c + drift * 0.5
        return PowerSample(
            t_s=t_s, power_w=float(power), util_pct=float(self._util * 100.0),
            vram_gb=self._vram_gb, sm_clock_mhz=clock, temp_c=float(temp),
            device=self.profile.name, context_active=self._context_active,
        )

    def record_phase(self, *, t0_s: float, n: int,
                     interval_s: float = 30.0) -> List[PowerSample]:
        """Record n samples at fixed cadence (one dose-response phase)."""
        return [self.sample(t0_s + i * interval_s) for i in range(n)]


# ---------------------------------------------------------------------------
# Phase 1: production fleet telemetry (14 H100s, 18 days, 30 s cadence).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetDataset:
    """Column-oriented Phase-1 dataset (numpy arrays, one row per sample)."""
    power_w: np.ndarray
    util_pct: np.ndarray
    vram_gb: np.ndarray
    sm_clock_mhz: np.ndarray
    gpu_id: np.ndarray
    context_active: np.ndarray      # bool

    def __len__(self) -> int:
        return int(self.power_w.shape[0])

    def idle_only(self) -> "FleetDataset":
        """Filter to 0% utilization (paper: 335,267 of 336,226 = 99.7%)."""
        m = self.util_pct == 0.0
        return FleetDataset(*(getattr(self, f.name)[m]
                              for f in dataclasses.fields(self)))


# Production-fleet H100 (paper Phase 1): SXM nodes idle hotter than the
# Phase-2 bench unit -- bare 74.7 W, CUDA-active 145.5 W (+70.9 W effect).
PHASE1_H100 = DeviceProfile(
    name="H100-80GB-SXM-prod", memory_tech="HBM3", tdp_w=700.0,
    p_base_w=74.7, p_ctx_w=145.5,
    sm_clock_idle_mhz=345.0, sm_clock_ctx_mhz=1980.0,
    vram_capacity_gb=80.0, max_vram_tested_gb=79.0,
    beta_w_per_gb=0.0, sigma_w=0.17, mem_bw_gbps=3350.0,
)

# the "five workload categories" of section 3.1: parked model footprints
_VRAM_CATEGORIES = (0.003, 5.0, 15.0, 40.0, 79.0)


def simulate_fleet(
    profile: DeviceProfile = PHASE1_H100,
    *,
    n_gpus: int = 14,
    n_total: int = 336_226,
    n_busy: int = 959,                 # non-idle samples filtered out (0.3%)
    intercept_spread_w: float = 6.0,   # node binning/cooling (~23 W range)
    bare_std_w: float = 7.9,           # paper per-state stds (sec 4.1)
    ctx_std_w: float = 11.2,
    n_epochs: int = 24,                # VRAM reallocation epochs per GPU
    seed: int = 7,
) -> FleetDataset:
    """Generate the Phase-1 production telemetry per the paper's description.

    Half the fleet holds a context (CUDA-active at max boost), half is bare
    idle; each GPU's VRAM allocation changes across epochs over the 18
    days, drawn from five workload categories spanning 3 MB .. 79 GB; the
    TRUE VRAM slope is the profile's beta (0).  Per-state total variance =
    per-node intercept spread (binning/cooling) + AR(1) sampling noise,
    matching the reported stds (7.9 W bare / 11.2 W active).
    """
    rng = np.random.default_rng(seed)
    per_gpu = n_total // n_gpus
    counts = np.full(n_gpus, per_gpu)
    counts[: n_total - per_gpu * n_gpus] += 1

    offsets = rng.normal(0.0, intercept_spread_w, size=n_gpus)
    ctx_flags = np.arange(n_gpus) % 2 == 0         # 7 active / 7 bare

    cols_p, cols_u, cols_v, cols_c, cols_g, cols_ctx = [], [], [], [], [], []
    for g in range(n_gpus):
        n = counts[g]
        total_std = ctx_std_w if ctx_flags[g] else bare_std_w
        sigma = np.sqrt(max(total_std ** 2 - intercept_spread_w ** 2, 1.0))
        rho = np.exp(-1.0 / 8.0)
        eps = rng.standard_normal(n) * sigma * np.sqrt(1 - rho ** 2)
        noise = np.empty(n)
        acc = rng.standard_normal() * sigma
        for i in range(n):
            acc = rho * acc + eps[i]
            noise[i] = acc
        # VRAM epochs: allocation changes as workloads come and go
        epoch_len = max(n // n_epochs, 1)
        vram = np.repeat(
            rng.choice(_VRAM_CATEGORIES, size=n_epochs + 1), epoch_len)[:n]
        base = np.array([profile.idle_power_w(bool(ctx_flags[g]), float(v))
                         for v in vram])
        power = base + offsets[g] + noise
        clock = (profile.sm_clock_ctx_mhz if ctx_flags[g]
                 else profile.sm_clock_idle_mhz)
        cols_p.append(power)
        cols_u.append(np.zeros(n))
        cols_v.append(vram)
        cols_c.append(np.full(n, clock))
        cols_g.append(np.full(n, g))
        cols_ctx.append(np.full(n, ctx_flags[g], dtype=bool))

    ds = FleetDataset(
        power_w=np.concatenate(cols_p),
        util_pct=np.concatenate(cols_u),
        vram_gb=np.concatenate(cols_v),
        sm_clock_mhz=np.concatenate(cols_c),
        gpu_id=np.concatenate(cols_g),
        context_active=np.concatenate(cols_ctx),
    )
    # sprinkle the 959 busy samples (avg util 0.11% over the full set)
    busy_idx = rng.choice(len(ds), size=n_busy, replace=False)
    ds.util_pct[busy_idx] = rng.uniform(1.0, 80.0, size=n_busy)
    ds.power_w[busy_idx] += rng.uniform(20.0, 400.0, size=n_busy)
    return ds
