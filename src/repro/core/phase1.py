"""Phase-1 production telemetry analysis (paper sections 3.1, 4.1).

Reproduces the pipeline: filter to 0%-utilization samples, split the fleet by
SM-clock bimodality into bare-idle vs context-active states, quantify the
context effect (Welch t + Cohen's d), run the pooled VRAM regression across
context-active GPUs, and the per-device slope bound of section 8 ("large
intercept variation with zero slope variation").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import stats
from repro.core.telemetry import FleetDataset


@dataclasses.dataclass(frozen=True)
class Phase1Result:
    n_raw: int
    n_idle: int
    n_eff_low: float
    n_eff_high: float
    bare_mean_w: float
    bare_std_w: float
    ctx_mean_w: float
    ctx_std_w: float
    context_effect_w: float
    cohens_d: float
    p_value: float
    pooled_slope_w_per_gb: float
    pooled_slope_p: float
    pooled_r2: float
    per_gpu_slopes: Dict[int, stats.OLSResult]
    intercept_range_w: float


def split_states(ds: FleetDataset) -> Dict[str, np.ndarray]:
    """Bimodal state split by SM clock (345 MHz bare vs 1980 MHz boost)."""
    thresh = 0.5 * (ds.sm_clock_mhz.min() + ds.sm_clock_mhz.max())
    active = ds.sm_clock_mhz > thresh
    return {"bare": ds.power_w[~active], "ctx": ds.power_w[active],
            "active_mask": active}


def analyze_fleet(ds: FleetDataset, *, tau_samples_low: float = 6.0,
                  tau_samples_high: float = 10.0) -> Phase1Result:
    idle = ds.idle_only()
    states = split_states(idle)
    two = stats.welch_cohens(states["bare"], states["ctx"])

    active = states["active_mask"]
    # pooled regression across context-active samples (slope = 0.013 W/GB,
    # R2 = 0.001 in the paper -- swamped by the ~23 W node-level variation)
    reg = stats.ols(idle.vram_gb[active], idle.power_w[active])

    # per-device slope bound (paper section 8): each GPU parks one VRAM level in
    # production, so a per-device slope needs within-device VRAM variation;
    # with sticky allocations we instead bound the *between-device* slope
    # via GPU-level (vram, mean power) pairs within the active state.
    per_gpu: Dict[int, stats.OLSResult] = {}
    gids = np.unique(idle.gpu_id[active])
    means, vrams = [], []
    for g in gids:
        m = active & (idle.gpu_id == g)
        means.append(float(idle.power_w[m].mean()))
        vrams.append(float(idle.vram_gb[m].mean()))
        if np.unique(idle.vram_gb[m]).size >= 3:
            per_gpu[int(g)] = stats.ols(idle.vram_gb[m], idle.power_w[m])
    device_reg = stats.ols(np.array(vrams), np.array(means)) \
        if len(means) >= 3 else reg

    n_idle = len(idle)
    return Phase1Result(
        n_raw=len(ds),
        n_idle=n_idle,
        n_eff_low=stats.effective_sample_size(n_idle, tau_samples_high),
        n_eff_high=stats.effective_sample_size(n_idle, tau_samples_low),
        bare_mean_w=two.mean_a, bare_std_w=two.std_a,
        ctx_mean_w=two.mean_b, ctx_std_w=two.std_b,
        context_effect_w=two.diff, cohens_d=two.cohens_d, p_value=two.p_value,
        pooled_slope_w_per_gb=reg.slope, pooled_slope_p=reg.p_value,
        pooled_r2=reg.r2,
        per_gpu_slopes=per_gpu,
        intercept_range_w=float(np.ptp(np.array(means))) if means else 0.0,
    )
