"""Phase-2 within-subject dose-response experiment harness (paper section 3.2).

Protocol per paper Table 1 / section 3.2, identical on every architecture:

  1. record bare-idle baseline (no context),
  2. create a persistent context (the DVFS step),
  3. for each VRAM level in an increasing ladder:
       allocate -> stabilize 60 s -> record n x 30 s -> release -> cool 30 s,
  4. fit OLS of phase-mean power on VRAM across context-active phases,
  5. TOST equivalence test against |beta| < 0.1 W/GB.

The harness only talks to the ``PowerReader`` interface, so the same code
drives the simulated oracle here and real SMI telemetry on hardware.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import stats
from repro.core.power_model import DeviceProfile
from repro.core.telemetry import PowerReader, SimulatedPowerReader


@dataclasses.dataclass(frozen=True)
class PhaseRecord:
    vram_gb: float
    context_active: bool
    mean_w: float
    std_w: float
    se_w: float
    n: int
    samples_w: np.ndarray


@dataclasses.dataclass(frozen=True)
class DoseResponse:
    """Full result of one device's dose-response experiment."""
    device: str
    bare_idle_w: float
    ctx_idle_w: float               # mean over CUDA-active phases
    dvfs_step_w: float
    power_range_w: float            # max-min across context-active phases
    regression: stats.OLSResult     # beta across context-active phases
    tost: stats.TOSTResult
    phases: List[PhaseRecord]

    @property
    def context_share_of_tax(self) -> float:
        """Fraction of the parking tax attributable to the context (>99%)."""
        vmax = max(p.vram_gb for p in self.phases)
        vram_component = abs(self.regression.slope) * vmax
        total = self.dvfs_step_w + vram_component
        return self.dvfs_step_w / total if total > 0 else 1.0


def default_vram_ladder(max_gb: float, n_levels: int = 9) -> List[float]:
    """0 .. max in even steps (paper: 0-64 H100 / 0-72 A100 / 0-40 L40S)."""
    return [round(v, 3) for v in np.linspace(0.0, max_gb, n_levels)]


def run_dose_response(
    reader: PowerReader,
    *,
    device_name: str,
    vram_levels_gb: Sequence[float],
    n_per_phase: int = 40,
    interval_s: float = 30.0,
    stabilize_s: float = 60.0,
    cooldown_s: float = 30.0,
    tost_bound_w_per_gb: float = 0.1,
) -> DoseResponse:
    """Execute the paper's Phase-2 protocol against any PowerReader."""
    t = 0.0
    phases: List[PhaseRecord] = []

    def record(context_active: bool, vram_gb: float) -> PhaseRecord:
        nonlocal t
        reader.set_state(context_active=context_active, vram_gb=vram_gb)
        t += stabilize_s
        samples = [reader.sample(t + i * interval_s) for i in range(n_per_phase)]
        t += n_per_phase * interval_s + cooldown_s
        p = np.array([s.power_w for s in samples])
        mean, sd, se = stats.phase_mean_se(p)
        return PhaseRecord(vram_gb=vram_gb, context_active=context_active,
                           mean_w=mean, std_w=sd, se_w=se, n=n_per_phase,
                           samples_w=p)

    # 1. bare idle baseline (no context)
    phases.append(record(context_active=False, vram_gb=0.0))
    # 2-3. context active, increasing VRAM ladder (within-subject)
    for v in vram_levels_gb:
        phases.append(record(context_active=True, vram_gb=float(v)))

    ctx_phases = [p for p in phases if p.context_active]
    x = np.array([p.vram_gb for p in ctx_phases])
    y = np.array([p.mean_w for p in ctx_phases])
    reg = stats.ols(x, y)
    tost = stats.tost_slope(reg, bound=tost_bound_w_per_gb)

    bare = phases[0].mean_w
    ctx_mean = float(y.mean())
    return DoseResponse(
        device=device_name,
        bare_idle_w=bare,
        ctx_idle_w=ctx_mean,
        dvfs_step_w=ctx_mean - bare,
        power_range_w=float(y.max() - y.min()),
        regression=reg,
        tost=tost,
        phases=phases,
    )


def run_simulated_dose_response(
    profile: DeviceProfile,
    *,
    seed: int = 0,
    thermal_drift_w_per_hr: float = 0.0,
    n_levels: int = 9,
    n_per_phase: int = 40,
) -> DoseResponse:
    """Phase-2 experiment against the paper-physics oracle for ``profile``."""
    reader = SimulatedPowerReader(
        profile, seed=seed, thermal_drift_w_per_hr=thermal_drift_w_per_hr)
    ladder = default_vram_ladder(profile.max_vram_tested_gb, n_levels=n_levels)
    return run_dose_response(reader, device_name=profile.name,
                             vram_levels_gb=ladder, n_per_phase=n_per_phase)


def table2_row(dr: DoseResponse, profile: DeviceProfile) -> dict:
    """One column of paper Table 2, from a DoseResponse result."""
    return {
        "device": dr.device,
        "memory": profile.memory_tech,
        "bare_idle_w": round(dr.bare_idle_w, 1),
        "ctx_power_w": round(dr.ctx_idle_w, 1),
        "context_overhead_w": round(dr.dvfs_step_w, 1),
        "context_pct_tdp": round(100.0 * dr.dvfs_step_w / profile.tdp_w, 1),
        "max_vram_gb": max(p.vram_gb for p in dr.phases),
        "power_range_w": round(dr.power_range_w, 2),
        "beta_w_per_gb": round(dr.regression.slope, 4),
        "beta_ci": (round(dr.regression.ci_low, 4),
                    round(dr.regression.ci_high, 4)),
        "p_beta": dr.regression.p_value,
        "p_tost": dr.tost.p_tost,
        "context_share_pct": round(100.0 * dr.context_share_of_tax, 1),
    }
