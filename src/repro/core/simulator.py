"""24-hour keep-warm/evict energy simulation (paper section 7, Table 6).

Event-driven walk over an arrival trace for ONE model on ONE device.
Power accounting follows the paper's Table 6 convention exactly:

  * warm idle   : P_ctx            (context-active idle)
  * evicted     : P_base           (bare idle -- the chip does not power off)
  * loading     : P_load           (loader-specific burst)
  * serving     : P_ctx (+active power only if service_s > 0; the paper's
                  evaluation holds request service energy constant across
                  policies, so Always-On 24 h energy == P_ctx * 24 h)

Always-on therefore integrates to P_ctx * horizon, matching the paper's
2,921 Wh baseline for the H100 (121.7 W x 24 h).

Cold-start latency: a request arriving while evicted waits t_load; a
request arriving mid-load or mid-service waits the residual time.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.coldstart import LoaderSpec
from repro.core.power_model import DeviceProfile
from repro.core.power_states import (PowerState, PowerStateMachine,
                                     state_power_w)
from repro.core.scheduler import Policy


@dataclasses.dataclass
class SimResult:
    policy: str
    horizon_s: float
    n_requests: int
    energy_wh: float
    cold_starts: int
    warm_idle_s: float
    evicted_s: float
    loading_s: float
    added_latency_s_total: float

    @property
    def mean_added_latency_s(self) -> float:
        return (self.added_latency_s_total / self.n_requests
                if self.n_requests else 0.0)

    def savings_vs(self, baseline: "SimResult") -> float:
        return 1.0 - self.energy_wh / baseline.energy_wh


def simulate(
    arrivals_s: Sequence[float],
    policy: Policy,
    profile: DeviceProfile,
    loader: LoaderSpec,
    *,
    horizon_s: float = 24 * 3600.0,
    service_s: float = 0.0,
    service_util: float = 0.6,
    start_warm: bool = True,
) -> SimResult:
    """Run one (trace, policy) cell of the paper's Table 6."""
    arrivals = sorted(float(a) for a in arrivals_s if 0.0 <= a < horizon_s)
    policy.reset()

    energy_j = 0.0
    warm_idle_s = evicted_s = loading_s = 0.0
    latency_s = 0.0
    cold_starts = 1 if start_warm else 0   # initial load (paper counts 1)

    # per-state power from the shared state machine (power_states): the
    # same formula the serving EnergyMeter integrates, so the layers
    # cannot drift apart
    p_ctx = state_power_w(profile, PowerState.CTX_IDLE)
    p_base = state_power_w(profile, PowerState.BARE)
    p_load = state_power_w(profile, PowerState.LOADING, loader)
    t_load = loader.t_load_s
    p_serve = state_power_w(profile, PowerState.ACTIVE,
                            service_util=service_util) \
        if service_s > 0 else p_ctx

    def spend(dt: float, watts: float) -> None:
        nonlocal energy_j
        if dt > 0:
            energy_j += dt * watts

    t = 0.0           # simulation clock: model is warm-idle at `t` if `warm`
    warm = start_warm
    # validated state walk alongside the closed-form integration: every
    # warm/evict/load edge below is a legal machine transition (a
    # miswired edge raises IllegalPowerTransition here, in the
    # REFERENCE dynamics, before any meter could misprice it)
    machine = PowerStateMachine(
        PowerState.CTX_IDLE if start_warm else PowerState.BARE, t)
    n = len(arrivals)
    i = 0
    while i < n:
        a = arrivals[i]
        policy.observe_arrival(a)
        gap = a - t
        if gap > 0:
            # --- idle interval [t, a) under the eviction policy -----------
            if warm:
                timeout = policy.idle_timeout_s(t, next_gap_s=gap)
                stay = min(gap, timeout)
                spend(stay, p_ctx)
                warm_idle_s += stay
                if stay < gap:            # evicted mid-gap
                    warm = False
                    machine.to(PowerState.BARE, t + stay)
                    spend(gap - stay, p_base)
                    evicted_s += gap - stay
            else:
                spend(gap, p_base)
                evicted_s += gap
        # gap <= 0 means the model is still busy from the previous batch;
        # the request queues (latency accounted below via ready time).
        ready = max(t, a)
        if not warm:
            # --- cold start -----------------------------------------------
            cold_starts += 1
            machine.to(PowerState.LOADING, ready)
            load_end = ready + t_load
            spend(t_load, p_load)
            loading_s += t_load
            warm = True
            machine.to(PowerState.CTX_IDLE, load_end)
            ready = load_end
        # serve this request plus anything that arrived before `ready`
        j = i
        while j < n and arrivals[j] <= ready:
            if j > i:
                policy.observe_arrival(arrivals[j])
            latency_s += ready - arrivals[j]
            j += 1
        batch = j - i
        if service_s > 0:
            machine.to(PowerState.ACTIVE, ready)
        spend(batch * service_s, p_serve)
        t = ready + batch * service_s
        if service_s > 0:
            machine.to(PowerState.CTX_IDLE, t)
        i = j

    # --- trailing interval [t, horizon) ----------------------------------
    gap = horizon_s - t
    if gap > 0:
        if warm:
            timeout = policy.idle_timeout_s(t, next_gap_s=gap)
            stay = min(gap, timeout)
            spend(stay, p_ctx)
            warm_idle_s += stay
            if stay < gap:
                machine.to(PowerState.BARE, t + stay)
                spend(gap - stay, p_base)
                evicted_s += gap - stay
        else:
            spend(gap, p_base)
            evicted_s += gap

    return SimResult(
        policy=policy.name,
        horizon_s=horizon_s,
        n_requests=n,
        energy_wh=energy_j / 3600.0,
        cold_starts=cold_starts,
        warm_idle_s=warm_idle_s,
        evicted_s=evicted_s,
        loading_s=loading_s,
        added_latency_s_total=latency_s,
    )


def compare_policies(
    arrivals_s: Sequence[float],
    policies: Sequence[Policy],
    profile: DeviceProfile,
    loader: LoaderSpec,
    **kw,
) -> List[SimResult]:
    """Table-6 style comparison; first policy is treated as the baseline."""
    return [simulate(arrivals_s, p, profile, loader, **kw) for p in policies]
