"""Statistics for the dose-response analysis (paper sections 3.3, 4.1, 4.2).

Implements exactly the tests the paper reports:
  * OLS slope with exact-t confidence intervals and two-sided p  (Table 2 beta)
  * Schuirmann TOST equivalence test against |beta| < bound     (Table 2 p_TOST)
  * Welch two-sample t and Cohen's d                            (Phase 1, d=7.3)
  * autocorrelation-corrected effective sample size             (Eq. 6)

scipy is available in this container; we use its t/norm CDFs and keep the
estimators themselves explicit so they are auditable against the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np
from scipy import stats as sps


@dataclasses.dataclass(frozen=True)
class OLSResult:
    slope: float
    intercept: float
    stderr: float                # SE of slope
    ci_low: float                # 95% CI of slope
    ci_high: float
    p_value: float               # two-sided, H0: slope = 0
    r2: float
    n: int
    dof: int

    def summary(self) -> str:
        return (f"beta={self.slope:+.4f} [{self.ci_low:+.4f},{self.ci_high:+.4f}] "
                f"p={self.p_value:.3g} R2={self.r2:.3f} n={self.n}")


def ols(x: np.ndarray, y: np.ndarray, *, dof_override: Optional[int] = None
        ) -> OLSResult:
    """Simple linear regression y = a + b x with exact-t inference.

    ``dof_override`` lets callers substitute the autocorrelation-corrected
    effective sample size (Eq. 6) for inference on serially-correlated
    telemetry (paper section 3.1) without changing the point estimate.
    """
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError("x and y must be 1-D arrays of equal length")
    n = x.size
    if n < 3:
        raise ValueError("need >= 3 points for OLS inference")
    xbar, ybar = x.mean(), y.mean()
    sxx = float(((x - xbar) ** 2).sum())
    if sxx == 0.0:
        raise ValueError("x has zero variance")
    sxy = float(((x - xbar) * (y - ybar)).sum())
    slope = sxy / sxx
    intercept = ybar - slope * xbar
    resid = y - (intercept + slope * x)
    sse = float((resid ** 2).sum())
    sst = float(((y - ybar) ** 2).sum())
    dof = (dof_override if dof_override is not None else n) - 2
    dof = max(dof, 1)
    s2 = sse / dof
    se = math.sqrt(s2 / sxx)
    tcrit = float(sps.t.ppf(0.975, dof))
    tstat = slope / se if se > 0 else math.inf
    p = float(2.0 * sps.t.sf(abs(tstat), dof))
    r2 = 1.0 - (sse / sst if sst > 0 else 0.0)
    return OLSResult(slope=slope, intercept=intercept, stderr=se,
                     ci_low=slope - tcrit * se, ci_high=slope + tcrit * se,
                     p_value=p, r2=r2, n=n, dof=dof)


@dataclasses.dataclass(frozen=True)
class TOSTResult:
    """Schuirmann two one-sided tests for equivalence |slope| < bound."""
    bound: float
    p_lower: float     # H0: slope <= -bound  vs  H1: slope > -bound
    p_upper: float     # H0: slope >= +bound  vs  H1: slope < +bound
    p_tost: float      # max of the two (the TOST decision p)
    equivalent: bool   # p_tost < alpha


def tost_slope(res: OLSResult, *, bound: float = 0.1, alpha: float = 0.05
               ) -> TOSTResult:
    """Equivalence test on a regression slope (paper Table 2, D=0.1 W/GB).

    Rejecting both one-sided nulls establishes |beta| < bound: "bounded below
    practical relevance" rather than merely failing to detect an effect.
    """
    if bound <= 0:
        raise ValueError("equivalence bound must be positive")
    t_lo = (res.slope + bound) / res.stderr
    t_hi = (res.slope - bound) / res.stderr
    p_lower = float(sps.t.sf(t_lo, res.dof))    # want slope > -bound
    p_upper = float(sps.t.cdf(t_hi, res.dof))   # want slope < +bound
    p = max(p_lower, p_upper)
    return TOSTResult(bound=bound, p_lower=p_lower, p_upper=p_upper,
                      p_tost=p, equivalent=bool(p < alpha))


@dataclasses.dataclass(frozen=True)
class TwoSampleResult:
    mean_a: float
    mean_b: float
    std_a: float
    std_b: float
    diff: float
    cohens_d: float
    t_stat: float
    p_value: float
    n_a: int
    n_b: int


def welch_cohens(a: np.ndarray, b: np.ndarray) -> TwoSampleResult:
    """Welch t-test + pooled-SD Cohen's d (paper 4.1: d = 7.3, p < 1e-300)."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    ma, mb = a.mean(), b.mean()
    sa, sb = a.std(ddof=1), b.std(ddof=1)
    na, nb = a.size, b.size
    se = math.sqrt(sa ** 2 / na + sb ** 2 / nb)
    t = (mb - ma) / se if se > 0 else math.inf
    # Welch-Satterthwaite dof
    num = (sa ** 2 / na + sb ** 2 / nb) ** 2
    den = (sa ** 2 / na) ** 2 / (na - 1) + (sb ** 2 / nb) ** 2 / (nb - 1)
    dof = num / den if den > 0 else na + nb - 2
    p = float(2.0 * sps.t.sf(abs(t), dof))
    pooled = math.sqrt(((na - 1) * sa ** 2 + (nb - 1) * sb ** 2) / (na + nb - 2))
    d = (mb - ma) / pooled if pooled > 0 else math.inf
    return TwoSampleResult(mean_a=float(ma), mean_b=float(mb), std_a=float(sa),
                           std_b=float(sb), diff=float(mb - ma),
                           cohens_d=float(d), t_stat=float(t), p_value=p,
                           n_a=na, n_b=nb)


def effective_sample_size(n_raw: int, tau_samples: float) -> float:
    """Paper Eq. 6: N_eff ~ N_raw / (2 tau + 1) for AR-correlated telemetry."""
    if tau_samples < 0:
        raise ValueError("tau must be >= 0")
    return n_raw / (2.0 * tau_samples + 1.0)


def autocorr_time(x: np.ndarray, *, max_lag: int = 200) -> float:
    """Integrated autocorrelation time (in samples) via initial-positive-sum.

    Used to estimate tau from raw telemetry rather than assuming it; the
    paper quotes tau ~ 6-10 samples for 3-5 min thermal correlation at 30 s.
    """
    x = np.asarray(x, dtype=np.float64)
    x = x - x.mean()
    n = x.size
    if n < 4:
        return 0.0
    var = float(np.dot(x, x)) / n
    if var == 0:
        return 0.0
    tau = 0.0
    for lag in range(1, min(max_lag, n - 1)):
        c = float(np.dot(x[:-lag], x[lag:])) / (n - lag) / var
        if c <= 0.05:
            break
        tau += c
    return tau


def phase_mean_se(samples: np.ndarray) -> Tuple[float, float, float]:
    """(mean, within-phase std, SE of mean) for one recording phase (Eq. 7)."""
    samples = np.asarray(samples, dtype=np.float64)
    m = float(samples.mean())
    sd = float(samples.std(ddof=1)) if samples.size > 1 else 0.0
    se = sd / math.sqrt(samples.size) if samples.size > 0 else 0.0
    return m, sd, se
