"""The paper's contribution: parking-tax power model, measurement pipeline,
breakeven model, and eviction scheduling (see DESIGN.md sections 1-2)."""
from repro.core.power_model import (A100, H100, L40S, PROFILES, TPU_V5E,
                                    DeviceProfile, get_profile)
from repro.core.power_states import (IllegalPowerTransition,
                                     LEGAL_TRANSITIONS, PowerState,
                                     PowerStateMachine, TransitionModel,
                                     can_transition, gate_breakeven_s,
                                     state_power_w, wake_penalty_j)
from repro.core.breakeven import (breakeven_seconds, critical_rate_per_hr,
                                  table4)
from repro.core.coldstart import (LoaderSpec, TABLE4_LOADERS,
                                  QWEN25_7B_MEASURED, PYTORCH_70B,
                                  SERVERLESSLLM_70B, RUNAI_STREAMER_8B,
                                  loader_from_checkpoint)
from repro.core.scheduler import (AdaptiveBreakeven, AlwaysOn, Breakeven,
                                  Clairvoyant, ExactBreakeven, FixedTTL,
                                  Policy)
from repro.core.simulator import SimResult, compare_policies, simulate

__all__ = [
    "A100", "H100", "L40S", "TPU_V5E", "PROFILES", "DeviceProfile",
    "get_profile",
    "PowerState", "PowerStateMachine", "TransitionModel",
    "IllegalPowerTransition", "LEGAL_TRANSITIONS", "can_transition",
    "state_power_w", "gate_breakeven_s", "wake_penalty_j",
    "breakeven_seconds", "critical_rate_per_hr", "table4",
    "LoaderSpec", "TABLE4_LOADERS", "QWEN25_7B_MEASURED", "PYTORCH_70B",
    "SERVERLESSLLM_70B", "RUNAI_STREAMER_8B", "loader_from_checkpoint",
    "Policy", "AlwaysOn", "FixedTTL", "Breakeven", "ExactBreakeven",
    "AdaptiveBreakeven", "Clairvoyant", "SimResult", "simulate",
    "compare_policies",
]
