"""Cold-start energy breakeven model (paper section 5, Eqs. 12-13; Table 4).

    T*      = P_load * t_load / P_park          (Eq. 12)
    lambda* = P_park / (P_load * t_load)        (Eq. 13; keep warm iff
                                                 Poisson rate > lambda*)

``P_park`` is the architecture's DVFS step (49.9 W H100 / 26.3 W A100 /
66.4 W L40S).  The paper uses the FULL loading power in Eq. 12; the
energy-exact accounting would charge only the loading power *above bare
idle* (during a cold start the chip would otherwise sit at P_base).  We
implement both; ``paper_convention=True`` is the faithful default and the
exact variant is reported under beyond-paper results (it shortens T* by
~25% and strictly improves the eviction policy).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.coldstart import LoaderSpec, TABLE4_LOADERS
from repro.core.power_model import DeviceProfile


def breakeven_seconds(
    loader: LoaderSpec,
    profile: DeviceProfile,
    *,
    paper_convention: bool = True,
) -> float:
    """Idle duration beyond which evicting beats keeping warm (Eq. 12)."""
    p_park = profile.dvfs_step_w
    if p_park <= 0:
        return float("inf")
    p_load = loader.p_load_w
    if not paper_convention:
        # energy-exact: only the above-bare-idle part of loading is a cost
        p_load = max(loader.p_load_w - profile.p_base_w, 0.0)
    return p_load * loader.t_load_s / p_park


def critical_rate_per_hr(
    loader: LoaderSpec,
    profile: DeviceProfile,
    *,
    paper_convention: bool = True,
) -> float:
    """lambda* (Eq. 13): keep warm iff requests/hour exceed this."""
    t_star = breakeven_seconds(loader, profile,
                               paper_convention=paper_convention)
    return 3600.0 / t_star if t_star > 0 else float("inf")


@dataclasses.dataclass(frozen=True)
class BreakevenRow:
    loader: str
    p_load_w: float
    t_load_s: float
    t_star_s: float
    t_star_exact_s: float
    lambda_star_per_hr: float


def table4(profile: DeviceProfile,
           loaders: Optional[List[LoaderSpec]] = None) -> List[BreakevenRow]:
    """Paper Table 4 (plus the exact-convention column and lambda*)."""
    rows = []
    for ld in (loaders or TABLE4_LOADERS):
        rows.append(BreakevenRow(
            loader=ld.name, p_load_w=ld.p_load_w, t_load_s=ld.t_load_s,
            t_star_s=breakeven_seconds(ld, profile),
            t_star_exact_s=breakeven_seconds(ld, profile,
                                             paper_convention=False),
            lambda_star_per_hr=critical_rate_per_hr(ld, profile),
        ))
    return rows


def format_t_star(seconds: float) -> str:
    if seconds < 60:
        return f"{seconds:.0f} s"
    return f"{seconds / 60.0:.1f} min"
