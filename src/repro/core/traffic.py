"""Synthetic request traffic (paper section 7) + beyond-paper heavy-tail traces.

All generators return a sorted np.ndarray of arrival times in seconds over
[0, horizon_s).  The paper evaluates three patterns on a 24 h horizon:

  * steady Poisson, 5 req/hr
  * bursty: alternating 2 and 60 req/hr
  * diurnal: sinusoidal with 30 req/hr peak

We add an MMPP (Markov-modulated Poisson) heavy-tail generator, since the
paper's Future Work calls out that synthetic Poisson/diurnal traces miss
the burstiness of production traffic.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

HOUR = 3600.0
DAY = 24 * HOUR


def poisson(rate_per_hr: float, horizon_s: float = DAY, *,
            seed: int = 0) -> np.ndarray:
    """Homogeneous Poisson arrivals."""
    rng = np.random.default_rng(seed)
    rate_per_s = rate_per_hr / HOUR
    if rate_per_s <= 0:
        return np.empty(0)
    # draw expected count + slack, then trim
    n = int(rate_per_s * horizon_s * 1.5 + 50)
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    t = np.cumsum(gaps)
    return t[t < horizon_s]


def inhomogeneous(rate_fn: Callable[[float], float], rate_max_per_hr: float,
                  horizon_s: float = DAY, *, seed: int = 0) -> np.ndarray:
    """Thinning (Lewis-Shedler) for a time-varying rate, rate in req/hr."""
    rng = np.random.default_rng(seed)
    lam_max = rate_max_per_hr / HOUR
    t, out = 0.0, []
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= horizon_s:
            break
        if rng.uniform() < rate_fn(t) / rate_max_per_hr:
            out.append(t)
    return np.asarray(out)


def bursty(low_per_hr: float = 2.0, high_per_hr: float = 60.0,
           low_s: float = 2 * HOUR, high_s: float = HOUR,
           horizon_s: float = DAY, *, seed: int = 0) -> np.ndarray:
    """Alternating low/high Poisson phases (paper: 2 / 60 req/hr).

    The paper does not state the phase duty cycle; a 2 h-low / 1 h-high
    alternation reproduces its Table-6 bursty row (~480-510 requests/day,
    ~48 cold starts, ~23% breakeven savings, ~4.5 s mean added latency) --
    see EXPERIMENTS.md "trace construction" note.
    """
    period = low_s + high_s
    def rate(t: float) -> float:
        return low_per_hr if (t % period) < low_s else high_per_hr
    return inhomogeneous(rate, max(low_per_hr, high_per_hr), horizon_s,
                         seed=seed)


def diurnal(peak_per_hr: float = 30.0, horizon_s: float = DAY, *,
            seed: int = 0) -> np.ndarray:
    """Sinusoidal daily cycle, 0 .. peak (paper: peak 30 req/hr)."""
    def rate(t: float) -> float:
        return 0.5 * peak_per_hr * (1.0 - np.cos(2.0 * np.pi * t / DAY))
    return inhomogeneous(rate, peak_per_hr, horizon_s, seed=seed)


def mmpp(rates_per_hr=(1.0, 40.0, 400.0), mean_dwell_s=(2 * HOUR, 20 * 60, 90),
         horizon_s: float = DAY, *, seed: int = 0) -> np.ndarray:
    """Markov-modulated Poisson: heavy-tailed production-like burstiness.

    Beyond-paper: used to stress-test eviction policies outside the paper's
    three benign patterns (see EXPERIMENTS.md, Beyond-paper section).
    """
    rng = np.random.default_rng(seed)
    k = len(rates_per_hr)
    t, state, out = 0.0, 0, []
    while t < horizon_s:
        dwell = rng.exponential(mean_dwell_s[state])
        seg_end = min(t + dwell, horizon_s)
        lam = rates_per_hr[state] / HOUR
        tt = t
        while lam > 0:
            tt += rng.exponential(1.0 / lam)
            if tt >= seg_end:
                break
            out.append(tt)
        t = seg_end
        state = int(rng.integers(0, k))
    return np.asarray(sorted(out))


PATTERNS = {
    "steady": lambda seed=0: poisson(5.0, seed=seed),
    "bursty": lambda seed=0: bursty(seed=seed),
    "diurnal": lambda seed=0: diurnal(seed=seed),
    "mmpp": lambda seed=0: mmpp(seed=seed),
}
