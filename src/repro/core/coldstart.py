"""Cold-start power/latency profiles (paper sections 4.3, 5, Table 4).

A cold start is bursty, not flat (paper's measured H100 trace for
Qwen2.5-7B, 29.7 s total):

    deserialize (CPU-side) : ~22 s near bare idle (~70.8 W)
    weight transfer burst  : ~3 s peaking at 124.1 W
    settle                 : context-active idle (~121 W)

``LoaderSpec`` captures (P_load, t_load) pairs -- the two numbers the
breakeven model consumes.  Table-4 loaders are shipped verbatim; per-
architecture load times for the serving framework are derived from
checkpoint bytes / storage bandwidth.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.power_model import DeviceProfile

GB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class LoaderSpec:
    """(mean loading power, loading duration) for one loading method."""
    name: str
    p_load_w: float
    t_load_s: float
    measured: bool = False       # True only for the paper's own measurement

    @property
    def load_energy_j(self) -> float:
        return self.p_load_w * self.t_load_s


# Paper Table 4 rows (H100 context).  "Measured in this work" vs estimates
# from published loader benchmarks.
QWEN25_7B_MEASURED = LoaderSpec("Qwen2.5-7B (measured)", 124.0, 30.0, measured=True)
PYTORCH_70B = LoaderSpec("Standard PyTorch (70B)", 300.0, 45.0)
SERVERLESSLLM_70B = LoaderSpec("ServerlessLLM (70B)", 300.0, 8.0)
RUNAI_STREAMER_8B = LoaderSpec("Run:ai Streamer (8B)", 200.0, 5.0)

TABLE4_LOADERS: List[LoaderSpec] = [
    QWEN25_7B_MEASURED, PYTORCH_70B, SERVERLESSLLM_70B, RUNAI_STREAMER_8B,
]


@dataclasses.dataclass(frozen=True)
class ColdStartPhases:
    """Piecewise-constant cold-start power trace (3 phases)."""
    deserialize_s: float
    deserialize_w: float
    transfer_s: float
    transfer_peak_w: float
    settle_w: float

    @property
    def total_s(self) -> float:
        return self.deserialize_s + self.transfer_s

    @property
    def mean_power_w(self) -> float:
        e = (self.deserialize_s * self.deserialize_w
             + self.transfer_s * self.transfer_peak_w)
        return e / self.total_s

    def trace(self, hz: float = 1.0) -> Tuple[np.ndarray, np.ndarray]:
        """1-Hz style trace like the paper's measured H100 profile."""
        n = int(np.ceil(self.total_s * hz))
        t = np.arange(n) / hz
        p = np.where(t < self.deserialize_s, self.deserialize_w,
                     self.transfer_peak_w)
        return t, p


# The paper's measured H100 Qwen2.5-7B profile (section 4.3).
QWEN25_7B_H100_TRACE = ColdStartPhases(
    deserialize_s=22.0, deserialize_w=70.8,
    transfer_s=7.7, transfer_peak_w=124.1, settle_w=121.0,
)


def loader_from_checkpoint(
    name: str,
    checkpoint_bytes: int,
    profile: DeviceProfile,
    *,
    storage_bw_gbps: float = 1.0,      # effective deserialize path, GB/s
    hbm_ingest_gbps: Optional[float] = None,
    deserialize_overhead: float = 1.8,  # CPU-side unpickle/convert factor
) -> LoaderSpec:
    """Derive a per-architecture LoaderSpec from checkpoint size.

    Matches the structure of the measured trace: an I/O/deserialize phase
    at ~bare idle dominated by storage, then a device-ingest burst.
    Calibrated on the paper's measured Qwen2.5-7B H100 profile (14.9 GB ->
    22 s deserialize + ~3 s burst peaking ~124 W = 29.7 s total).
    """
    gbs = checkpoint_bytes / GB
    ingest = hbm_ingest_gbps or max(profile.mem_bw_gbps * 0.0015, 1.0)
    t_deser = gbs / storage_bw_gbps * deserialize_overhead
    t_xfer = gbs / ingest
    t_total = t_deser + t_xfer
    # mean power: deserialize near bare idle, transfer at modest burst
    burst_w = profile.idle_power_w(True) + 0.004 * profile.tdp_w
    p_mean = (t_deser * (profile.p_base_w * 0.99) + t_xfer * burst_w) / t_total
    return LoaderSpec(name=name, p_load_w=float(p_mean), t_load_s=float(t_total))
