"""Keep-warm / evict policies (paper section 7 + beyond-paper extensions).

A policy answers one question after each service completion: *how long may
the model sit warm-idle before we evict it?*  (``math.inf`` = never evict.)

Paper policies:
  * AlwaysOn            -- industry default
  * FixedTTL(ttl)       -- evict after a fixed idle timeout
  * Breakeven           -- evict after T* = P_load * t_load / P_park (Eq. 12)

Beyond-paper policies (DESIGN.md section 2, "beyond paper"):
  * ExactBreakeven      -- energy-exact T* (charges only above-bare loading
                           power); strictly shorter T*, strictly >= savings
  * AdaptiveBreakeven   -- EWMA arrival-rate estimator + hysteresis band
                           around lambda* (Eq. 13).  Fixes the diurnal
                           oscillation the paper reports in section 8.
  * Clairvoyant         -- offline optimal (ski-rental with known gaps);
                           upper-bounds attainable savings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.breakeven import breakeven_seconds, critical_rate_per_hr
from repro.core.coldstart import LoaderSpec
from repro.core.power_model import DeviceProfile


class Policy:
    """Base class: stateful idle-timeout policies."""

    name = "base"
    clairvoyant = False

    def reset(self) -> None:  # called once per simulation
        pass

    def observe_arrival(self, t_s: float) -> None:
        """Called at every request arrival (for rate estimators)."""

    def idle_timeout_s(self, now_s: float, next_gap_s: Optional[float] = None
                       ) -> float:
        """Seconds of idle to tolerate before evicting; inf = keep warm."""
        raise NotImplementedError


class AlwaysOn(Policy):
    name = "always-on"

    def idle_timeout_s(self, now_s, next_gap_s=None) -> float:
        return math.inf


class FixedTTL(Policy):
    def __init__(self, ttl_s: float):
        if ttl_s <= 0:
            raise ValueError("ttl must be positive")
        self.ttl_s = float(ttl_s)
        self.name = f"ttl-{ttl_s / 60:g}min"

    def idle_timeout_s(self, now_s, next_gap_s=None) -> float:
        return self.ttl_s


class Breakeven(Policy):
    """Paper section 7 policy: evict after T* seconds of idle."""

    def __init__(self, loader: LoaderSpec, profile: DeviceProfile, *,
                 paper_convention: bool = True):
        self.t_star_s = breakeven_seconds(loader, profile,
                                          paper_convention=paper_convention)
        conv = "paper" if paper_convention else "exact"
        self.name = f"breakeven-{conv}(T*={self.t_star_s:.0f}s)"

    def idle_timeout_s(self, now_s, next_gap_s=None) -> float:
        return self.t_star_s


def ExactBreakeven(loader: LoaderSpec, profile: DeviceProfile) -> Breakeven:
    """Beyond-paper: energy-exact convention (see breakeven.py docstring)."""
    return Breakeven(loader, profile, paper_convention=False)


class AdaptiveBreakeven(Policy):
    """Beyond-paper: EWMA rate estimate + hysteresis around lambda*.

    Decision (Eq. 13): keep warm iff lambda_hat > lambda*.  A hysteresis
    band [lambda*(1-h), lambda*(1+h)] with sticky state kills the threshold
    oscillation near the crossover rate that makes plain Breakeven lose to
    TTL on diurnal ramps (paper Table 6 / section 8 discussion).
    When the estimate says evict, we still wait T* (the myopic optimum).
    """

    def __init__(self, loader: LoaderSpec, profile: DeviceProfile, *,
                 halflife_s: float = 900.0, hysteresis: float = 0.3,
                 keep_cap_tstars: float = 4.0, evict_frac_tstars: float = 0.0,
                 paper_convention: bool = True):
        self.t_star_s = breakeven_seconds(loader, profile,
                                          paper_convention=paper_convention)
        self.lambda_star_hr = critical_rate_per_hr(
            loader, profile, paper_convention=paper_convention)
        self.halflife_s = halflife_s
        self.h = hysteresis
        self.keep_cap = keep_cap_tstars
        self.evict_frac = evict_frac_tstars
        self.name = f"adaptive-breakeven(h={hysteresis:g})"
        self.reset()

    def reset(self) -> None:
        self._rate_hr: Optional[float] = None
        self._last_arrival: Optional[float] = None
        self._keep_warm = True          # start optimistic (model just loaded)

    def observe_arrival(self, t_s: float) -> None:
        if self._last_arrival is not None:
            gap = max(t_s - self._last_arrival, 1e-9)
            inst_rate_hr = 3600.0 / gap
            if self._rate_hr is None:
                self._rate_hr = inst_rate_hr
            else:
                # per-event EWMA with time-aware decay
                alpha = 1.0 - 0.5 ** (gap / self.halflife_s)
                self._rate_hr += alpha * (inst_rate_hr - self._rate_hr)
        self._last_arrival = t_s

    def idle_timeout_s(self, now_s, next_gap_s=None) -> float:
        confident = None
        if self._rate_hr is not None:
            if self._rate_hr > self.lambda_star_hr * (1.0 + self.h):
                self._keep_warm = True
                confident = True
            elif self._rate_hr < self.lambda_star_hr * (1.0 - self.h):
                self._keep_warm = False
                confident = True
            # inside the band: sticky previous decision (hysteresis)
        if self._keep_warm:
            # trust the estimator but cap exposure at keep_cap * T* in case
            # the burst has ended (the rate estimate is stale while idle)
            return self.keep_cap * self.t_star_s
        if confident:
            # Eq. 13: for memoryless arrivals below lambda* the optimal
            # action is to evict immediately (binary policy).
            return self.evict_frac * self.t_star_s
        return self.t_star_s


class Clairvoyant(Policy):
    """Offline optimal: sees the actual next gap (ski-rental lower bound).

    Per idle gap g the optimal action is: stay warm iff
    P_park * g  <  (P_load - P_base) * t_load, i.e. iff g < T*_exact.
    Evicting is instantaneous here, so this bounds ANY online policy.
    """

    clairvoyant = True

    def __init__(self, loader: LoaderSpec, profile: DeviceProfile):
        self.t_star_s = breakeven_seconds(loader, profile,
                                          paper_convention=False)
        self.name = "clairvoyant-optimal"

    def idle_timeout_s(self, now_s, next_gap_s=None) -> float:
        if next_gap_s is None:
            raise ValueError("Clairvoyant policy needs next_gap_s")
        return math.inf if next_gap_s < self.t_star_s else 0.0
