"""Device power-state machine: the single authority on what a device's
power states ARE, which transitions between them are legal, and what
each state costs.

Before this module the power semantics were smeared across four layers
(stringly-typed meter states, idle/active formulas in ``power_model``,
override composition in ``Cluster.sync_power``, ad-hoc handling in
``fleetsim``).  Every consumer now drives the same machine:

  * ``PowerState`` -- the typed states.  The str-enum VALUES are the
    historical wire names (``"parked"`` for ``CTX_IDLE``), so meter
    reports, bench rows, and pinned tests keep their keys.
  * ``LEGAL_TRANSITIONS`` -- the transition table.  Illegal transitions
    (serving on a sleeping device, waking straight into a load) RAISE
    ``IllegalPowerTransition`` instead of silently mispricing energy.
  * ``PowerStateMachine`` -- a tiny validated state holder (current
    state + when it was entered); ``EnergyMeter`` owns one per device
    and the reference simulator drives one for validation.
  * ``TransitionModel`` -- per-SKU wake latency / wake energy.
    Context-create is the paper's DVFS step (a standing power change,
    not a lump); sleep/wake are the new ``DeviceProfile`` fields
    (engineering estimates -- the paper never powers a device down).
  * ``state_power_w`` -- the per-state power formula (Eq. 1 extended
    below bare idle), shared by the meter and ``core/simulator.py``.
  * ``gate_breakeven_s`` -- the device-level ski rental: sleeping is
    worth it iff the expected bare-idle gap exceeds the wake-energy
    breakeven (the Eq.-12 argument of ``core/breakeven.py`` one level
    down the power ladder: reload->wake, DVFS step->bare-minus-sleep).

States, low to high power::

    OFF -- SLEEP -- BARE -- CTX_IDLE ("parked") -- LOADING -- ACTIVE

Overlap (a load streaming while other models decode) is NOT a seventh
state: it meters through the composed-override channel -- the meter
enters a base state with an explicit composed wattage
(``transition(state, power_override_w=...)``), which is how
``Cluster.sync_power`` prices concurrent phases additively.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, FrozenSet, Optional, Union

from repro.core.power_model import DeviceProfile


class PowerState(str, enum.Enum):
    """Typed device power states.  Values are the historical meter/report
    names (``CTX_IDLE`` reports as ``"parked"``), so energy buckets and
    pinned bench keys are unchanged by the typed refactor."""

    OFF = "off"            # machine powered down (0 W; not used by the sim)
    SLEEP = "sleep"        # gated: below bare idle, must wake before use
    BARE = "bare"          # bare idle, no runtime context (P_base)
    CTX_IDLE = "parked"    # live context, 0% util -- pays the DVFS step
    LOADING = "loading"    # weight ingest burst (loader-specific watts)
    ACTIVE = "active"      # decode slots busy

    @classmethod
    def coerce(cls, state: Union["PowerState", str]) -> "PowerState":
        """Accept a ``PowerState`` or a legacy string state name."""
        if isinstance(state, cls):
            return state
        try:
            return cls(state)
        except ValueError:
            raise ValueError(
                f"unknown power state {state!r}; have "
                f"{sorted(s.value for s in cls)}") from None


#: Legal state changes (self-loops are always legal: re-entering the
#: current state is how the meter flushes an interval or swaps the
#: composed override).  SLEEP and OFF are deliberately strict: a gated
#: device can only come back through BARE -- it cannot grow a context,
#: start a load, or serve without an explicit wake, so a scheduler bug
#: that routes work to a sleeping device raises instead of metering
#: wrong watts.
LEGAL_TRANSITIONS: Dict[PowerState, FrozenSet[PowerState]] = {
    PowerState.OFF: frozenset({PowerState.BARE}),
    # SLEEP's only exit is the metered wake ramp into BARE -- even a
    # full power-off must wake first, so no sleep exit escapes metering
    PowerState.SLEEP: frozenset({PowerState.BARE}),
    PowerState.BARE: frozenset({
        PowerState.OFF, PowerState.SLEEP, PowerState.CTX_IDLE,
        PowerState.LOADING, PowerState.ACTIVE}),
    PowerState.CTX_IDLE: frozenset({
        PowerState.BARE, PowerState.LOADING, PowerState.ACTIVE}),
    # BARE from LOADING/ACTIVE: device failure drops mid-phase
    PowerState.LOADING: frozenset({
        PowerState.BARE, PowerState.CTX_IDLE, PowerState.ACTIVE}),
    PowerState.ACTIVE: frozenset({
        PowerState.BARE, PowerState.CTX_IDLE, PowerState.LOADING}),
}


class IllegalPowerTransition(ValueError):
    """A state change outside ``LEGAL_TRANSITIONS`` was requested."""


def can_transition(src: PowerState, dst: PowerState) -> bool:
    """Whether ``src -> dst`` is legal (self-loops always are)."""
    return dst is src or dst in LEGAL_TRANSITIONS[src]


class PowerStateMachine:
    """Validated holder of one device's power state.

    Tracks the CURRENT state and when it was entered (self-loops do not
    reset the entry time -- re-settling into bare keeps the bare-idle
    clock running, which is what the gating ski rental measures).
    """

    def __init__(self, initial: PowerState = PowerState.BARE,
                 now_s: float = 0.0):
        self.state = PowerState.coerce(initial)
        self.entered_at_s = now_s

    def to(self, dst: Union[PowerState, str], now_s: float) -> bool:
        """Move to ``dst`` at ``now_s``; returns whether the state
        actually CHANGED.  Raises ``IllegalPowerTransition`` on a move
        outside the table (state unchanged on raise)."""
        dst = PowerState.coerce(dst)
        if dst is self.state:
            return False
        if dst not in LEGAL_TRANSITIONS[self.state]:
            raise IllegalPowerTransition(
                f"illegal power transition {self.state.value!r} -> "
                f"{dst.value!r}")
        self.state = dst
        self.entered_at_s = now_s
        return True

    def time_in_state_s(self, now_s: float) -> float:
        return max(now_s - self.entered_at_s, 0.0)


def state_power_w(profile: DeviceProfile, state: Union[PowerState, str],
                  loader=None, *, service_util: float = 0.6) -> float:
    """Watts a device draws in ``state`` -- paper Eq. 1 extended below
    bare idle, the one formula the meter AND the reference simulator
    integrate.

    ``loader`` (a ``LoaderSpec``) prices LOADING per loading method;
    without one the profile's own per-SKU ``p_load_w`` is used (the
    field that replaced the old ``p_base_w + 30.0`` magic)."""
    state = PowerState.coerce(state)
    if state is PowerState.OFF:
        return 0.0
    if state is PowerState.SLEEP:
        return profile.p_sleep_w
    if state is PowerState.BARE:
        return profile.p_base_w
    if state is PowerState.CTX_IDLE:
        return profile.idle_power_w(context_active=True)
    if state is PowerState.LOADING:
        return profile.load_power_w(loader)
    return profile.active_power_w(service_util)


@dataclasses.dataclass(frozen=True)
class TransitionModel:
    """Per-SKU cost of the gated transitions.

    ``wake_s`` / ``wake_energy_j``: the SLEEP -> BARE ramp (driver
    re-init + clock bring-up); the wake window draws
    ``wake_energy_j / wake_s`` watts for ``wake_s`` seconds.
    ``p_sleep_w``: the gated floor while asleep.
    Context-create (BARE -> CTX_IDLE) is NOT a lump here: it is the
    paper's standing DVFS step, already carried by ``p_ctx_w``.
    """

    p_sleep_w: float
    wake_s: float
    wake_energy_j: float

    @classmethod
    def for_profile(cls, profile: DeviceProfile) -> "TransitionModel":
        return cls(p_sleep_w=profile.p_sleep_w,
                   wake_s=profile.wake_latency_s,
                   wake_energy_j=profile.wake_energy_j)

    @property
    def wake_power_w(self) -> float:
        """Mean power of the wake ramp (what the meter integrates)."""
        if self.wake_s <= 0.0:
            return 0.0
        return self.wake_energy_j / self.wake_s

    def wake_extra_j(self, p_base_w: float) -> float:
        """Extra joules one wake cycle costs over a device that had
        stayed bare through the same window."""
        return max(self.wake_energy_j - p_base_w * self.wake_s, 0.0)


def gate_breakeven_s(profile: DeviceProfile) -> float:
    """Device-level ski rental T*_gate: the bare-idle gap beyond which
    sleeping beats staying bare.

        stay bare over gap g:  P_base * g
        sleep + wake on demand: P_sleep * g + (E_wake - P_base * t_wake)

        T*_gate = (E_wake - P_base * t_wake) / (P_base - P_sleep)

    -- exactly Eq. 12 one power level down: the reload becomes the wake
    ramp, the DVFS step becomes the bare-minus-sleep delta.  Infinite
    when sleeping saves nothing (P_sleep >= P_base)."""
    tm = TransitionModel.for_profile(profile)
    save_w = profile.p_base_w - tm.p_sleep_w
    if save_w <= 0.0:
        return math.inf
    return tm.wake_extra_j(profile.p_base_w) / save_w


def wake_penalty_j(profile: DeviceProfile, hold_s: float = 0.0) -> float:
    """Marginal joules of waking a GATED device for a cold placement,
    versus leaving it asleep: the wake ramp's above-sleep energy plus
    the bare-minus-sleep delta held for ``hold_s`` (how long the device
    is expected to stay awake).  Routers and the autoscaler add this to
    a sleeping candidate's cold-placement score -- a gated device is
    cheap watts but slow (and not free) first-token."""
    tm = TransitionModel.for_profile(profile)
    ramp = max(tm.wake_energy_j - tm.p_sleep_w * tm.wake_s, 0.0)
    return ramp + (profile.p_base_w - tm.p_sleep_w) * max(hold_s, 0.0)
