"""Piecewise-constant idle power model (paper Eq. 1).

    P_idle(C, V) = P_base + dP_DVFS * 1[C=1] + beta * V

The paper's central empirical finding is that ``beta ~ 0`` (|beta| < 0.02 W/GB,
TOST-bounded below 0.1 W/GB) on every architecture tested, while the
context/runtime-residency step ``dP_DVFS`` is +26-66 W.  The model therefore
degenerates to a step function of context presence.

``DeviceProfile`` carries every hardware constant the rest of the framework
consumes (breakeven times, eviction thresholds, simulator energy accounting,
industry impact).  The three GPU profiles are the paper's Table 2 columns and
act as ground truth for reproducing the paper; the TPU profile is a documented
estimate (``estimated=True``) for the TPU-native serving framework -- see
DESIGN.md section 3 (hardware adaptation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

GB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Static power/clock characterisation of one accelerator model.

    All wattages are chip-level board power as a telemetry counter would
    report them (nvidia-smi / TPU runtime metrics).
    """

    name: str
    memory_tech: str                 # "HBM3" | "HBM2e" | "GDDR6" | ...
    tdp_w: float
    p_base_w: float                  # bare idle, no runtime context
    p_ctx_w: float                   # idle with a live context (0% util)
    sm_clock_idle_mhz: float
    sm_clock_ctx_mhz: float
    vram_capacity_gb: float
    max_vram_tested_gb: float        # dose-response ladder ceiling (paper Tab.1)
    beta_w_per_gb: float = 0.0       # TRUE marginal VRAM slope (physics: ~0)
    sigma_w: float = 0.1             # within-phase sampling noise (paper 3.3)
    mem_bw_gbps: float = 0.0         # memory bandwidth, for roofline/loading
    estimated: bool = False          # True when not measured by the paper
    # -- load-phase watts (per-SKU fallback when no LoaderSpec applies;
    #    replaces the old hardcoded `p_base_w + 30.0`); None derives it
    p_load_w: Optional[float] = None
    # -- sleep/wake gating (core/power_states.py): the paper never powers
    #    a device down, so these are ENGINEERING ESTIMATES (driver
    #    persistence off / deep-idle rail state; wake = driver re-init +
    #    clock bring-up).  None derives conservative defaults from the
    #    bare-idle power.
    p_sleep_w: Optional[float] = None    # gated floor while asleep
    wake_latency_s: float = 10.0         # SLEEP -> BARE ramp duration
    wake_energy_j: Optional[float] = None  # TOTAL joules of the wake ramp

    def __post_init__(self):
        if self.p_load_w is None:
            object.__setattr__(self, "p_load_w", self.p_base_w + 30.0)
        if self.p_sleep_w is None:
            object.__setattr__(self, "p_sleep_w", 0.2 * self.p_base_w)
        if self.wake_energy_j is None:
            object.__setattr__(self, "wake_energy_j",
                               2.5 * self.p_base_w * self.wake_latency_s)

    @property
    def dvfs_step_w(self) -> float:
        """The parking tax ``dP_DVFS`` = context overhead (paper Table 2)."""
        return self.p_ctx_w - self.p_base_w

    @property
    def ctx_pct_tdp(self) -> float:
        return self.dvfs_step_w / self.tdp_w

    def idle_power_w(self, context_active: bool, vram_gb: float = 0.0) -> float:
        """Paper Eq. 1 (deterministic part)."""
        p = self.p_base_w
        if context_active:
            p += self.dvfs_step_w
        return p + self.beta_w_per_gb * vram_gb

    def active_power_w(self, utilization: float) -> float:
        """Crude active-compute model: linear ramp ctx-idle -> TDP.

        Only used for *relative* accounting in the serving simulator; the
        paper's scheduler study holds request-service energy constant across
        policies (always-on 24h energy == p_ctx * 24h in Table 6).
        """
        utilization = min(max(utilization, 0.0), 1.0)
        return self.p_ctx_w + utilization * (self.tdp_w - self.p_ctx_w)

    def load_power_w(self, loader=None) -> float:
        """Load-phase watts: the loading method's own measured/derived
        power when a ``LoaderSpec`` is given, else this SKU's catalog
        ``p_load_w`` (one resolution rule for the meter and
        ``fleet.catalog.above_base_load_j``)."""
        if loader is not None:
            return loader.p_load_w
        return self.p_load_w

    def with_instance_offset(self, offset_w: float) -> "DeviceProfile":
        """Same silicon, different node: intercepts vary (~23 W in Phase 1,
        e.g. the Table 3 A100 idling at 105 W vs. 80 W in Phase 2); slopes
        do not.  Every idle-anchored level rides the intercept -- P_base,
        P_ctx, the loading fallback, the sleep floor, and the wake ramp
        (offset x t_wake) -- so the DVFS step, the above-base load delta,
        and the gating breakeven T*_gate are all preserved."""
        return dataclasses.replace(
            self,
            p_base_w=self.p_base_w + offset_w,
            p_ctx_w=self.p_ctx_w + offset_w,
            p_load_w=self.p_load_w + offset_w,
            p_sleep_w=self.p_sleep_w + offset_w,
            wake_energy_j=self.wake_energy_j
            + offset_w * self.wake_latency_s,
        )


# ---------------------------------------------------------------------------
# Paper Table 2 ground-truth profiles (measured; these are the reproduction
# targets) + the TPU adaptation profile (estimated; see DESIGN.md section 3).
# Sleep/wake constants are engineering estimates in every profile (the
# paper never gates a device): sleep = persistence-off deep idle, wake =
# driver re-init + clock bring-up, sized so the device-level gating
# breakeven (power_states.gate_breakeven_s) lands around ~30 s.
# ---------------------------------------------------------------------------

H100 = DeviceProfile(
    name="H100-80GB-SXM", memory_tech="HBM3", tdp_w=700.0,
    p_base_w=71.8, p_ctx_w=121.7,
    sm_clock_idle_mhz=345.0, sm_clock_ctx_mhz=1980.0,
    vram_capacity_gb=80.0, max_vram_tested_gb=64.0,
    beta_w_per_gb=0.0, sigma_w=0.17, mem_bw_gbps=3350.0,
    p_load_w=124.1,              # paper's measured Qwen2.5-7B load mean
    p_sleep_w=14.0, wake_latency_s=10.0, wake_energy_j=2500.0,
)

A100 = DeviceProfile(
    name="A100-80GB-PCIe", memory_tech="HBM2e", tdp_w=300.0,
    p_base_w=53.7, p_ctx_w=80.0,
    sm_clock_idle_mhz=210.0, sm_clock_ctx_mhz=1410.0,
    vram_capacity_gb=80.0, max_vram_tested_gb=72.0,
    beta_w_per_gb=0.0, sigma_w=0.08, mem_bw_gbps=2000.0,
    p_load_w=96.0,
    p_sleep_w=11.0, wake_latency_s=8.0, wake_energy_j=1600.0,
)

L40S = DeviceProfile(
    name="L40S-48GB", memory_tech="GDDR6", tdp_w=350.0,
    p_base_w=35.6, p_ctx_w=102.1,
    sm_clock_idle_mhz=210.0, sm_clock_ctx_mhz=2520.0,
    vram_capacity_gb=48.0, max_vram_tested_gb=40.0,
    beta_w_per_gb=0.0, sigma_w=1.2, mem_bw_gbps=864.0,
    p_load_w=118.0,
    p_sleep_w=8.0, wake_latency_s=6.0, wake_energy_j=1000.0,
)

# TPU v5e: the CUDA-context mechanism does not exist on TPU; the analogue is
# PJRT-client/program residency keeping the chip out of deep idle.  Constants
# are engineering estimates for a ~200 W-class chip (819 GB/s HBM, 197 bf16
# TFLOP/s) and are NOT paper measurements -- flagged `estimated`.
TPU_V5E = DeviceProfile(
    name="TPU-v5e", memory_tech="HBM2e", tdp_w=200.0,
    p_base_w=55.0, p_ctx_w=90.0,
    sm_clock_idle_mhz=0.0, sm_clock_ctx_mhz=0.0,
    vram_capacity_gb=16.0, max_vram_tested_gb=16.0,
    beta_w_per_gb=0.0, sigma_w=0.2, mem_bw_gbps=819.0,
    estimated=True,
    p_load_w=100.0,
    p_sleep_w=12.0, wake_latency_s=12.0, wake_energy_j=2000.0,
)

PROFILES: Dict[str, DeviceProfile] = {
    "h100": H100,
    "a100": A100,
    "l40s": L40S,
    "tpu_v5e": TPU_V5E,
}


def get_profile(name: str) -> DeviceProfile:
    key = name.lower().replace("-", "_")
    if key not in PROFILES:
        raise KeyError(f"unknown device profile {name!r}; have {sorted(PROFILES)}")
    return PROFILES[key]
