"""Industry-scale impact model (paper section 6, Eq. 14, Table 5).

    E_park = N * (1 - rho) * P_park_bar * T_year

Sensitivity grid over fleet size, utilization, and the fleet-weighted
parking tax.  Note the paper's "Low" energy scenario pairs the SMALL fleet
with the HIGH utilization (least idle time) and the A100's low tax -- i.e.
each column of Table 5 is the consistent best/typical/worst case, not an
independent per-row sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

T_YEAR_HR = 8760.0
# Single source of truth for the US grid intensity: the paper's "180 kT
# at 462 GWh" pins this value, and fleet/catalog.py DERIVES its
# MIXES["USA"].gwp_kg_per_kwh from it (core cannot import fleet, so the
# dependency points from fleet to here; regression-tested in
# tests/test_carbon.py).
US_GRID_KG_CO2_PER_KWH = 0.39


@dataclasses.dataclass(frozen=True)
class ImpactScenario:
    name: str
    fleet_size: float           # datacenter GPUs
    utilization: float          # rho
    p_park_w: float             # fleet-weighted average parking tax

    @property
    def energy_gwh_per_year(self) -> float:
        watts = self.fleet_size * (1.0 - self.utilization) * self.p_park_w
        return watts * T_YEAR_HR / 1e9  # W*h -> GWh

    @property
    def co2_kt_per_year(self) -> float:
        return self.energy_gwh_per_year * 1e6 * US_GRID_KG_CO2_PER_KWH / 1e6


# Paper Table 5 (Low pairs high utilization + small fleet + A100 tax;
# High pairs low utilization + large fleet + L40S tax).
LOW = ImpactScenario("low", fleet_size=2.0e6, utilization=0.80, p_park_w=26.3)
BASE = ImpactScenario("base", fleet_size=3.76e6, utilization=0.65, p_park_w=40.0)
HIGH = ImpactScenario("high", fleet_size=6.0e6, utilization=0.50, p_park_w=66.4)

TABLE5: List[ImpactScenario] = [LOW, BASE, HIGH]


def sensitivity_grid(
    fleet_sizes=(2.0e6, 3.76e6, 6.0e6),
    utilizations=(0.50, 0.65, 0.80),
    p_parks=(26.3, 40.0, 66.4),
) -> List[ImpactScenario]:
    """Full factorial sweep (27 cells) around the paper's Table 5 anchors."""
    out = []
    for n in fleet_sizes:
        for rho in utilizations:
            for p in p_parks:
                out.append(ImpactScenario(
                    name=f"N={n / 1e6:.2f}M rho={rho:.2f} P={p:.1f}W",
                    fleet_size=n, utilization=rho, p_park_w=p))
    return out
