"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 -- MLA
attention with dense FFN (hf:openbmb/MiniCPM3-4B; hf)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import (ArchConfig, BlockSpec, FFN, MLAConfig,
                                 Mixer, ScanGroup)

_blk = BlockSpec(Mixer.MLA, FFN.DENSE)

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab_size=73448, head_dim=64,
    groups=(ScanGroup("main", 62, (_blk,)),),
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32,
                  v_head_dim=64),
    sub_quadratic=False,
    source="hf:openbmb/MiniCPM3-4B; hf",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="minicpm3-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16,
        groups=(ScanGroup("main", 2, (_blk,)),),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
