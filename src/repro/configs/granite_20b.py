"""granite-20b [dense]: 52L d_model=6144 48H (kv=1, MQA) d_ff=24576
vocab=49152 -- llama-style code model (arXiv:2405.04324; hf)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import ArchConfig, BlockSpec, FFN, Mixer, \
    ScanGroup, dense_lm

CONFIG = dense_lm(
    "granite-20b", n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    family="dense", source="arXiv:2405.04324; hf")


def reduced() -> ArchConfig:
    blk = BlockSpec(Mixer.ATTN, FFN.DENSE)
    return dataclasses.replace(
        CONFIG, name="granite-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=256, head_dim=16,
        groups=(ScanGroup("main", 2, (blk,)),),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
