"""command-r-35b [dense]: 40L d_model=8192 64H (kv=8) d_ff=22528
vocab=256000 -- GQA, no-bias (hf:CohereForAI/c4ai-command-r-v01;
unverified)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import ArchConfig, BlockSpec, FFN, Mixer, \
    ScanGroup, dense_lm

CONFIG = dense_lm(
    "command-r-35b", n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab_size=256000, head_dim=128,
    family="dense", source="hf:CohereForAI/c4ai-command-r-v01; unverified")


def reduced() -> ArchConfig:
    blk = BlockSpec(Mixer.ATTN, FFN.DENSE)
    return dataclasses.replace(
        CONFIG, name="command-r-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16,
        groups=(ScanGroup("main", 2, (blk,)),),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
