"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (kv=1) d_ff=12288
vocab=256000 -- Griffin: RG-LRU recurrent blocks + local attention at a
2:1 recurrent:attention ratio, window 2048 (arXiv:2402.19427; unverified).

38 layers = 12 x (rglru, rglru, local-attn) superlayers + 2 trailing rglru
blocks (separate scan group -- DESIGN.md section 5 scan-group design).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import (ArchConfig, BlockSpec, FFN, Mixer,
                                 RecurrentConfig, ScanGroup)

_WINDOW = 2048
_r = BlockSpec(Mixer.RGLRU, FFN.DENSE)
_a = BlockSpec(Mixer.ATTN, FFN.DENSE, window=_WINDOW)

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab_size=256000, head_dim=256,
    groups=(ScanGroup("main", 12, (_r, _r, _a)),
            ScanGroup("tail", 1, (_r, _r))),
    recurrent=RecurrentConfig(lru_width=4096, conv_width=4),
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2402.19427; unverified",
)


def reduced() -> ArchConfig:
    r = BlockSpec(Mixer.RGLRU, FFN.DENSE)
    a = BlockSpec(Mixer.ATTN, FFN.DENSE, window=8)
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-reduced",
        n_layers=5, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab_size=256, head_dim=32,
        groups=(ScanGroup("main", 1, (r, r, a)),
                ScanGroup("tail", 1, (r, r))),
        recurrent=RecurrentConfig(lru_width=64, conv_width=4),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
