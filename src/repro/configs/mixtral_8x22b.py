"""mixtral-8x22b [moe]: 56L d_model=6144 48H (kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention (arXiv:2401.04088; hf)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import (ArchConfig, BlockSpec, FFN, Mixer,
                                 MoEConfig, ScanGroup)

_WINDOW = 4096
_blk = BlockSpec(Mixer.ATTN, FFN.MOE, window=_WINDOW)

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768, head_dim=128,
    groups=(ScanGroup("main", 56, (_blk,)),),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384,
                  capacity_factor=1.25, group_size=2048),
    sub_quadratic=True,             # SWA bounds the attention span
    source="arXiv:2401.04088; hf",
)


def reduced() -> ArchConfig:
    blk = BlockSpec(Mixer.ATTN, FFN.MOE, window=8)
    return dataclasses.replace(
        CONFIG, name="mixtral-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=256, head_dim=16,
        groups=(ScanGroup("main", 2, (blk,)),),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      capacity_factor=2.0),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
