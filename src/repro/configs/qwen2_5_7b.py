"""qwen2.5-7b: the paper's section 4.3 real-model validation subject
(Qwen2.5-7B fp16, ~14.9 GB).  28L d_model=3584 28H (kv=4) d_ff=18944
vocab=152064 (arXiv:2412.15115).  Used by the serving examples and the
Table 3/4 benchmarks (loading profile, breakeven)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import ArchConfig, BlockSpec, FFN, Mixer, \
    ScanGroup, dense_lm

CONFIG = dense_lm(
    "qwen2-5-7b", n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128, rope_theta=1_000_000.0,
    family="dense", source="arXiv:2412.15115; hf")


def reduced() -> ArchConfig:
    blk = BlockSpec(Mixer.ATTN, FFN.DENSE)
    return dataclasses.replace(
        CONFIG, name="qwen2-5-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16,
        groups=(ScanGroup("main", 2, (blk,)),),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
