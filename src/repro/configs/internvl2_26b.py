"""internvl2-26b [vlm]: 48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553.
InternLM2-20B language backbone; the InternViT vision tower is a STUB --
input_specs() supplies precomputed patch embeddings [B, 256, 6144]
prepended to the token sequence (arXiv:2404.16821; hf)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import ArchConfig, dense_lm, ScanGroup, BlockSpec, \
    FFN, Mixer

CONFIG = dataclasses.replace(
    dense_lm(
        "internvl2-26b", n_layers=48, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=16384, vocab_size=92553, head_dim=128,
        family="vlm", source="arXiv:2404.16821; hf"),
    n_prefix_embeddings=256,
)


def reduced() -> ArchConfig:
    blk = BlockSpec(Mixer.ATTN, FFN.DENSE)
    return dataclasses.replace(
        CONFIG, name="internvl2-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, n_prefix_embeddings=4,
        groups=(ScanGroup("main", 2, (blk,)),),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
