"""whisper-base [audio]: 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
Enc-dec; conv/mel frontend is a STUB -- input_specs() supplies precomputed
frame embeddings [B, 1500, 512] (arXiv:2212.04356; unverified).

Adaptation notes (DESIGN.md section 3): the backbone uses this framework's
uniform RoPE+RMSNorm decoder blocks (original Whisper uses learned absolute
positions + LayerNorm); 6L = decoder depth, with a matching 6L encoder
tower per the whisper-base layout.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import (ArchConfig, BlockSpec, EncoderConfig, FFN,
                                 Mixer, ScanGroup)

_dec = BlockSpec(Mixer.ATTN, FFN.DENSE, cross_attention=True)

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51865,
    groups=(ScanGroup("dec", 6, (_dec,)),),
    encoder=EncoderConfig(n_layers=6, source_len=1500,
                          frontend="audio_stub"),
    sub_quadratic=False,
    max_position=448 * 128,        # shapes drive the cache length
    source="arXiv:2212.04356; unverified",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-base-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256,
        groups=(ScanGroup("dec", 2, (_dec,)),),
        encoder=EncoderConfig(n_layers=2, source_len=8,
                              frontend="audio_stub"),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
