"""Assigned architecture configs (+ the paper's Qwen2.5-7B validation model).

Each module exports CONFIG (the exact assigned full-scale config) and
``reduced()`` (a structurally-identical small config for CPU smoke tests).
``get_config(name)`` / ``ARCHS`` are the registry the launcher and dry-run
consume (``--arch <id>``).
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.models.config import ArchConfig

_MODULES = [
    "whisper_base",
    "deepseek_v2_236b",
    "mixtral_8x22b",
    "xlstm_125m",
    "internvl2_26b",
    "gemma3_1b",
    "granite_20b",
    "command_r_35b",
    "minicpm3_4b",
    "recurrentgemma_9b",
    "qwen2_5_7b",          # the paper's section 4.3 validation model
]

ARCHS: List[str] = [m.replace("_", "-") for m in _MODULES]


def _module(name: str):
    key = name.replace("-", "_").replace(".", "_")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ArchConfig:
    return _module(name).reduced()
