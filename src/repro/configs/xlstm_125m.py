"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 -- alternating
mLSTM + sLSTM blocks, no separate FFN (projections live inside the blocks)
(arXiv:2405.04517; unverified)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import (ArchConfig, BlockSpec, FFN, Mixer,
                                 ScanGroup)

_pattern = (BlockSpec(Mixer.MLSTM, FFN.NONE), BlockSpec(Mixer.SLSTM, FFN.NONE))

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304,
    groups=(ScanGroup("main", 6, _pattern),),
    tie_embeddings=True,
    sub_quadratic=True,             # pure recurrent state, O(1) per token
    source="arXiv:2405.04517; unverified",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="xlstm-reduced",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        vocab_size=256,
        groups=(ScanGroup("main", 2, _pattern),),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
