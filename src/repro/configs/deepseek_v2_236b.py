"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536(expert)
vocab=102400, MLA kv_lora=512, MoE 2 shared + 160 routed top-6
(arXiv:2405.04434; hf)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import (ArchConfig, BlockSpec, FFN, MLAConfig,
                                 Mixer, MoEConfig, ScanGroup)

_blk = BlockSpec(Mixer.MLA, FFN.MOE)

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_ff=1536,
    vocab_size=102400, head_dim=128,
    groups=(ScanGroup("main", 60, (_blk,)),),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                  n_shared_experts=2, d_ff_shared=3072,
                  capacity_factor=1.25),
    sub_quadratic=False,            # MLA compresses KV but attn is global
    source="arXiv:2405.04434; hf",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-v2-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
        vocab_size=256, head_dim=16,
        groups=(ScanGroup("main", 2, (_blk,)),),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=1, d_ff_shared=32,
                      capacity_factor=2.0),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
