"""gemma3-1b [dense]: 26L d_model=1152 4H (kv=1) head_dim=256 d_ff=6912
vocab=262144 -- 5:1 local(512-window):global layer pattern, local RoPE
theta 10k / global 1M, tied embeddings, 128k context
(hf:google/gemma-3-1b-pt; unverified).

Layer heterogeneity is expressed STRUCTURALLY -- scan groups of
(5 local + 1 global) x 4 + a 2-local tail = 26 layers -- so each pattern
position carries a STATIC window and the chunked attention can slice K/V
to the window span (attention.py); see EXPERIMENTS.md section Perf.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import (ArchConfig, BlockSpec, FFN, Mixer,
                                 ScanGroup)

_LOCAL_WINDOW = 512
_l = BlockSpec(Mixer.ATTN, FFN.DENSE, window=_LOCAL_WINDOW,
               rope_theta=10_000.0)
_g = BlockSpec(Mixer.ATTN, FFN.DENSE, window=None, rope_theta=1_000_000.0)

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab_size=262144, head_dim=256,
    groups=(ScanGroup("main", 4, (_l, _l, _l, _l, _l, _g)),
            ScanGroup("tail", 1, (_l, _l))),
    tie_embeddings=True,
    max_position=131_072,
    sub_quadratic=True,      # 22/26 layers local; 4 global layers have kv=1
    source="hf:google/gemma-3-1b-pt; unverified",
)


def reduced() -> ArchConfig:
    l = BlockSpec(Mixer.ATTN, FFN.DENSE, window=8, rope_theta=10_000.0)
    g = BlockSpec(Mixer.ATTN, FFN.DENSE, window=None, rope_theta=1_000_000.0)
    return dataclasses.replace(
        CONFIG, name="gemma3-reduced",
        n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab_size=256, head_dim=32,
        groups=(ScanGroup("main", 1, (l, l, g)),),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
