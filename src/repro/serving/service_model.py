"""Calibrated service-time model: how long one request occupies a slot.

The fleet simulator used to treat ``service_s`` as one global constant,
which makes contention and batching occupancy -- the quantities that set
the effective arrival rate a device sees, and that Chung et al. ("Where
Do the Joules Go?") and Ozcan et al. show dominate inference energy
accounting -- fake.  This module replaces the constant with a model of
per-request prefill + decode time as a function of the model's
architecture numbers, the device's per-SKU throughput (``tflops_bf16``
on the catalog SKU, ``mem_bw_gbps`` on the power profile), and the
decode-batch occupancy at admission:

  prefill_s       = prompt_tokens * flops_per_token / (TFLOPS * MFU)
  decode_step_s   = weight_bytes / mem_bw          (batch-shared stream)
                    + batch * (kv_read + compute)  (per-sequence terms)
  service_s       = overhead + prefill_s + output_tokens * decode_step_s

Batching occupancy enters exactly as in a real continuous-batching
engine: weights stream from HBM once per step for the WHOLE batch, so a
fuller batch slows each step only by the per-sequence terms while
multiplying tokens/step -- per-request latency degrades gently, and
throughput scales until compute-bound.  The event-driven simulator
freezes a request's service time at admission occupancy (a documented
approximation; true continuous batching would re-time in-flight
requests as occupancy changes).

Calibration anchor: a 7B bf16 model (14.9 GB weights) on H100
(3.35 TB/s) gives a 4.5 ms decode step ~ 220 tok/s/slot, matching
published single-request H100 decode rates for that class
(tests/test_fleet.py pins the band).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

GB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class RequestShape:
    """The traffic's token shape (one knob pair, not per-request)."""
    prompt_tokens: int = 128
    output_tokens: int = 64


@dataclasses.dataclass(frozen=True)
class ModelServiceProfile:
    """The three per-model numbers the service-time model consumes."""
    name: str
    weight_bytes: float            # bytes streamed per decode step
    flops_per_token: float         # 2 * N_active (inference forward)
    kv_bytes_per_token: float = 0.0

    @classmethod
    def from_arch(cls, cfg, dtype_bytes: int = 2) -> "ModelServiceProfile":
        """Exact numbers from an ``ArchConfig`` (models/config.py)."""
        n_active = cfg.active_param_count()
        kv = 2 * cfg.total_layers * cfg.n_kv_heads * cfg.head_dim_ \
            * dtype_bytes
        return cls(name=cfg.name,
                   weight_bytes=float(cfg.param_count() * dtype_bytes),
                   flops_per_token=2.0 * n_active,
                   kv_bytes_per_token=float(kv))

    @classmethod
    def from_checkpoint_bytes(cls, name: str, checkpoint_bytes: int,
                              dtype_bytes: int = 2
                              ) -> "ModelServiceProfile":
        """Estimate from checkpoint size alone (bf16: N = bytes / 2).

        KV bytes/token uses the GQA-era ratio kv ~ 3e-6 * weights
        (Qwen2.5-7B: 56 KB/token vs 14.9 GB; Llama-70B: 320 KB vs
        140 GB) -- good to ~2x across 7B-70B, and the KV term is a
        small correction to the weight stream anyway.
        """
        n = checkpoint_bytes / dtype_bytes
        return cls(name=name, weight_bytes=float(checkpoint_bytes),
                   flops_per_token=2.0 * n,
                   kv_bytes_per_token=3e-6 * checkpoint_bytes)


class ServiceTimeModel:
    """How long one request occupies a decode slot on a given device."""

    name = "base"

    def request_service_s(self, spec, device, batch: int) -> float:
        """Service time for one request admitted at `batch` occupancy
        (the request itself included).  ``spec`` is a FleetModelSpec-like
        record; ``device`` a DeviceInstance-like (``.profile``/``.sku``)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantServiceTime(ServiceTimeModel):
    """Occupancy-blind constant (the legacy ``FleetScenario.service_s``;
    0.0 reproduces the paper's service-energy-held-constant convention)."""

    service_s: float = 0.0
    name = "constant"

    def request_service_s(self, spec, device, batch: int) -> float:
        return self.service_s


@dataclasses.dataclass(frozen=True)
class RooflineServiceTime(ServiceTimeModel):
    """Roofline prefill/decode times from per-SKU throughput numbers."""

    shape: RequestShape = RequestShape()
    mfu: float = 0.4               # model-FLOP utilization for compute terms
    overhead_s: float = 0.01       # scheduling/tokenizer/network floor

    name = "roofline"

    def _profile_for(self, spec) -> ModelServiceProfile:
        svc = getattr(spec, "service", None)
        if svc is not None:
            return svc
        ckpt = getattr(spec, "checkpoint_bytes", None)
        if ckpt:
            return ModelServiceProfile.from_checkpoint_bytes(
                getattr(spec, "model_id", "model"), ckpt)
        # loader-only spec: assume a 7B-class bf16 checkpoint
        return ModelServiceProfile.from_checkpoint_bytes(
            getattr(spec, "model_id", "model"), 15 * GB)

    @staticmethod
    def _throughput(device) -> tuple:
        """(bytes/s, flop/s) roofs, validated: a SKU constructed without
        tflops_bf16 (it defaults to 0.0) must fail HERE with a clear
        message, not as a ZeroDivisionError deep in the event loop."""
        bw = device.profile.mem_bw_gbps * 1e9
        tflops = device.sku.tflops_bf16 * 1e12
        if bw <= 0 or tflops <= 0:
            raise ValueError(
                f"SKU {device.sku.key!r} lacks throughput numbers for the "
                f"roofline service model (mem_bw_gbps="
                f"{device.profile.mem_bw_gbps}, tflops_bf16="
                f"{device.sku.tflops_bf16}); set both in fleet/catalog.py")
        return bw, tflops

    def prefill_s(self, msp: ModelServiceProfile, device) -> float:
        _, tflops = self._throughput(device)
        return self.shape.prompt_tokens * msp.flops_per_token \
            / (tflops * self.mfu)

    def decode_step_s(self, msp: ModelServiceProfile, device,
                      batch: int) -> float:
        """One batched decode step: the weight stream is shared by the
        whole batch; each sequence adds its KV read + its compute."""
        bw, tflops = self._throughput(device)
        tflops *= self.mfu
        mean_ctx = self.shape.prompt_tokens + self.shape.output_tokens / 2
        per_seq = (msp.kv_bytes_per_token * mean_ctx / bw
                   + msp.flops_per_token / tflops)
        return msp.weight_bytes / bw + max(batch, 1) * per_seq

    def request_service_s(self, spec, device, batch: int) -> float:
        msp = self._profile_for(spec)
        return (self.overhead_s + self.prefill_s(msp, device)
                + self.shape.output_tokens
                * self.decode_step_s(msp, device, batch))

    def decode_tokens_per_s(self, spec, device, batch: int = 1) -> float:
        """Aggregate decode throughput at a given occupancy (reporting)."""
        msp = self._profile_for(spec)
        return max(batch, 1) / self.decode_step_s(msp, device, batch)
