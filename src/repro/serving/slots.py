"""Slot/occupancy primitives shared by the live serving engine and the
fleet simulator.

Both execution models are the same shape: a fixed number of decode
*slots* per model (continuous batching -- vLLM-style admission into a
static working set), plus, at fleet scale, one serialized *loader
channel* per device (weight ingest is PCIe/storage-bound, so loads
queue; decode does not).  ``SlotPool`` is the occupancy tracker
``ServingEngine`` uses for its KV-cache rows and ``DeviceRuntime``
uses per replica; ``DeviceRuntime`` is the multi-slot per-device state
the fleet event loop drives (it replaces the old single ``busy`` flag,
so loads overlap serving and up to ``max_batch`` requests per model
decode concurrently).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Set, Tuple

#: Loader-channel sentinel: the device is running its SLEEP -> BARE wake
#: ramp (core/power_states.py).  Wake serializes on the same channel as
#: loads -- a gated device must finish waking before any weight ingest
#: starts -- and the sentinel can never collide with a model_id.
WAKE_CHANNEL = "__wake__"


class SlotPool:
    """Fixed-size pool of reusable slot ids (lowest-free-first).

    The acquire/release discipline is the whole continuous-batching
    contract: a released slot is immediately reusable, and the pool
    never grows, so downstream state keyed by slot id (KV-cache rows,
    in-flight decode events) stays statically shaped.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self._live: List[bool] = [False] * n_slots

    def acquire(self) -> Optional[int]:
        """Claim the lowest free slot id, or None when full."""
        for i, live in enumerate(self._live):
            if not live:
                self._live[i] = True
                return i
        return None

    def release(self, slot: int) -> None:
        if not self._live[slot]:
            raise ValueError(f"slot {slot} is not live")
        self._live[slot] = False

    def is_live(self, slot: int) -> bool:
        return self._live[slot]

    @property
    def busy(self) -> int:
        return sum(self._live)

    @property
    def free(self) -> int:
        return self.n_slots - self.busy

    @property
    def full(self) -> bool:
        return self.busy == self.n_slots

    def live_slots(self) -> List[int]:
        return [i for i, live in enumerate(self._live) if live]

    def free_slots(self) -> List[int]:
        return [i for i, live in enumerate(self._live) if not live]

    def utilization(self) -> float:
        return self.busy / self.n_slots


class DeviceRuntime:
    """Concurrent per-device runtime state for the fleet event loop.

    One serialized loader channel (``loading`` + ``load_q``) and one
    ``SlotPool`` of ``max_batch`` decode slots per resident model:
    a device can stream weights for model A while models B and C decode,
    and each model serves up to ``max_batch`` requests concurrently.
    Requests that find their model cold or its pool full park in a
    per-model ``wait_q`` (their pins keep the replica from evicting).
    """

    def __init__(self, max_batch: int = 4):
        if max_batch < 1:
            raise ValueError("need at least one decode slot per model")
        self.max_batch = max_batch
        self.loading: Optional[str] = None      # model_id mid-load
        self.loading_until: float = 0.0         # sim time the load lands
        # ("load", model_id) | ("mig", src_device_id, model_id)
        self.load_q: Deque[Tuple] = deque()
        self.load_queued: Set[str] = set()      # model_ids queued/in-flight
        self._pools: Dict[str, SlotPool] = {}
        self._waiting: Dict[str, Deque[float]] = {}

    # -- per-model views ----------------------------------------------------
    def pool(self, model_id: str) -> SlotPool:
        if model_id not in self._pools:
            self._pools[model_id] = SlotPool(self.max_batch)
        return self._pools[model_id]

    def wait_q(self, model_id: str) -> Deque[float]:
        if model_id not in self._waiting:
            self._waiting[model_id] = deque()
        return self._waiting[model_id]

    # -- aggregates (router / consolidator signals) -------------------------
    def busy_slots(self, model_id: Optional[str] = None) -> int:
        if model_id is not None:
            p = self._pools.get(model_id)
            return p.busy if p else 0
        return sum(p.busy for p in self._pools.values())

    def waiting_count(self, model_id: Optional[str] = None) -> int:
        if model_id is not None:
            q = self._waiting.get(model_id)
            return len(q) if q else 0
        return sum(len(q) for q in self._waiting.values())

    @property
    def busy(self) -> bool:
        """Any in-flight or queued work (the consolidator's skip signal)."""
        return (self.loading is not None or bool(self.load_q)
                or self.busy_slots() > 0 or self.waiting_count() > 0)
