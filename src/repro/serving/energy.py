"""Energy accounting for the serving runtime (the paper's Eq. 1 applied
to a live system).

``EnergyMeter`` integrates device power over power-state intervals.  The
states are the typed ``core.power_states.PowerState`` machine -- sleep
(gated) / bare (no context) / parked (context idle, pays the context
tax) / loading / active -- and every transition is validated against the
machine's legality table, so a scheduler bug that e.g. serves on a
sleeping device raises ``IllegalPowerTransition`` instead of silently
metering the wrong watts.  The paper's central result means the meter
does NOT need to know HOW MUCH memory a parked model uses -- only
whether a runtime context is live (beta ~ 0, section 4.2).

Per-state power comes from ``power_states.state_power_w`` (one formula
shared with ``core/simulator.py``); concurrent phases meter through the
composed-override channel (``transition(state, power_override_w=...)``).

A ``SimClock`` lets the 24 h example and the tests run in simulated time;
production would pass time.monotonic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core.power_model import DeviceProfile
from repro.core.power_states import (IllegalPowerTransition, PowerState,
                                     PowerStateMachine, TransitionModel,
                                     state_power_w)


class SimClock:
    def __init__(self, t0: float = 0.0):
        self._t = t0

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("time cannot go backwards")
        self._t += dt


@dataclasses.dataclass
class EnergyMeter:
    profile: DeviceProfile
    clock: Callable[[], float]

    def __post_init__(self):
        self._machine = PowerStateMachine(PowerState.BARE, self.clock())
        self._since = self.clock()
        self._energy_j: Dict[str, float] = {}
        self._durations_s: Dict[str, float] = {}
        self._power_override: Optional[float] = None
        # sleep/wake bookkeeping (power_states.TransitionModel): wake
        # ramps meter as BARE with the ramp's mean power composed over
        # the override channel, so `wakes` is what turns the metered
        # "bare" bucket back into a gating saving (gated_wh_saved)
        self.wakes = 0
        # metered power timeline: (t0_s, t1_s, watts) per closed interval
        # (constant power within each).  This is what lets carbon be an
        # INTEGRAL over a time-varying grid-intensity trace instead of
        # energy x scalar (fleet/carbon.py) -- same instants, same watts
        # as the energy sums above, so flat-trace carbon is exactly the
        # scalar bookkeeping.
        self.timeline: List[Tuple[float, float, float]] = []

    def _power_w(self, state: PowerState) -> float:
        # an explicit override wins in ANY state: concurrent phases
        # (load overlapping decode, the wake ramp) meter at their
        # composed power
        if self._power_override is not None:
            return self._power_override
        return state_power_w(self.profile, state)

    def transition(self, state: Union[PowerState, str], *,
                   power_override_w: Optional[float] = None) -> None:
        """Close the current interval and enter `state` (validated:
        raises ``IllegalPowerTransition`` on a move outside the state
        machine's table, without mutating the meter)."""
        state = PowerState.coerce(state)
        now = self.clock()
        cur = self._machine.state
        self._machine.to(state, now)         # raises BEFORE any charge
        dt = now - self._since
        p = self._power_w(cur)
        key = cur.value
        self._energy_j[key] = self._energy_j.get(key, 0.0) + dt * p
        self._durations_s[key] = self._durations_s.get(key, 0.0) + dt
        if dt > 0.0:
            # coalesce contiguous equal-power intervals (sync_power often
            # re-settles into the same state): lossless for integration
            # and bounds growth to one entry per actual power CHANGE.
            # NOTE: in a long-lived production meter (time.monotonic
            # clock) this list still grows with every power change --
            # flush it after pricing (timeline.clear()) in that setting.
            if self.timeline and self.timeline[-1][1] == self._since \
                    and self.timeline[-1][2] == p:
                self.timeline[-1] = (self.timeline[-1][0], now, p)
            else:
                self.timeline.append((self._since, now, p))
        self._since = now
        self._power_override = power_override_w

    @property
    def state(self) -> PowerState:
        """Current power state (str-enum: compares equal to the legacy
        string names, e.g. ``meter.state == "parked"``)."""
        return self._machine.state

    @property
    def power_override_w(self) -> Optional[float]:
        """The composed-override wattage currently in force (None when
        the state's own formula prices the interval)."""
        return self._power_override

    def state_since_s(self) -> float:
        """Sim time the CURRENT state was entered (self-loop flushes do
        not reset it -- this is the bare-idle clock the gating ski
        rental measures)."""
        return self._machine.entered_at_s

    # -- sleep/wake gating ---------------------------------------------------
    def gate(self) -> None:
        """BARE -> SLEEP (raises from any other state, and from
        bare-with-a-composed-burst -- e.g. mid-wake: only a fully
        drained, SETTLED device may gate)."""
        if self._power_override is not None:
            raise IllegalPowerTransition(
                "cannot gate: a composed power burst is in force")
        self.transition(PowerState.SLEEP)

    def begin_wake(self) -> float:
        """Start the SLEEP -> BARE wake ramp; returns its duration.

        The ramp meters as BARE with the ramp's mean power
        (``wake_energy_j / wake_latency_s``) composed over the override
        channel, so the metered joules over the window are exactly the
        profile's ``wake_energy_j``."""
        tm = TransitionModel.for_profile(self.profile)
        self.transition(PowerState.BARE, power_override_w=tm.wake_power_w)
        self.wakes += 1
        return tm.wake_s

    def finish_wake(self) -> None:
        """Close the wake ramp: settle at plain bare power."""
        self.transition(PowerState.BARE)

    def gated_wh_saved(self) -> float:
        """Wh saved by gating vs having idled bare through the same
        windows: (P_base - P_sleep) over the slept time, minus each wake
        ramp's extra energy over bare.  Uses flushed durations -- call
        after ``totals()``/``peek_totals()`` semantics apply."""
        prof = self.profile
        tm = TransitionModel.for_profile(prof)
        sleep_s = self._durations_s.get(PowerState.SLEEP.value, 0.0)
        saved_j = (prof.p_base_w - tm.p_sleep_w) * sleep_s \
            - self.wakes * tm.wake_extra_j(prof.p_base_w)
        return saved_j / 3600.0

    # -- reporting -----------------------------------------------------------
    def totals(self) -> Dict[str, float]:
        """Finalize up to 'now' and report energy (Wh) per state + total.

        MUTATES the meter: the open interval is flushed (closed at the
        current clock and appended to ``timeline``); the state and any
        composed override are preserved, so calling ``totals()`` twice
        (or mid-run) is safe and the second call only adds the newly
        elapsed interval.  For a pure read use ``peek_totals()``."""
        self.transition(self._machine.state,
                        power_override_w=self._power_override)
        wh = {k: v / 3600.0 for k, v in self._energy_j.items()}
        wh["total"] = sum(wh.values())
        return wh

    def peek_totals(self) -> Dict[str, float]:
        """Energy (Wh) per state + total as of 'now', WITHOUT mutating
        the meter (the open interval is priced virtually; no flush, no
        timeline append)."""
        dt = self.clock() - self._since
        cur = self._machine.state
        wh = {k: v / 3600.0 for k, v in self._energy_j.items()}
        wh[cur.value] = wh.get(cur.value, 0.0) + dt * self._power_w(cur) / 3600.0
        wh["total"] = sum(v for k, v in wh.items())
        return wh

    def durations(self) -> Dict[str, float]:
        return dict(self._durations_s)

    def parking_tax_wh(self) -> float:
        """Energy attributable to the context DVFS step while parked."""
        parked_s = self._durations_s.get(PowerState.CTX_IDLE.value, 0.0)
        return parked_s * self.profile.dvfs_step_w / 3600.0
