"""Energy accounting for the serving runtime (the paper's Eq. 1 applied
to a live system).

``EnergyMeter`` integrates device power over state intervals:
bare (no model resident) / parked (model resident, idle -- pays the
context tax) / loading / active.  The paper's central result means the
meter does NOT need to know HOW MUCH memory a parked model uses -- only
whether a runtime context is live (beta ~ 0, section 4.2).

A ``SimClock`` lets the 24 h example and the tests run in simulated time;
production would pass time.monotonic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.power_model import DeviceProfile


class SimClock:
    def __init__(self, t0: float = 0.0):
        self._t = t0

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("time cannot go backwards")
        self._t += dt


@dataclasses.dataclass
class EnergyMeter:
    profile: DeviceProfile
    clock: Callable[[], float]

    def __post_init__(self):
        self._state = "bare"
        self._since = self.clock()
        self._energy_j: Dict[str, float] = {}
        self._durations_s: Dict[str, float] = {}
        self._power_override: Optional[float] = None
        # metered power timeline: (t0_s, t1_s, watts) per closed interval
        # (constant power within each).  This is what lets carbon be an
        # INTEGRAL over a time-varying grid-intensity trace instead of
        # energy x scalar (fleet/carbon.py) -- same instants, same watts
        # as the energy sums above, so flat-trace carbon is exactly the
        # scalar bookkeeping.
        self.timeline: List[Tuple[float, float, float]] = []

    def _power_w(self, state: str) -> float:
        # an explicit override wins in ANY state: concurrent phases
        # (load overlapping decode) meter at their composed power
        if self._power_override is not None:
            return self._power_override
        if state == "bare":
            return self.profile.p_base_w
        if state == "parked":
            return self.profile.idle_power_w(context_active=True)
        if state == "loading":
            return self.profile.p_base_w + 30.0
        if state == "active":
            return self.profile.active_power_w(0.6)
        raise ValueError(state)

    def transition(self, state: str, *, power_override_w: Optional[float]
                   = None) -> None:
        """Close the current interval and enter `state`."""
        now = self.clock()
        dt = now - self._since
        p = self._power_w(self._state)
        self._energy_j[self._state] = self._energy_j.get(self._state, 0.0) \
            + dt * p
        self._durations_s[self._state] = \
            self._durations_s.get(self._state, 0.0) + dt
        if dt > 0.0:
            # coalesce contiguous equal-power intervals (sync_power often
            # re-settles into the same state): lossless for integration
            # and bounds growth to one entry per actual power CHANGE.
            # NOTE: in a long-lived production meter (time.monotonic
            # clock) this list still grows with every power change --
            # flush it after pricing (timeline.clear()) in that setting.
            if self.timeline and self.timeline[-1][1] == self._since \
                    and self.timeline[-1][2] == p:
                self.timeline[-1] = (self.timeline[-1][0], now, p)
            else:
                self.timeline.append((self._since, now, p))
        self._state = state
        self._since = now
        self._power_override = power_override_w

    @property
    def state(self) -> str:
        return self._state

    def totals(self) -> Dict[str, float]:
        """Finalize up to 'now' and report energy (Wh) per state + total."""
        self.transition(self._state)         # flush current interval
        wh = {k: v / 3600.0 for k, v in self._energy_j.items()}
        wh["total"] = sum(wh.values())
        return wh

    def durations(self) -> Dict[str, float]:
        return dict(self._durations_s)

    def parking_tax_wh(self) -> float:
        """Energy attributable to the context DVFS step while parked."""
        parked_s = self._durations_s.get("parked", 0.0)
        return parked_s * self.profile.dvfs_step_w / 3600.0
