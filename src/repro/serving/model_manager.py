"""Multi-model lifecycle manager: the paper's breakeven scheduling as a
first-class serving feature.

``ModelManager`` owns a device's energy state (EnergyMeter) and a set of
registered models.  Each model carries a per-arch ``LoaderSpec`` (derived
from its checkpoint bytes -- coldstart.loader_from_checkpoint) and an
eviction ``Policy`` (core/scheduler.py).  On request arrival the manager
cold-starts if needed (charging loading energy + latency), serves, and
arms the policy's idle timeout; ``tick()`` applies due evictions.

Node-failure handling: ``fail()`` simulates a device loss -- resident
models drop, the meter resets to bare, and the next request transparently
reloads (the serving-side analogue of checkpoint/restart; see
tests/test_serving.py).

Fleet hooks (repro.fleet): loads are split-phase (``begin_load`` /
``finish_load``) so a cluster event loop can interleave other devices'
evictions with an in-flight load, and ``unload`` / ``export_model`` /
``prewarm`` give the consolidation pass the migration primitives it
needs.  ``handle_request`` keeps the original blocking behaviour.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional

from repro.core.coldstart import LoaderSpec, loader_from_checkpoint
from repro.core.power_model import DeviceProfile
from repro.core.power_states import PowerState
from repro.core.scheduler import Policy
from repro.serving.energy import EnergyMeter, SimClock

Tree = Any


@dataclasses.dataclass
class ManagedModel:
    model_id: str
    loader: LoaderSpec
    policy: Policy
    load_fn: Optional[Callable[[], Any]] = None   # returns engine/params
    engine: Any = None
    resident: bool = False
    loading: bool = False
    vram_gb: float = 0.0                          # capacity accounting only
    evict_at: float = math.inf
    pins: int = 0          # queued demand holding the model (fleet layer)
    # autoscaler-held replica: exempt from the policy's idle timeout --
    # it stays warm through lulls (paying the parking tax) until the
    # autoscaler's own breakeven scale-in test retires it
    held: bool = False
    cold_starts: int = 0
    requests: int = 0
    added_latency_s: float = 0.0
    # per-request added latency (queue wait + cold start), one entry per
    # served request -- the fleet layer aggregates these into p50/p99
    latency_samples: List[float] = dataclasses.field(default_factory=list)


class ModelManager:
    def __init__(self, profile: DeviceProfile, *,
                 clock: Optional[SimClock] = None):
        self.profile = profile
        self.clock = clock or SimClock()
        self.meter = EnergyMeter(profile, self.clock)
        self.models: Dict[str, ManagedModel] = {}

    # -- registry -----------------------------------------------------------
    def register(self, model_id: str, *, policy: Policy,
                 loader: Optional[LoaderSpec] = None,
                 checkpoint_bytes: Optional[int] = None,
                 load_fn: Optional[Callable[[], Any]] = None,
                 vram_gb: float = 0.0) -> ManagedModel:
        if loader is None:
            if checkpoint_bytes is None:
                raise ValueError("need loader or checkpoint_bytes")
            loader = loader_from_checkpoint(model_id, checkpoint_bytes,
                                            self.profile)
        policy.reset()
        m = ManagedModel(model_id=model_id, loader=loader, policy=policy,
                         load_fn=load_fn, vram_gb=vram_gb)
        self.models[model_id] = m
        return m

    def _any_resident(self) -> bool:
        return any(m.resident for m in self.models.values())

    def resident_ids(self) -> List[str]:
        return [mid for mid, m in self.models.items() if m.resident]

    def vram_used_gb(self) -> float:
        return sum(m.vram_gb for m in self.models.values()
                   if m.resident or m.loading)

    # -- lifecycle ------------------------------------------------------------
    def begin_load(self, model_id: str) -> float:
        """Enter the loading state WITHOUT advancing time; returns t_load.

        The fleet event loop uses the split-phase form so evictions on
        other devices (sharing this SimClock) land mid-load at the right
        instant."""
        m = self.models[model_id]
        m.loading = True
        self.meter.transition(PowerState.LOADING,
                              power_override_w=m.loader.p_load_w)
        return m.loader.t_load_s

    def finish_load(self, model_id: str) -> None:
        m = self.models[model_id]
        m.cold_starts += 1
        if m.load_fn is not None:
            m.engine = m.load_fn()
        m.loading = False
        m.resident = True
        self.meter.transition(PowerState.CTX_IDLE)

    def _load(self, m: ManagedModel) -> None:
        self.begin_load(m.model_id)
        self.clock.advance(m.loader.t_load_s)
        self.finish_load(m.model_id)

    def _evict(self, m: ManagedModel) -> None:
        m.engine = None                      # frees device buffers
        m.resident = False
        m.evict_at = math.inf
        m.held = False
        # only fall to bare from parked: mid-load/mid-service the burst
        # power keeps metering until that phase closes
        if not self._any_resident() and self.meter.state is PowerState.CTX_IDLE:
            self.meter.transition(PowerState.BARE)

    def unload(self, model_id: str) -> bool:
        """Graceful unload hook (fleet migration): evict now, regardless
        of the armed idle timeout.  Returns whether it was resident."""
        m = self.models[model_id]
        if m.loading:
            raise RuntimeError(
                f"cannot unload {model_id!r}: split-phase load in flight "
                f"(finish_load it first)")
        was = m.resident
        if was:
            self._evict(m)
        return was

    def export_model(self, model_id: str) -> ManagedModel:
        """Unload and remove from the registry, returning the record so a
        migration can re-home the model (engine handle, loader, stats)."""
        self.unload(model_id)
        return self.models.pop(model_id)

    def prewarm(self, model_id: str, *, count_cold_start: bool = True) -> None:
        """Make a model resident NOW without charging load energy/time.

        This is the simulator's ``start_warm`` convention (paper Table 6
        counts the initial load as 1 cold start but starts the horizon
        warm); the fleet uses it for warm-everywhere baselines."""
        m = self.models[model_id]
        if m.resident:
            return
        if m.load_fn is not None:
            m.engine = m.load_fn()
        m.resident = True
        if count_cold_start:
            m.cold_starts += 1
        self.meter.transition(PowerState.CTX_IDLE)
        self.arm(model_id)

    def arm(self, model_id: str) -> None:
        """(Re)arm a model's idle-eviction deadline from its policy.
        Autoscaler-held replicas never arm: the controller owns their
        lifetime (scale-in), not the per-replica policy."""
        m = self.models[model_id]
        if m.held:
            m.evict_at = math.inf
            return
        timeout = m.policy.idle_timeout_s(self.clock())
        m.evict_at = self.clock() + timeout if math.isfinite(timeout) \
            else math.inf

    def settle(self) -> None:
        """Close the current burst phase (load/serve): fall to parked or
        bare according to residency."""
        self.meter.transition(PowerState.CTX_IDLE if self._any_resident()
                              else PowerState.BARE)

    def tick(self) -> None:
        """Apply due evictions at the current sim time."""
        now = self.clock()
        for m in self.models.values():
            if m.resident and now >= m.evict_at:
                self._evict(m)

    def fail(self) -> None:
        """Device failure: all residents drop instantly (no graceful
        unload); energy state falls to bare.  Requests after this
        transparently cold-start."""
        for m in self.models.values():
            m.engine = None
            m.resident = False
            m.loading = False
            m.evict_at = math.inf
            m.pins = 0
            m.held = False
        # a failed device comes back up bare whatever it was doing
        # (including asleep: SLEEP -> BARE is the legal wake edge)
        self.meter.transition(PowerState.BARE)

    # -- request path --------------------------------------------------------
    def handle_request(self, model_id: str, *, service_s: float = 0.0,
                       work_fn: Optional[Callable[[Any], Any]] = None
                       ) -> Any:
        """Serve one request at the current sim time.

        Advances the clock by load time (if cold) + service_s, charges
        energy per state, updates the policy, and re-arms the idle
        timeout (Eq. 12/13 for Breakeven policies)."""
        self.tick()
        m = self.models[model_id]
        m.requests += 1
        m.policy.observe_arrival(self.clock())
        wait = 0.0
        if not m.resident:
            t0 = self.clock()
            self._load(m)
            wait = self.clock() - t0
            m.added_latency_s += wait
        m.latency_samples.append(wait)
        result = None
        if work_fn is not None or service_s > 0:
            self.meter.transition(PowerState.ACTIVE)
            if work_fn is not None:
                result = work_fn(m.engine)
            self.clock.advance(service_s)
        self.meter.transition(PowerState.CTX_IDLE)
        self.arm(model_id)
        return result

    def run_trace(self, model_id: str, arrivals_s: List[float], *,
                  horizon_s: float, service_s: float = 0.0) -> Dict[str, Any]:
        """Replay an arrival trace (the serving-level Table 6)."""
        for a in sorted(arrivals_s):
            target = max(a, self.clock())
            self._advance_with_evictions(target)
            self.handle_request(model_id, service_s=service_s)
        self._advance_with_evictions(horizon_s)
        m = self.models[model_id]
        return {"energy_wh": self.meter.totals(),
                "durations_s": self.meter.durations(),
                "cold_starts": m.cold_starts,
                "requests": m.requests,
                "mean_added_latency_s": (m.added_latency_s / m.requests
                                         if m.requests else 0.0),
                "parking_tax_wh": self.meter.parking_tax_wh()}

    def _advance_with_evictions(self, target: float) -> None:
        """Advance sim time, applying any eviction deadlines on the way."""
        while True:
            pending = [m.evict_at for m in self.models.values()
                       if m.resident and math.isfinite(m.evict_at)
                       and m.evict_at <= target]
            if not pending:
                break
            t_evt = min(pending)
            self.clock.advance(max(t_evt - self.clock(), 0.0))
            self.tick()
        self.clock.advance(max(target - self.clock(), 0.0))
