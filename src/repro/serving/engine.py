"""Single-model serving engine: slot-based continuous batching over the
prefill/decode steps from models/model.py.

The engine owns a fixed decode working set: ``max_batch`` slots sharing
one stacked KV cache of ``max_len``.  Requests prefill into a free slot
(prompt written at cache offset 0..len) and then join the batched decode
step; finished slots are released and immediately reusable -- continuous
batching without recompilation (slot count and cache length are static).
Slot occupancy is tracked by the shared ``serving/slots.py`` SlotPool --
the same abstraction the fleet simulator's per-device runtime builds on.

Runs the same code the dry-run lowers; on this container the reduced
configs decode for real on CPU (examples/serve_parking.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.model import (RunFlags, build_cache_specs,
                                build_param_specs, decode_step, prefill)
from repro.models.params import materialize
from repro.serving.slots import SlotPool

Tree = Any


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    prompt: List[int]
    tokens: List[int]
    prefill_s: float = 0.0
    decode_s: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params: Tree, *, max_batch: int = 4,
                 max_len: int = 128, flags: RunFlags = RunFlags(),
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.flags = flags
        self._rng = np.random.default_rng(seed)
        self._caches = materialize(
            build_cache_specs(cfg, max_batch, max_len, jnp.float32),
            jax.random.PRNGKey(0))
        self._slots = SlotPool(max_batch)                # occupancy tracker
        self._slot_pos = np.zeros(max_batch, np.int32)   # next write offset
        self._slot_last = np.zeros(max_batch, np.int32)  # last sampled token

        cfg_ = cfg
        fl = flags

        def _prefill(params, batch, caches):
            return prefill(params, batch, caches, cfg_, fl)

        def _decode(params, tokens, caches, pos):
            return decode_step(params, tokens, caches, pos, cfg_, fl)

        self._jit_prefill = jax.jit(_prefill)
        self._jit_decode = jax.jit(_decode)

    # -- slots -------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return self._slots.free_slots()

    # -- serving -----------------------------------------------------------
    def admit(self, prompt: List[int], extras: Optional[Dict[str, Any]]
              = None) -> int:
        """Prefill `prompt` into a free slot; returns the slot id."""
        slot = self._slots.acquire()
        if slot is None:
            raise RuntimeError("no free slots")
        # batch-1 prefill then scatter the slot's cache rows
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        batch = {"tokens": toks}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        b1_caches = materialize(
            build_cache_specs(self.cfg, 1, self.max_len, jnp.float32),
            jax.random.PRNGKey(0))
        logits, b1_caches = self._jit_prefill(self.params, batch, b1_caches)
        next_tok = int(jnp.argmax(logits[0]))
        # scatter slot rows: every cache leaf has batch on some axis; the
        # builders put batch first after the layer axis, i.e. axis=1
        def put(big, small):
            return jax.lax.dynamic_update_index_in_dim(
                big, small[:, 0], slot, 1)
        self._caches = jax.tree_util.tree_map(put, self._caches, b1_caches)
        self._slot_pos[slot] = len(prompt)
        self._slot_last[slot] = next_tok
        return slot

    def step(self) -> Dict[int, int]:
        """One batched decode step across live slots; returns
        {slot: sampled_token}.  Slots advance independent positions via
        per-slot position vector folded into a single max-pos decode (the
        static-shape compromise: positions differ per slot, so we decode
        at each slot's own offset using a vectorized pos array)."""
        if self._slots.busy == 0:
            return {}
        # single shared offset decode: use per-slot position by running
        # decode at pos = max over live slots after aligning; simplest
        # correct scheme for heterogeneous positions: loop grouped by pos
        out: Dict[int, int] = {}
        tokens = jnp.asarray(self._slot_last, jnp.int32)[:, None]
        # group slots by their current position -> one decode per group
        # (snapshot positions first: a slot advanced by an earlier group
        # must not match a later group's position and decode twice)
        live = np.asarray(self._slots.live_slots(), dtype=np.intp)
        pos_now = self._slot_pos.copy()
        for pos in np.unique(pos_now[live]):
            pos_slots = [s for s in live if pos_now[s] == pos]
            logits, new_caches = self._jit_decode(
                self.params, tokens, self._caches, jnp.int32(pos))
            # keep cache updates only for the slots at this position
            def merge(new, old):
                sel = np.zeros(self.max_batch, bool)
                sel[pos_slots] = True
                sel_arr = jnp.asarray(sel)
                bshape = [1] * new.ndim
                bdim = 1  # batch axis after layer axis
                bshape[bdim] = self.max_batch
                return jnp.where(sel_arr.reshape(bshape), new, old)
            self._caches = jax.tree_util.tree_map(merge, new_caches,
                                                  self._caches)
            for s in pos_slots:
                tok = int(jnp.argmax(logits[s]))
                out[s] = tok
                self._slot_last[s] = tok
                self._slot_pos[s] += 1
        return out

    def release(self, slot: int) -> None:
        self._slots.release(slot)
        self._slot_pos[slot] = 0

    def generate(self, prompt: List[int], max_new: int = 16
                 ) -> GenerationResult:
        """Convenience single-request generation."""
        slot = self.admit(prompt)
        toks: List[int] = [int(self._slot_last[slot])]
        for _ in range(max_new - 1):
            if self._slot_pos[slot] + 1 >= self.max_len:
                break
            out = self.step()
            toks.append(out[slot])
        self.release(slot)
        return GenerationResult(request_id=slot, prompt=list(prompt),
                                tokens=toks)
