from repro.serving.energy import EnergyMeter, SimClock
from repro.serving.engine import GenerationResult, ServingEngine
from repro.serving.model_manager import ManagedModel, ModelManager
from repro.serving.service_model import (ConstantServiceTime,
                                         ModelServiceProfile, RequestShape,
                                         RooflineServiceTime,
                                         ServiceTimeModel)
from repro.serving.slots import DeviceRuntime, SlotPool

__all__ = ["EnergyMeter", "SimClock", "ServingEngine", "GenerationResult",
           "ModelManager", "ManagedModel", "SlotPool", "DeviceRuntime",
           "ServiceTimeModel", "ConstantServiceTime", "RooflineServiceTime",
           "ModelServiceProfile", "RequestShape"]
