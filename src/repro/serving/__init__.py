from repro.serving.energy import EnergyMeter, SimClock
from repro.serving.engine import GenerationResult, ServingEngine
from repro.serving.model_manager import ManagedModel, ModelManager

__all__ = ["EnergyMeter", "SimClock", "ServingEngine", "GenerationResult",
           "ModelManager", "ManagedModel"]
