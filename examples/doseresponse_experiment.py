"""Reproduce the paper's Phase-2 dose-response figure data (Fig. 1/3) and
Table 2 on all three GPU architectures, printing the per-phase means the
figures plot.

Run:  PYTHONPATH=src python examples/doseresponse_experiment.py
"""
from repro.core import A100, H100, L40S
from repro.core.doseresponse import run_simulated_dose_response, table2_row

DRIFT = {"H100-80GB-SXM": 0.0, "A100-80GB-PCIe": 0.05, "L40S-48GB": 0.0}


def main() -> None:
    for prof in (H100, A100, L40S):
        dr = run_simulated_dose_response(
            prof, seed=42, thermal_drift_w_per_hr=DRIFT[prof.name])
        row = table2_row(dr, prof)
        print(f"=== {prof.name} ({prof.memory_tech}) ===")
        print("  Fig-1 dose-response (vram_gb -> mean W +- sd):")
        for ph in dr.phases:
            tag = "ctx" if ph.context_active else "bare"
            print(f"    {tag:4s} {ph.vram_gb:6.1f} GB : "
                  f"{ph.mean_w:8.2f} +- {ph.std_w:.2f} W")
        print(f"  Table-2 column: step=+{row['context_overhead_w']} W "
              f"({row['context_pct_tdp']}% TDP), "
              f"beta={row['beta_w_per_gb']:+.4f} W/GB "
              f"[{row['beta_ci'][0]:+.4f},{row['beta_ci'][1]:+.4f}], "
              f"p={row['p_beta']:.3f}, p_TOST={row['p_tost']:.2g}, "
              f"context share {row['context_share_pct']}%")


if __name__ == "__main__":
    main()
