"""Cluster-scale parking tax: 10 models on 6 mixed-architecture GPUs.

The paper's single-device question -- keep a parked model warm or evict
it -- becomes three coupled questions at fleet scale: WHERE to load a
cold model (routing), WHEN to evict each replica (policy), and whether
to PACK parked models onto fewer devices so drained GPUs fall back to
bare idle (consolidation: the DVFS step is per-device, one context
keeps the clocks up).

This example replays a day of mixed traffic (diurnal + bursty +
heavy-tail MMPP + steady) for 10 models with 5-37 GB checkpoints over
2x H100 + 2x A100 + 2x L40S, and walks the operating points from the
industry default (always-on, warm everywhere) to energy-greedy routing
with breakeven eviction and consolidation, against the clairvoyant
lower bound.

The second table turns on the concurrent device runtime: roofline
service times (occupancy-dependent prefill/decode from per-SKU
throughput), loads overlapping decode, and up to max_batch=4 requests
per model in flight -- and walks the energy/latency Pareto the
SLO-aware router trades along (energy min subject to a p99
added-latency budget).

The third table prices the day in carbon: the same fleet under a
solar-duck grid-intensity trace (fleet/carbon.py), with the carbon-aware
stack (carbon-breakeven eviction + carbon routing + carbon-aware
consolidation) against energy-greedy, and the schedule re-priced across
electricity zones (carbon is a post-hoc integral over the metered power
timeline, so zones need no re-simulation).

The final table opens the bare-idle floor itself: device power gating
(core/power_states.py sleep/wake state machine) puts fully drained
devices to SLEEP past the wake-energy breakeven, cutting below the
p_base_w floor every other policy treats as untouchable.

Run:  PYTHONPATH=src python examples/fleet_parking.py
"""
import math

from repro.core.scheduler import AlwaysOn, Breakeven
from repro.fleet import (CarbonAwareRouter, CarbonBreakeven, Consolidator,
                         MIXES, ReplicaAutoscaler, SLOAwareRouter,
                         mixed_fleet_scenario, run_fleet, trace_for_zone)
from repro.serving import RooflineServiceTime


def main() -> None:
    runs = [
        ("always-on, warm everywhere (industry default)",
         mixed_fleet_scenario(AlwaysOn, "warm-first")),
        ("always-on + consolidation (packing alone)",
         mixed_fleet_scenario(AlwaysOn, "warm-first", consolidate=True)),
        ("breakeven eviction + warm-first routing",
         mixed_fleet_scenario(Breakeven, "warm-first")),
        ("breakeven + energy-greedy routing",
         mixed_fleet_scenario(Breakeven, "energy-greedy")),
        ("breakeven + energy-greedy + consolidation",
         mixed_fleet_scenario(Breakeven, "energy-greedy", consolidate=True)),
    ]
    base = None
    for name, sc in runs:
        res = run_fleet(sc)
        base = base or res
        print(f"{name:48s} {res.energy_wh:9.1f} Wh "
              f"({100 * res.savings_vs(base):5.1f}% vs always-on) | "
              f"cold {res.cold_starts:4d} | migrations {res.migrations:3d} | "
              f"mean added latency {res.mean_added_latency_s:5.2f} s")
        if base is res:
            print(f"{'':48s}   per-device: " + ", ".join(
                f"{d.instance_id} {d.total_wh:.0f} Wh" for d in res.devices))
    print(f"{'clairvoyant non-gated lower bound':48s} "
          f"{base.lb_nongated_wh:9.1f} Wh "
          f"({100 * (1 - base.lb_nongated_wh / base.energy_wh):5.1f}%)")
    print(f"\nfleet rental {base.infra_usd:.0f} USD/day on-demand; "
          f"always-on energy {base.energy_usd:.2f} USD/day, "
          f"{base.carbon_kg:.1f} kgCO2e/day (USA grid; catalog estimates)")

    # -- energy vs latency Pareto under concurrent serving ---------------
    svc = RooflineServiceTime()
    print("\nconcurrent runtime (roofline service times, max_batch=4):"
          f" {'Wh':>9s} {'req/s':>6s} {'p50_s':>6s} {'p99_s':>7s}")
    pareto = [
        ("always-on, warm everywhere", mixed_fleet_scenario(
            AlwaysOn, "warm-first", service_model=svc)),
        ("breakeven + energy-greedy (joules only)", mixed_fleet_scenario(
            Breakeven, "energy-greedy", service_model=svc)),
        ("breakeven + slo-aware (p99 <= 120 s)", mixed_fleet_scenario(
            Breakeven, SLOAwareRouter(120.0), service_model=svc)),
        ("breakeven + slo-aware (p99 <= 90 s)", mixed_fleet_scenario(
            Breakeven, SLOAwareRouter(90.0), service_model=svc)),
        ("breakeven + slo-aware (p99 <= 30 s, infeasible)",
         mixed_fleet_scenario(Breakeven, SLOAwareRouter(30.0),
                              service_model=svc)),
    ]
    slo_single = None
    for name, sc in pareto:
        res = run_fleet(sc)
        if "p99 <= 90" in name:
            slo_single = res
        print(f"{name:56s} {res.energy_wh:9.1f} {res.requests_per_s:6.3f}"
              f" {res.p50_added_latency_s:6.2f}"
              f" {res.p99_added_latency_s:7.2f}")
    print("(tighter budgets buy latency with joules: the router keeps "
          "cold routes off slow-loading SKUs; an infeasible budget "
          "degrades to latency-greedy, the best achievable p99)")

    # -- replica auto-scaling: the over-provisioning parking tax ----------
    auto = run_fleet(mixed_fleet_scenario(
        Breakeven, SLOAwareRouter(90.0), service_model=svc,
        autoscaler=ReplicaAutoscaler()))
    print(f"\n{'breakeven + slo-aware (90 s) + replica autoscaler':56s}"
          f" {auto.energy_wh:9.1f} {auto.requests_per_s:6.3f}"
          f" {auto.p50_added_latency_s:6.2f}"
          f" {auto.p99_added_latency_s:7.2f}")
    d_wh = auto.energy_wh - slo_single.energy_wh
    d_p99 = slo_single.p99_added_latency_s - auto.p99_added_latency_s
    rate = f"{d_wh / d_p99:.1f}" if d_p99 > 0 else "n/a"
    print(f"  {auto.scale_outs} scale-outs / {auto.scale_ins} scale-ins, "
          f"peak {auto.peak_replicas()} replicas per route; "
          f"cold starts {slo_single.cold_starts} -> {auto.cold_starts}")
    print(f"  over-provisioned warm replicas buy {d_p99:.1f} s of p99 for "
          f"{d_wh:+.1f} Wh ({rate} Wh per p99-second): the "
          f"parking tax of keeping hot routes multi-replica, priced")

    # -- carbon: the same day under a time-varying grid ------------------
    eg_c = run_fleet(mixed_fleet_scenario(
        Breakeven, "energy-greedy", service_model=svc,
        carbon_trace="solar-duck"))
    ca_c = run_fleet(mixed_fleet_scenario(
        CarbonBreakeven, CarbonAwareRouter(math.inf), service_model=svc,
        carbon_trace="solar-duck",
        consolidate=Consolidator(carbon_aware=True, period_s=300.0)))
    print("\ncarbon under a solar-duck grid trace (daily mean = USA "
          "0.39 kgCO2e/kWh):")
    for name, res in (("breakeven + energy-greedy", eg_c),
                      ("carbon-aware stack", ca_c)):
        print(f"  {name:40s} {res.carbon_kg:8.4f} kg  "
              f"p99 {res.p99_added_latency_s:6.2f} s  "
              f"({res.energy_wh:8.1f} Wh)")
    d_kg = eg_c.carbon_kg - ca_c.carbon_kg
    print(f"  carbon-aware scheduling saves {d_kg:+.4f} kgCO2e/day at "
          f"equal-or-better p99; most fleet carbon is the bare-idle "
          f"floor, so the lever is hour-scale deferrable work "
          f"(see docs/CARBON.md)")
    print("\n  the SAME schedule re-priced per zone trace "
          "(kgCO2e/day, no re-simulation):")
    row = "   ".join(
        f"{zone} {ca_c.carbon_with(trace_for_zone(zone)):7.3f}"
        for zone in sorted(MIXES))
    print(f"  {row}")

    # -- per-device zones: follow-the-sun placement -----------------------
    # geo-split the same fleet (DEU / USA / IND), price each device on
    # its zone's LOCAL-time trace, and let the carbon-aware router +
    # consolidator chase the solar troughs across zones.  Cross-zone
    # migrations pay a WAN checkpoint transfer (energy + latency), so
    # only moves that clear the carbon margin happen (docs/CARBON.md).
    zfleet = "2xh100@DEU+2xa100@USA+2xl40s@IND"
    zruns = {}
    for aware in (True, False):
        zruns[aware] = run_fleet(mixed_fleet_scenario(
            CarbonBreakeven, CarbonAwareRouter(math.inf, zone_aware=aware),
            consolidate=Consolidator(carbon_aware=True, period_s=300.0),
            fleet=zfleet, carbon_trace="zone", zone="USA"))
    print(f"\nper-device zones: follow-the-sun on {zfleet}:")
    for name, res in (("zone-aware placement", zruns[True]),
                      ("zone-blind placement", zruns[False])):
        per_zone = "  ".join(f"{z} {kg:.4f}" for z, kg
                             in sorted(res.zone_carbon_kg.items()))
        print(f"  {name:40s} {res.carbon_kg:8.4f} kg  "
              f"p99 {res.p99_added_latency_s:6.2f} s  [{per_zone}]")
    z_kg = zruns[False].carbon_kg - zruns[True].carbon_kg
    print(f"  knowing WHERE each joule is drawn saves {z_kg:+.4f} "
          f"kgCO2e/day on top of knowing when; "
          f"{zruns[True].cross_zone_migrations} cross-zone moves "
          f"({zruns[True].transfer_wh:.2f} Wh WAN transfer)")

    # -- device power gating: opening the bare-idle floor -----------------
    # ~92% of fleet carbon is the trace-invariant p_base floor; the
    # sleep/wake state machine (core/power_states.py) is the first
    # mechanism that cuts below it.  Consolidation drains devices,
    # gate_drained_devices puts them to SLEEP past the wake-energy
    # breakeven, and routing prices wake latency + energy into cold
    # placement so the p99 budget still holds.
    best_nongated = run_fleet(mixed_fleet_scenario(
        Breakeven, "energy-greedy", consolidate=True, service_model=svc))
    gated = run_fleet(mixed_fleet_scenario(
        Breakeven, SLOAwareRouter(90.0), service_model=svc,
        consolidate=Consolidator(period_s=300.0,
                                 gate_drained_devices=True)))
    print("\ndevice power gating (sleep/wake; see docs/POWER.md):")
    for name, res in (("best non-gated (energy-greedy + consolidate)",
                       best_nongated),
                      ("slo-aware (90 s) + consolidate + gating", gated)):
        print(f"  {name:46s} {res.energy_wh:9.1f} Wh  "
              f"p99 {res.p99_added_latency_s:6.2f} s")
    sleep_h = gated.state_durations_s.get("sleep", 0.0) / 3600.0
    print(f"  {gated.gates} gates / {gated.wakes} wakes, {sleep_h:.0f} "
          f"device-hours asleep; {gated.gated_wh_saved:.0f} Wh recovered "
          f"from the bare-idle floor -- "
          f"{100 * gated.savings_vs(best_nongated):.0f}% below the best "
          f"non-gated policy (and below its non-gated clairvoyant bound "
          f"{best_nongated.lb_nongated_wh:.0f} Wh, which assumed devices "
          f"never sleep)")


if __name__ == "__main__":
    main()
