"""Quickstart: the paper in 60 seconds of CPU time.

  1. Measure a device's parking tax with the Phase-2 dose-response
     protocol (simulated oracle carrying the paper's physics).
  2. Derive the cold-start breakeven T* / critical rate lambda*.
  3. Run the 24 h scheduler comparison on bursty traffic.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import H100, PYTORCH_70B
from repro.core.breakeven import breakeven_seconds, critical_rate_per_hr, \
    format_t_star
from repro.core.doseresponse import run_simulated_dose_response
from repro.core.scheduler import AdaptiveBreakeven, AlwaysOn, Breakeven, \
    FixedTTL
from repro.core.simulator import compare_policies
from repro.core import traffic


def main() -> None:
    # -- 1. measure --------------------------------------------------------
    dr = run_simulated_dose_response(H100, seed=0)
    print(f"[measure] {dr.device}: bare {dr.bare_idle_w:.1f} W, "
          f"context-idle {dr.ctx_idle_w:.1f} W "
          f"-> parking tax {dr.dvfs_step_w:.1f} W")
    print(f"[measure] VRAM slope beta = {dr.regression.slope:+.4f} W/GB "
          f"(p={dr.regression.p_value:.2f}); TOST |beta|<0.1: "
          f"{'PASS' if dr.tost.equivalent else 'FAIL'} "
          f"-> context is {100*dr.context_share_of_tax:.1f}% of the tax")

    # -- 2. decide ----------------------------------------------------------
    t_star = breakeven_seconds(PYTORCH_70B, H100)
    lam = critical_rate_per_hr(PYTORCH_70B, H100)
    print(f"[breakeven] 70B/PyTorch loader: T* = {format_t_star(t_star)}, "
          f"keep warm above {lam:.1f} req/hr")

    # -- 3. schedule ---------------------------------------------------------
    arr = traffic.bursty(seed=0)
    res = compare_policies(
        arr, [AlwaysOn(), FixedTTL(300), Breakeven(PYTORCH_70B, H100),
              AdaptiveBreakeven(PYTORCH_70B, H100)], H100, PYTORCH_70B)
    base = res[0]
    print(f"[schedule] bursty day, {len(arr)} requests:")
    for r in res:
        print(f"  {r.policy:34s} {r.energy_wh:7.0f} Wh "
              f"({100*r.savings_vs(base):+5.1f}%)  "
              f"cold-starts {r.cold_starts:3d}  "
              f"added latency {r.mean_added_latency_s:5.1f} s/req")


if __name__ == "__main__":
    main()
