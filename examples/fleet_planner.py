"""Four-objective fleet planning on the pinned 3-zone day: sweep fleet
compositions, purchase tiers, routers, and spot preemption rates, then
print the non-dominated (cost, energy, carbon, p99) frontier and its
hypervolume against the all-on-demand plan.

Run:  PYTHONPATH=src python examples/fleet_planner.py [--fast]

--fast shrinks the day to 6 h and uses the numpy replay backend (the
default sweeps the full 24 h day with the jax backend where plans fit
the compiled scope).  --batched (the default) groups grid points that
share dynamics into one simulation each; --serial evaluates every
point on its own.  Passing BOTH runs both modes and prints the
wall-clock comparison (the frontiers are identical point-for-point).
"""
import argparse

from repro.fleet.planner import pinned_day_axes, pinned_day_base, plan_fleet


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="6 h horizon + numpy backend")
    ap.add_argument("--json", action="store_true",
                    help="emit the frontier as JSON instead of a table")
    ap.add_argument("--batched", action="store_true",
                    help="grouped shared-compile execution (default)")
    ap.add_argument("--serial", action="store_true",
                    help="one simulation per grid point")
    args = ap.parse_args()

    base = pinned_day_base(horizon_s=6 * 3600.0 if args.fast else 24 * 3600.0)
    axes = pinned_day_axes(routers=("warm-first", "slo-aware",
                                    "carbon-aware"))
    backend = "numpy" if args.fast else "jax"

    compare = args.batched and args.serial
    res_serial = None
    if args.serial:
        res_serial = plan_fleet(base, axes, backend=backend, batched=False)
    res = (plan_fleet(base, axes, backend=backend, batched=True)
           if (args.batched or not args.serial) else res_serial)

    if args.json:
        print(res.to_json())
        return

    ref = res.reference
    st = res.stats
    print(f"evaluated {len(res.points)} plans; "
          f"frontier {len(res.frontier)}; "
          f"hypervolume vs all-on-demand {res.hypervolume:.4f}")
    print(f"{st['mode']} execution: {st['sims']} simulations for "
          f"{st['points']} points in {st['wall_s']:.2f} s wall "
          f"({st['compiles']} fresh compiles)")
    if compare:
        ss = res_serial.stats
        same = all(a.objectives() == b.objectives()
                   for a, b in zip(res_serial.points, res.points))
        print(f"serial execution: {ss['sims']} simulations in "
              f"{ss['wall_s']:.2f} s wall -> batched speedup "
              f"{ss['wall_s'] / st['wall_s']:.2f}x "
              f"(frontiers identical: {same})")
    print(f"reference (all on-demand): ${ref.cost_usd:.2f}  "
          f"{ref.energy_wh:.0f} Wh  {ref.carbon_kg:.3f} kg  "
          f"p99 {ref.p99_s:.1f} s")
    print()
    print(f"{'cost $':>9} {'Wh':>8} {'kgCO2e':>8} {'p99 s':>7} "
          f"{'pre':>4}  plan")
    for p in res.frontier:
        print(f"{p.cost_usd:9.2f} {p.energy_wh:8.0f} {p.carbon_kg:8.3f} "
              f"{p.p99_s:7.1f} {p.preemptions:4d}  {p.label()}")
    print()
    best_cost = res.best("cost_usd")
    best_kg = res.best("carbon_kg")
    print(f"best cost:   {best_cost.label()} "
          f"(${best_cost.cost_usd:.2f}, "
          f"{1 - best_cost.cost_usd / ref.cost_usd:.0%} under on-demand)")
    print(f"best carbon: {best_kg.label()} ({best_kg.carbon_kg:.3f} kg)")


if __name__ == "__main__":
    main()
