"""End-to-end serving driver: a REAL model served with batched requests
under energy-aware lifecycle management (the paper's technique live).

A reduced Qwen2.5-7B-family model decodes actual tokens on CPU through
the ServingEngine; the ModelManager makes keep-warm/evict decisions with
the breakeven policy and meters energy with the H100 profile.  A day of
bursty traffic is replayed in simulated time (decode compute runs for
real; waiting does not).

Run:  PYTHONPATH=src python examples/serve_parking.py
"""
import jax

from repro.configs import get_reduced
from repro.core import H100, QWEN25_7B_MEASURED
from repro.core.scheduler import AlwaysOn, Breakeven
from repro.core import traffic
from repro.models import RunFlags, build_param_specs, materialize
from repro.serving import ModelManager, ServingEngine, SimClock


def main() -> None:
    cfg = get_reduced("qwen2-5-7b")
    params = materialize(build_param_specs(cfg), jax.random.PRNGKey(0))
    # one warm engine reused across cold starts: in production the load
    # deserializes a checkpoint (ModelManager advances the sim clock by
    # t_load and charges P_load); rebuilding jit closures per cold start
    # would only measure XLA compile time
    engine = ServingEngine(cfg, params, max_batch=4, max_len=48,
                           flags=RunFlags(remat="none"))

    def load_engine():
        return engine

    arrivals = traffic.bursty(seed=1, horizon_s=6 * 3600.0)  # 6h demo
    print(f"replaying {len(arrivals)} requests over 6 h (simulated time, "
          f"real decode compute)")

    for policy in (AlwaysOn(), Breakeven(QWEN25_7B_MEASURED, H100)):
        mm = ModelManager(H100, clock=SimClock())
        mm.register("qwen", policy=policy, loader=QWEN25_7B_MEASURED,
                    load_fn=load_engine)
        tokens_out = 0

        def serve_one(engine):
            nonlocal tokens_out
            res = engine.generate([1, 2, 3, 4, 5], max_new=8)
            tokens_out += len(res.tokens)
            return res

        mm.handle_request("qwen", work_fn=serve_one)       # initial load
        for a in arrivals:
            mm._advance_with_evictions(max(float(a), mm.clock()))
            mm.handle_request("qwen", work_fn=serve_one)
        mm._advance_with_evictions(6 * 3600.0)

        m = mm.models["qwen"]
        wh = mm.meter.totals()
        print(f"  {policy.name:30s} energy {wh['total']:7.1f} Wh "
              f"(parked {wh.get('parked', 0.0):6.1f}, "
              f"bare {wh.get('bare', 0.0):6.1f}, "
              f"loading {wh.get('loading', 0.0):5.1f}) | "
              f"cold starts {m.cold_starts:3d} | "
              f"{tokens_out} real tokens decoded | "
              f"parking tax {mm.meter.parking_tax_wh():6.1f} Wh")


if __name__ == "__main__":
    main()
