"""Train a ~100M-parameter LM for a few hundred steps on CPU with the
full production path: sharded init, AdamW + microbatch accumulation,
int8 gradient compression, async fault-tolerant checkpoints, resumable
data pipeline.  Loss must descend on the structured synthetic corpus.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import dataclasses

import numpy as np

from repro.models.config import dense_lm
from repro.models.model import RunFlags
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import TrainConfig, train

# ~100M params: 12L x 512 with a 32k vocab (GPT-small-ish)
CONFIG = dense_lm(
    "lm-100m", n_layers=12, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=32_768, family="dense",
    source="examples/train_100m")
CONFIG = dataclasses.replace(CONFIG, param_dtype=None or CONFIG.param_dtype)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    import jax.numpy as jnp
    cfg = dataclasses.replace(CONFIG, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32)
    print(f"params: {cfg.param_count()/1e6:.1f}M")
    tc = TrainConfig(
        steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        checkpoint_dir=args.ckpt, checkpoint_every=100, log_every=20,
        grad_compression=True,
        opt=AdamWConfig(lr=3e-4, warmup_steps=50, total_steps=args.steps),
        flags=RunFlags(remat="full", grad_accum=2))
    hist = train(cfg, tc)
    first = float(np.mean(hist["loss"][:20]))
    last = float(np.mean(hist["loss"][-20:]))
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'DESCENDED' if last < first - 0.1 else 'check run length'})")


if __name__ == "__main__":
    main()
