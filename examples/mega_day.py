"""Mega-fleet day: 600 GPUs, a million requests, three bad days.

The event-driven fleet simulator prices every request at Python speed;
this example uses the vectorized mega simulator (fleet/mega/, see
docs/SCALE.md) to replay production-shaped days over a 600-device
mixed estate in seconds -- and shows what each day shape does to the
parking tax.

Three synthetic days, all seeded and reproducible:

  * flash-crowd      one route goes viral for 30 minutes at 1pm
  * product-launch   a new model is public at 9am (zero traffic before)
  * regional-outage  an upstream region is dark 11am-noon, then the
                     deferred demand slams back

First, though, the anchor that makes the speed trustworthy: on the
pinned 10-model x 6-GPU day, run_mega reproduces run_fleet's joules
bit-for-bit (tests/test_mega.py pins this; here we just print it).

Run:  PYTHONPATH=src python examples/mega_day.py
"""
import time

from repro.core.scheduler import Breakeven
from repro.fleet import (flash_crowd, mixed_fleet_scenario, product_launch,
                         regional_outage, run_fleet, run_mega)

SEED = 100
FLEET = "200xh100+200xa100+200xl40s"


def main() -> None:
    # -- the anchor: same day, both simulators, same joules ------------
    t0 = time.perf_counter()
    ref = run_fleet(mixed_fleet_scenario(Breakeven, "warm-first",
                                         seed=SEED))
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    got = run_mega(mixed_fleet_scenario(Breakeven, "warm-first",
                                        seed=SEED))
    t_mega = time.perf_counter() - t0
    print("== anchor: pinned 10-model x 6-GPU day ==")
    print(f"   event loop  {ref.energy_wh:12.3f} Wh   {t_ref:6.2f} s")
    print(f"   mega        {got.energy_wh:12.3f} Wh   {t_mega:6.2f} s"
          f"   ({t_ref / t_mega:.1f}x)")
    assert got.energy_wh == ref.energy_wh
    assert got.requests == ref.requests

    # -- three production-shaped mega days -----------------------------
    print(f"\n== mega days: 600 routes on {FLEET} ==")
    print(f"   {'day':16s} {'requests':>10s} {'kWh':>8s} {'cold':>6s}"
          f" {'tax kWh':>8s} {'p99_s':>6s} {'wall_s':>7s}")
    for gen in (flash_crowd, product_launch, regional_outage):
        trace = gen(n_routes=600, fleet=FLEET, seed=SEED,
                    base_rate_hr=130.0)
        t0 = time.perf_counter()
        res = run_mega(trace.to_scenario(Breakeven), compute_bound=False)
        wall = time.perf_counter() - t0
        print(f"   {trace.name:16s} {res.requests:10,d}"
              f" {res.energy_wh / 1e3:8.1f} {res.cold_starts:6d}"
              f" {res.parking_tax_wh / 1e3:8.1f}"
              f" {res.p99_added_latency_s:6.1f} {wall:7.1f}")

    print("\n   (same physics as run_fleet -- the anchor above is the "
          "proof -- at ~50k simulated requests/second)")


if __name__ == "__main__":
    main()
