"""Mega-fleet day: 600 GPUs, a million requests, three bad days.

The event-driven fleet simulator prices every request at Python speed;
this example uses the vectorized mega simulator (fleet/mega/, see
docs/SCALE.md) to replay production-shaped days over a 600-device
mixed estate in seconds -- and shows what each day shape does to the
parking tax.

Three synthetic days, all seeded and reproducible:

  * flash-crowd      one route goes viral for 30 minutes at 1pm
  * product-launch   a new model is public at 9am (zero traffic before)
  * regional-outage  an upstream region is dark 11am-noon, then the
                     deferred demand slams back

First, though, the anchor that makes the speed trustworthy: on the
pinned 10-model x 6-GPU day, run_mega reproduces run_fleet's joules
bit-for-bit (tests/test_mega.py pins this; here we just print it).

The closer repeats one day on the compiled backend
(run_mega(backend="jax"), see docs/SCALE.md): same decisions, same
joules, bulk arithmetic jit-compiled -- then sweeps a batch of seeded
days through run_mega_sweep so the compiles amortize across points.

Run:  PYTHONPATH=src JAX_PLATFORMS=cpu python examples/mega_day.py
"""
import time

from repro.core.scheduler import Breakeven
from repro.fleet import (flash_crowd, make_trace, mixed_fleet_scenario,
                         product_launch, regional_outage, run_fleet,
                         run_mega, run_mega_sweep)

SEED = 100
FLEET = "200xh100+200xa100+200xl40s"


def main() -> None:
    # -- the anchor: same day, both simulators, same joules ------------
    t0 = time.perf_counter()
    ref = run_fleet(mixed_fleet_scenario(Breakeven, "warm-first",
                                         seed=SEED))
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    got = run_mega(mixed_fleet_scenario(Breakeven, "warm-first",
                                        seed=SEED))
    t_mega = time.perf_counter() - t0
    print("== anchor: pinned 10-model x 6-GPU day ==")
    print(f"   event loop  {ref.energy_wh:12.3f} Wh   {t_ref:6.2f} s")
    print(f"   mega        {got.energy_wh:12.3f} Wh   {t_mega:6.2f} s"
          f"   ({t_ref / t_mega:.1f}x)")
    assert got.energy_wh == ref.energy_wh
    assert got.requests == ref.requests

    # -- three production-shaped mega days -----------------------------
    print(f"\n== mega days: 600 routes on {FLEET} ==")
    print(f"   {'day':16s} {'requests':>10s} {'kWh':>8s} {'cold':>6s}"
          f" {'tax kWh':>8s} {'p99_s':>6s} {'wall_s':>7s}")
    for gen in (flash_crowd, product_launch, regional_outage):
        trace = gen(n_routes=600, fleet=FLEET, seed=SEED,
                    base_rate_hr=130.0)
        t0 = time.perf_counter()
        res = run_mega(trace.to_scenario(Breakeven), compute_bound=False)
        wall = time.perf_counter() - t0
        print(f"   {trace.name:16s} {res.requests:10,d}"
              f" {res.energy_wh / 1e3:8.1f} {res.cold_starts:6d}"
              f" {res.parking_tax_wh / 1e3:8.1f}"
              f" {res.p99_added_latency_s:6.1f} {wall:7.1f}")

    print("\n   (same physics as run_fleet -- the anchor above is the "
          "proof -- at ~50k simulated requests/second)")

    # -- the compiled backend ------------------------------------------
    # Price the flash-crowd day against a shaped carbon trace -- the
    # setting where the numpy bulk path pays a per-segment Python
    # integral and the jax backend's compiled programs (including the
    # kernels/segment_trapz carbon kernel) earn their keep.
    ct = make_trace("solar-duck", 0.39)
    trace = flash_crowd(n_routes=600, fleet=FLEET, seed=SEED,
                        base_rate_hr=130.0)
    print("\n== compiled backend: flash-crowd day, solar-duck carbon ==")
    results = {}
    for backend in ("numpy", "jax"):
        t0 = time.perf_counter()
        res = run_mega(trace.to_scenario(Breakeven, carbon_trace=ct),
                       compute_bound=False, backend=backend)
        wall = time.perf_counter() - t0
        bulk = sum(res.phase_timings.values())
        results[backend] = res
        print(f"   {backend:6s} {res.energy_wh / 1e3:8.1f} kWh"
              f" {res.carbon_kg:8.1f} kgCO2e"
              f"   bulk {bulk:5.1f} s   wall {wall:5.1f} s")
    assert results["jax"].requests == results["numpy"].requests
    assert abs(results["jax"].carbon_kg - results["numpy"].carbon_kg) \
        <= 1e-9 * results["numpy"].carbon_kg

    # -- sweep: compile once, run the batch hot ------------------------
    n_pts = 8
    t0 = time.perf_counter()
    pts = run_mega_sweep(seeds=range(n_pts), generator="flash-crowd",
                         n_routes=24, fleet="2xh100+2xa100+2xl40s",
                         horizon_s=6 * 3600.0, base_rate_hr=40.0,
                         scenario_kw=dict(carbon_trace=ct))
    wall = time.perf_counter() - t0
    taxes = [p.parking_tax_wh / 1e3 for p in pts]
    print(f"\n== sweep: {n_pts} seeded 6 h days in {wall:.1f} s "
          f"({n_pts / wall:.1f} pts/s) ==")
    print(f"   parking tax {min(taxes):.2f}-{max(taxes):.2f} kWh per day"
          f" (seed spread on one compiled program)")


if __name__ == "__main__":
    main()
