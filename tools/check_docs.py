#!/usr/bin/env python
"""Docs gate (CI `docs` job): markdown links resolve, python blocks run.

Three checks over README.md and every markdown file under docs/:

  1. every RELATIVE markdown link/image target exists on disk
     (external http(s)/mailto links and pure #anchors are skipped);
  2. every fenced ```python code block executes successfully under
     PYTHONPATH=src (each block in its own interpreter, repo root as
     cwd) -- so the documented examples cannot rot;
  3. every page under docs/ is LINKED from at least one other scanned
     page -- a new docs page (e.g. docs/POWER.md) cannot land as an
     orphan that readers never find.

Blocks that are intentionally non-executable should use a different
fence language (```text, ```console, or bare ```).

Run locally:  python tools/check_docs.py
Exit status: 0 clean, 1 with a per-failure report.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TIMEOUT_S = 300

# [text](target) / ![alt](target); target ends at the first unbalanced ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^```(\w*)\s*$")


def doc_files() -> list:
    files = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        for dirpath, _, names in os.walk(docs):
            files.extend(os.path.join(dirpath, n)
                         for n in sorted(names) if n.endswith(".md"))
    return [f for f in files if os.path.isfile(f)]


def strip_code(text: str) -> str:
    """Remove fenced blocks so code snippets can't fake link syntax."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_links(path: str, text: str, resolved_out: set = None) -> list:
    """Broken-relative-link errors; existing CROSS-page targets are
    added to ``resolved_out`` (absolute paths) for the orphan-page
    check -- a page linking to itself does not count as linked."""
    errors = []
    for target in _LINK.findall(strip_code(text)):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target) or target.startswith("#"):
            continue                       # external scheme or in-page anchor
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, ROOT)}: broken link "
                          f"-> {target}")
        elif resolved_out is not None \
                and os.path.abspath(resolved) != os.path.abspath(path):
            resolved_out.add(os.path.abspath(resolved))
    return errors


def check_orphans(files: list, linked: set) -> list:
    """Every docs/ page must be linked from some other scanned page."""
    errors = []
    for path in files:
        if os.path.basename(path) == "README.md":
            continue                       # the root is the entry point
        if os.path.abspath(path) not in linked:
            errors.append(f"{os.path.relpath(path, ROOT)}: orphan docs "
                          f"page (not linked from README.md or any "
                          f"other docs page)")
    return errors


def python_blocks(text: str) -> list:
    blocks, cur, lang = [], None, None
    for line in text.splitlines():
        m = _FENCE.match(line)
        if m:
            if cur is None:
                cur, lang = [], m.group(1).lower()
            else:
                if lang == "python":
                    blocks.append("\n".join(cur))
                cur, lang = None, None
            continue
        if cur is not None:
            cur.append(line)
    return blocks


def run_block(path: str, idx: int, code: str) -> list:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    try:
        proc = subprocess.run([sys.executable, "-c", code], cwd=ROOT,
                              env=env, capture_output=True, text=True,
                              timeout=TIMEOUT_S)
    except subprocess.TimeoutExpired:
        return [f"{os.path.relpath(path, ROOT)}: python block #{idx} "
                f"timed out after {TIMEOUT_S}s"]
    if proc.returncode != 0:
        tail = (proc.stderr or proc.stdout).strip().splitlines()[-12:]
        return [f"{os.path.relpath(path, ROOT)}: python block #{idx} "
                f"failed (rc={proc.returncode}):\n    "
                + "\n    ".join(tail)]
    return []


def main() -> int:
    errors = []
    n_blocks = 0
    files = doc_files()
    linked: set = set()
    for path in files:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        errors.extend(check_links(path, text, linked))
        for i, code in enumerate(python_blocks(text), 1):
            n_blocks += 1
            print(f"running {os.path.relpath(path, ROOT)} "
                  f"python block #{i} ...", flush=True)
            errors.extend(run_block(path, i, code))
    errors.extend(check_orphans(files, linked))
    if errors:
        print(f"\nFAIL: {len(errors)} docs problem(s)\n")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"\nOK: {len(files)} files, all links resolve and no page "
          f"is orphaned, {n_blocks} python blocks ran clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
