#!/usr/bin/env python
"""Docs gate (CI `docs` job): markdown links resolve, python blocks run.

Two checks over README.md and every markdown file under docs/:

  1. every RELATIVE markdown link/image target exists on disk
     (external http(s)/mailto links and pure #anchors are skipped);
  2. every fenced ```python code block executes successfully under
     PYTHONPATH=src (each block in its own interpreter, repo root as
     cwd) -- so the documented examples cannot rot.

Blocks that are intentionally non-executable should use a different
fence language (```text, ```console, or bare ```).

Run locally:  python tools/check_docs.py
Exit status: 0 clean, 1 with a per-failure report.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TIMEOUT_S = 300

# [text](target) / ![alt](target); target ends at the first unbalanced ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^```(\w*)\s*$")


def doc_files() -> list:
    files = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        for dirpath, _, names in os.walk(docs):
            files.extend(os.path.join(dirpath, n)
                         for n in sorted(names) if n.endswith(".md"))
    return [f for f in files if os.path.isfile(f)]


def strip_code(text: str) -> str:
    """Remove fenced blocks so code snippets can't fake link syntax."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_links(path: str, text: str) -> list:
    errors = []
    for target in _LINK.findall(strip_code(text)):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target) or target.startswith("#"):
            continue                       # external scheme or in-page anchor
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, ROOT)}: broken link "
                          f"-> {target}")
    return errors


def python_blocks(text: str) -> list:
    blocks, cur, lang = [], None, None
    for line in text.splitlines():
        m = _FENCE.match(line)
        if m:
            if cur is None:
                cur, lang = [], m.group(1).lower()
            else:
                if lang == "python":
                    blocks.append("\n".join(cur))
                cur, lang = None, None
            continue
        if cur is not None:
            cur.append(line)
    return blocks


def run_block(path: str, idx: int, code: str) -> list:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    try:
        proc = subprocess.run([sys.executable, "-c", code], cwd=ROOT,
                              env=env, capture_output=True, text=True,
                              timeout=TIMEOUT_S)
    except subprocess.TimeoutExpired:
        return [f"{os.path.relpath(path, ROOT)}: python block #{idx} "
                f"timed out after {TIMEOUT_S}s"]
    if proc.returncode != 0:
        tail = (proc.stderr or proc.stdout).strip().splitlines()[-12:]
        return [f"{os.path.relpath(path, ROOT)}: python block #{idx} "
                f"failed (rc={proc.returncode}):\n    "
                + "\n    ".join(tail)]
    return []


def main() -> int:
    errors = []
    n_blocks = 0
    for path in doc_files():
        with open(path, encoding="utf-8") as f:
            text = f.read()
        errors.extend(check_links(path, text))
        for i, code in enumerate(python_blocks(text), 1):
            n_blocks += 1
            print(f"running {os.path.relpath(path, ROOT)} "
                  f"python block #{i} ...", flush=True)
            errors.extend(run_block(path, i, code))
    if errors:
        print(f"\nFAIL: {len(errors)} docs problem(s)\n")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"\nOK: {len(doc_files())} files, all links resolve, "
          f"{n_blocks} python blocks ran clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
